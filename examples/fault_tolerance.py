"""Fault-tolerance demo: REAL JAX training under the platform, with a
learner crash injected mid-run.  The learner restores from a real
checkpoint in the object store and finishes with loss continuity.

    PYTHONPATH=src python examples/fault_tolerance.py
"""
import jax
import jax.numpy as jnp

from repro.configs import RunConfig, get_config
from repro.core import DLaaSPlatform, JobManifest
from repro.core.learner import RealPayload
from repro.data.pipeline import SyntheticLMData
from repro.models.layers import Ctx
from repro.train.steps import init_train_state, make_train_step


def main():
    cfg = get_config("paper-overhead-100m").reduced()
    run = RunConfig(learning_rate=2e-3, warmup_steps=5, total_steps=80)
    data = SyntheticLMData(cfg.vocab_size, 32, 4, seed=0)
    step = jax.jit(make_train_step(cfg, Ctx(dtype=jnp.float32), run))

    platform = DLaaSPlatform(seed=21)
    platform.run(10)
    h = platform.submit(JobManifest(
        name="real-train", learners=1, total_steps=80, step_time_s=0.5,
        checkpoint_interval_s=10, real_compute=True))
    platform.run(5)
    payload = RealPayload(
        make_state=lambda: init_train_state(cfg, jax.random.key(0), run),
        train_step=step, data=data)
    platform.register_payload(h.job_id, payload)

    print(f"job {h.job_id} training (real JAX steps on CPU)...")
    platform.run(45)
    vol = platform.volumes.get(f"vol-{h.job_id}")
    print(f"  loss before crash: {vol.read('last_loss'):.4f} "
          f"(step {vol.read('progress/0')['step']})")

    print("  >>> killing the learner pod <<<")
    platform.kill_pod(f"learner-{h.job_id}-0")

    final = platform.run_until_terminal(h.job_id, timeout=900)
    print(f"job finished: {final}")
    print(f"  restarts recorded: {platform.client.status(h.job_id)['restarts']}")
    print("\nlearner log (crash + restore visible):")
    print(platform.client.logs(h.job_id, 0))


if __name__ == "__main__":
    main()
