"""Batched serving example over the Job API v2: flags become a
``JobSpec(kind="serve")`` and the shared executor runs it — the exact
same spec a client could submit to the platform for gang-scheduled,
quota'd, metered serving (reduced configs run on CPU; full configs target
the production mesh).

    PYTHONPATH=src python examples/serve_batch.py --arch rwkv6-7b
"""
import argparse

from repro.core import JobSpec, ServeSpec
from repro.launch.executor import execute


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    spec = JobSpec(
        name=f"serve-batch-{args.arch}",
        kind="serve",
        framework=args.arch,
        serve=ServeSpec(
            batch=args.batch,
            prompt_len=args.prompt_len,
            gen=args.gen,
            reduced=True,
        ))
    return execute(spec)


if __name__ == "__main__":
    raise SystemExit(main())
