"""Batched serving example: prefill + greedy decode over the public API
(reduced configs run on CPU; full configs target the production mesh).

    PYTHONPATH=src python examples/serve_batch.py --arch rwkv6-7b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.layers import Ctx
from repro.models.model import init_cache
from repro.models.params import init_params
from repro.train.steps import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    ctx = Ctx(dtype=jnp.float32)
    params = init_params(cfg, jax.random.key(0))
    B, P, G = args.batch, args.prompt_len, args.gen

    batch = {"tokens": jax.random.randint(jax.random.key(1), (B, P), 0,
                                          cfg.vocab_size)}
    src_len = 0
    if cfg.is_encoder_decoder:
        src_len = max(P // 4, 16)
        batch["src_embeds"] = 0.02 * jax.random.normal(
            jax.random.key(2), (B, src_len, cfg.d_model))

    prefill = jax.jit(make_prefill_step(cfg, ctx))
    decode = jax.jit(make_decode_step(cfg, ctx), donate_argnums=(2,))
    cache = init_cache(cfg, B, P + G, src_len=src_len)

    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    generated = [tok]
    t0 = time.time()
    for t in range(P, P + G - 1):
        logits, cache = decode(params, {"tokens": tok}, cache, jnp.int32(t))
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        generated.append(tok)
    jax.block_until_ready(tok)
    out = jnp.concatenate(generated, 1)
    print(f"[serve] {args.arch} (reduced) batch={B}: generated {G} tokens "
          f"per request in {time.time()-t0:.1f}s")
    for i in range(min(B, 2)):
        print(f"  req {i}: {out[i].tolist()}")


if __name__ == "__main__":
    main()
