"""End-to-end driver: train a ~100M-parameter model for a few hundred steps
under the platform (real forward/backward, real checkpoints, crash-safe).

On this CPU container the full 100M preset is slow (~10s/step); presets let
you scale the demo.  On a TPU slice use --arch to train any registry
architecture at full size.

    PYTHONPATH=src python examples/train_e2e.py --preset 3m --steps 200
    PYTHONPATH=src python examples/train_e2e.py --preset 100m --steps 12
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, get_config
from repro.core import DLaaSPlatform, JobManifest
from repro.core.learner import RealPayload
from repro.data.pipeline import SyntheticLMData
from repro.models.layers import Ctx
from repro.models.params import count_params
from repro.train.steps import init_train_state, make_train_step

PRESETS = {
    # name: (num_layers, d_model, heads, kv, d_ff, vocab, seq, batch)
    "1m": (4, 128, 4, 2, 512, 2048, 64, 4),
    "3m": (6, 192, 6, 2, 768, 4096, 64, 4),
    "10m": (8, 320, 8, 4, 1280, 8192, 96, 4),
    "100m": (12, 768, 12, 4, 3072, 32768, 128, 4),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="3m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lr", type=float, default=2e-3)
    args = ap.parse_args()

    L, D, H, K, F, V, seq, batch = PRESETS[args.preset]
    base = get_config("paper-overhead-100m")
    cfg = dataclasses.replace(base, name=f"e2e-{args.preset}", num_layers=L,
                              d_model=D, num_heads=H, num_kv_heads=K,
                              head_dim=D // H, d_ff=F, vocab_size=V)
    print(f"[e2e] model: {count_params(cfg)/1e6:.1f}M non-embedding params "
          f"({count_params(cfg, include_embed=True)/1e6:.1f}M total)")

    run = RunConfig(learning_rate=args.lr, warmup_steps=args.steps // 20 + 1,
                    total_steps=args.steps)
    data = SyntheticLMData(cfg.vocab_size, seq, batch, seed=0)
    step = jax.jit(make_train_step(cfg, Ctx(dtype=jnp.float32), run))

    platform = DLaaSPlatform(seed=3)
    platform.run(10)
    h = platform.submit(JobManifest(
        name=f"e2e-{args.preset}", learners=1, total_steps=args.steps,
        step_time_s=0.2, checkpoint_interval_s=30, real_compute=True))
    platform.run(5)
    platform.register_payload(h.job_id, RealPayload(
        make_state=lambda: init_train_state(cfg, jax.random.key(0), run),
        train_step=step, data=data))

    t0 = time.time()
    vol = None
    while True:
        platform.run(20)
        vol = platform.volumes.get(f"vol-{h.job_id}")
        st = platform.client.status(h.job_id)
        if vol is not None and vol.read("last_loss") is not None:
            pr = vol.read("progress/0")
            print(f"  wall {time.time()-t0:6.1f}s  step {pr['step']:4d}  "
                  f"loss {vol.read('last_loss'):.4f}  state {st['state']}")
        if st["state"] in ("COMPLETED", "FAILED", "HALTED"):
            break
    print(f"[e2e] final: {st['state']} in {time.time()-t0:.0f}s wall; "
          f"checkpoints kept: "
          f"{[p for p in platform.objectstore.list_prefix(f'ckpt/{h.job_id}/') if p.endswith('manifest')]}")


if __name__ == "__main__":
    main()
