"""Quickstart: submit a training job to the DLaaS platform, watch it run.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import DLaaSPlatform, JobManifest


def main():
    # a 16-node cluster with core services (API x2, LCM, 3-replica ETCD)
    platform = DLaaSPlatform(seed=0)
    platform.run(10)                      # services come up

    manifest = JobManifest(
        name="my-first-job",
        framework="qwen3-0.6b",           # any registry architecture
        learners=4,
        gpus_per_learner=2,
        total_steps=100,
        step_time_s=0.5,
        checkpoint_interval_s=15.0,       # bound lost work to 15 virtual s
    )
    handle = platform.submit(manifest)
    platform.run(5)
    print(f"submitted: acked={handle.acked} job_id={handle.job_id}")

    # poll status while it runs
    for _ in range(6):
        platform.run(15)
        st = platform.client.status(handle.job_id)
        print(f"t={platform.sim.now:7.1f}s  state={st['state']:12s} "
              f"learners={st['learner_states']}")
        if st["state"] in ("COMPLETED", "FAILED"):
            break

    final = platform.run_until_terminal(handle.job_id, timeout=600)
    print(f"\nfinal state: {final}")
    print("\ntimeline (first 10 events):")
    for e in platform.client.events(handle.job_id)[:10]:
        print(f"  {e['t']:8.2f}  {e['event']}")
    print("\nlearner-0 log:")
    print(platform.client.logs(handle.job_id, 0))
    print(f"gpu-seconds metered: "
          f"{platform.client.gpu_seconds('default'):.0f}")


if __name__ == "__main__":
    main()
