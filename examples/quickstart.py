"""Quickstart: submit a Job API v2 training job to the DLaaS platform,
watch it run, then demonstrate idempotent resubmission and listing.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import DLaaSPlatform, JobSpec, Resources, TrainSpec


def main():
    # a 16-node cluster with core services (API x2, LCM, 3-replica ETCD)
    platform = DLaaSPlatform(seed=0)
    platform.run(10)                      # services come up

    spec = JobSpec(
        name="my-first-job",
        kind="train",                     # train | serve | dryrun
        framework="qwen3-0.6b",           # any id in the adapter registry
        resources=Resources(replicas=4, gpus_per_replica=2),
        train=TrainSpec(
            total_steps=100,
            step_time_s=0.5,
            checkpoint_interval_s=15.0,   # bound lost work to 15 virtual s
        ))
    handle = platform.submit(spec, request_id="quickstart-001")
    platform.run(5)
    print(f"submitted: acked={handle.acked} job_id={handle.job_id}")

    # resubmitting the same request_id is idempotent: same job, no dup —
    # this is how a client recovers from an ack lost to an API failover
    again = platform.submit(spec, request_id="quickstart-001")
    platform.run(5)
    print(f"resubmit:  job_id={again.job_id} "
          f"(deduplicated={again.deduplicated})")

    # poll status while it runs
    for _ in range(6):
        platform.run(15)
        st = platform.client.get(handle.job_id)
        print(f"t={platform.sim.now:7.1f}s  state={st['state']:12s} "
              f"learners={st['learner_states']}")
        if st["state"] in ("COMPLETED", "FAILED"):
            break

    final = platform.run_until_terminal(handle.job_id, timeout=600)
    print(f"\nfinal state: {final}")
    jobs, _ = platform.client.list(kind="train")
    print(f"train jobs: {[(j['id'], j['state']) for j in jobs]}")
    print("\ntimeline (first 10 events):")
    for e in platform.client.events(handle.job_id)[:10]:
        print(f"  {e['t']:8.2f}  {e['event']}")
    print("\nlearner-0 log:")
    print(platform.client.logs(handle.job_id, 0))
    print(f"gpu-seconds metered: "
          f"{platform.client.gpu_seconds('default'):.0f}")


if __name__ == "__main__":
    main()
