"""ServingEngine: snapshot/restore golden-token equivalence, the
requeue-on-eviction path (optimistic admission), hash-addressed prefix
caching (shared-prefix dedup, CoW divergence, evict-then-readmit, restore
with live refcounts), and PagePool allocator/refcount invariants under
random traffic (hypothesis-stub properties)."""
import dataclasses
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.jobspec import ServeSpec
from repro.launch.engine import (
    PagePool, Request, ServingEngine, synthesize_requests)
from repro.models.layers import Ctx
from repro.models.params import init_params


def _build(sv: ServeSpec):
    cfg = dataclasses.replace(get_config("qwen3-0.6b").reduced(),
                              cache_layout="paged")
    ctx = Ctx(dtype=jnp.float32)
    params = init_params(cfg, jax.random.key(0))
    return cfg, ctx, params


def _drive(engine, snap_at=()):
    """engine.run(), capturing a snapshot after decode step k for every
    k in ``snap_at`` (the boundaries: post-admission, mid-flight, late)."""
    snaps = {}
    while not engine.idle:
        engine.admit()
        if 0 in snap_at and 0 not in snaps:
            snaps[0] = engine.snapshot()         # after the first admission
        if all(s is None for s in engine.slots):
            if not engine.queue:
                break
            continue
        engine.step()
        k = engine.decode_steps
        if k in snap_at and k not in snaps:
            snaps[k] = engine.snapshot()
    return snaps


# ---------------------------------------------------------------------------
# Kill-mid-stream / restore: golden-token equivalence
# ---------------------------------------------------------------------------
def test_snapshot_restore_golden_tokens():
    """Run the engine to completion, snapshotting at several boundaries
    (right after the first admission round, mid-decode, near the end).
    A FRESH engine restored from each snapshot must finish with responses
    byte-identical to the uninterrupted run — the recovery contract the
    platform's killed-server scenario rests on."""
    sv = ServeSpec(batch=2, prompt_len=16, gen=6, requests=5,
                   page_budget=6, reduced=True)
    cfg, ctx, params = _build(sv)

    golden = ServingEngine(cfg, ctx, params, sv)
    for r in synthesize_requests(cfg, sv, seed=0, ragged=golden.ragged):
        golden.submit(r)
    snaps = _drive(golden, snap_at=(0, 3, 7))
    assert len(golden.responses) == sv.requests
    assert set(snaps) == {0, 3, 7}, set(snaps)

    for k, snap in snaps.items():
        eng = ServingEngine(cfg, ctx, params, sv)
        eng.restore(snap)
        _drive(eng)
        assert eng.responses == golden.responses, f"boundary {k}"
        # every request's stream has exactly its generation budget
        for r, toks in eng.responses.items():
            assert len(toks) > 0


def test_snapshot_is_plain_host_data():
    """Snapshots must be device-free (they live on the job volume and are
    restored by a different pod incarnation): numpy arrays + plain
    Python containers only."""
    sv = ServeSpec(batch=2, prompt_len=16, gen=4, requests=2, reduced=True)
    cfg, ctx, params = _build(sv)
    eng = ServingEngine(cfg, ctx, params, sv)
    for r in synthesize_requests(cfg, sv, seed=0, ragged=eng.ragged):
        eng.submit(r)
    eng.admit()
    eng.step()
    snap = eng.snapshot()
    for leaf in jax.tree.leaves(snap["cache"]):
        assert isinstance(leaf, np.ndarray), type(leaf)
    assert isinstance(snap["host_table"], np.ndarray)
    assert snap["journal_len"] == len(eng.journal)


# ---------------------------------------------------------------------------
# Optimistic admission + requeue-on-eviction
# ---------------------------------------------------------------------------
def _two_requests(ps=8):
    toks = np.asarray(jax.random.randint(
        jax.random.key(1), (2, 8), 0, 503))
    # gen 10: decode writes positions 8..16 — the 17th slot forces a third
    # page mid-decode, which a 4-page pool cannot give both sequences
    return [Request(req=0, tokens=toks[0], gen_len=10),
            Request(req=1, tokens=toks[1], gen_len=10)]


def test_overcommit_evicts_and_loses_nothing():
    """Page-starved workload: budget 4 pages, two requests needing 3
    worst-case each.  Conservative admission (1.0) serializes them;
    overcommit 2.0 admits both optimistically, hits page exhaustion
    mid-decode, evicts the youngest back to the queue (requeue path) and
    still completes every request — with responses identical to the
    conservative run (greedy decode re-prefills deterministically)."""
    sv = ServeSpec(batch=2, prompt_len=8, gen=10, requests=2,
                   page_budget=4, reduced=True)
    cfg, ctx, params = _build(sv)

    conservative = ServingEngine(cfg, ctx, params, sv)
    for r in _two_requests():
        conservative.submit(r)
    _drive(conservative)
    assert conservative.evictions == 0
    assert conservative.stalled_admissions > 0   # the pool forced a wait
    assert len(conservative.responses) == 2

    optimistic = ServingEngine(cfg, ctx, params,
                               dataclasses.replace(sv, overcommit=2.0))
    for r in _two_requests():
        optimistic.submit(r)
    _drive(optimistic)
    assert optimistic.evictions > 0              # preemption really fired
    assert len(optimistic.responses) == 2        # no request lost
    assert optimistic.responses == conservative.responses
    evicted = [e["req"] for e in optimistic.journal if e["ev"] == "evict"]
    assert evicted, "journal must record the eviction"
    # the evicted request was re-admitted after its eviction
    j = optimistic.journal
    last_evict = max(i for i, e in enumerate(j) if e["ev"] == "evict")
    assert any(e["ev"] == "admit" and e["req"] == j[last_evict]["req"]
               for e in j[last_evict + 1:])


def test_submit_rejects_undeadlockable_request():
    """A request whose worst-case pages exceed a shard's capacity can
    never be admitted — submit() rejects it up front instead of letting
    admission deadlock on it."""
    sv = ServeSpec(batch=2, prompt_len=8, gen=10, requests=1,
                   page_budget=4, reduced=True)
    cfg, ctx, params = _build(sv)
    eng = ServingEngine(cfg, ctx, params, sv)
    big = Request(req=0, tokens=np.zeros(17, np.int64), gen_len=24)
    with pytest.raises(ValueError, match="worst-case"):
        eng.submit(big)


# ---------------------------------------------------------------------------
# PagePool invariants (hypothesis-stub property tests)
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(n_shards=st.sampled_from([1, 2, 4]),
       per_shard=st.integers(1, 8),
       ops=st.lists(st.tuples(st.integers(0, 3), st.integers(1, 6)),
                    min_size=1, max_size=40))
def test_page_pool_invariants(n_shards, per_shard, ops):
    """Random alloc/free traffic: no page is ever handed out twice, the
    free + in-use partition always covers exactly the pool, and shard
    locality survives any free/realloc interleaving (pages always return
    to — and are always handed out from — their own shard's range)."""
    n_pages = n_shards * per_shard
    pool = PagePool(n_pages, n_shards)
    rng = np.random.default_rng(per_shard * 1000 + len(ops))
    held = []                                  # lists of allocated pages
    for kind, n in ops:
        if kind == 0 and held:                 # free a random allocation
            pages = held.pop(rng.integers(len(held)))
            pool.free(pages)
        else:                                  # alloc n from a random shard
            shard = int(rng.integers(n_shards))
            got = pool.alloc(n, shard)
            if got is None:
                free_in_shard = len(pool.free_lists[shard])
                assert n > free_in_shard       # refusal only when starved
                continue
            assert len(got) == n
            lo, hi = shard * per_shard, (shard + 1) * per_shard
            assert all(lo <= p < hi for p in got)   # shard locality
            held.append(got)
        # global invariants after every operation
        out = [p for pages in held for p in pages]
        assert len(out) == len(set(out))       # no double allocation
        free = [p for fl in pool.free_lists for p in fl]
        assert len(free) == len(set(free))     # no double free
        assert sorted(out + free) == list(range(n_pages))
        assert pool.in_use == len(out)
        assert pool.high_water >= pool.in_use


@settings(max_examples=30, deadline=None)
@given(per_shard=st.integers(2, 8),
       ops=st.lists(st.tuples(st.integers(0, 3), st.integers(1, 4)),
                    min_size=1, max_size=50))
def test_page_pool_refcount_invariants(per_shard, ops):
    """Random alloc/free/publish/attach traffic (the prefix-cache op mix):
    a page's refcount always equals the number of live holders, the free
    lists never intersect the referenced set, nothing is double-freed, and
    every page is either free or referenced — never both, never neither."""
    n_shards = 2
    n_pages = n_shards * per_shard
    pool = PagePool(n_pages, n_shards)
    rng = np.random.default_rng(per_shard * 7 + len(ops))
    held = []                      # page lists; each entry holds one ref/page
    for kind, n in ops:
        shard = int(rng.integers(n_shards))
        if kind == 0 and held:                 # release one holder
            pool.free(held.pop(rng.integers(len(held))))
        elif kind == 1:                        # fresh allocation
            got = pool.alloc(n, shard)
            if got is not None:
                held.append(got)
        elif kind == 2 and held:               # publish a held page
            pages = held[int(rng.integers(len(held)))]
            p = pages[int(rng.integers(len(pages)))]
            pool.publish(p, "root", f"chain-{p}", [p])
        else:                                  # prefix hit: attach via index
            kids = pool.candidates(shard, "root")
            if kids:
                chain = sorted(kids)[int(rng.integers(len(kids)))]
                p = pool.lookup(shard, "root", chain)
                pool.attach(p)
                held.append([p])
        # invariants after every operation
        holders = Counter(p for pages in held for p in pages)
        free = [p for fl in pool.free_lists for p in fl]
        assert len(free) == len(set(free))     # no double free
        assert not set(free) & set(holders)    # free ∩ referenced = ∅
        for p in range(n_pages):
            assert pool.refcount[p] == holders.get(p, 0)
        assert sorted(set(free) | set(holders)) == list(range(n_pages))
        assert pool.in_use == len(holders)     # unique, not sum of refs
        assert pool.high_water >= pool.in_use
        # the index never points at a page whose metadata disagrees
        for s in range(n_shards):
            for parent, kids in pool.prefix_index[s].items():
                for chain, p in kids.items():
                    assert pool.page_meta[p]["hash"] == chain
                    assert pool.page_meta[p]["parent"] == parent
                    assert pool.shard_of(p) == s


def test_page_pool_shard_free_realloc_locality():
    """Freeing a foreign-shard page routes it back to its home shard's
    free list, so a later same-shard alloc returns it (the regression the
    property test covers, pinned deterministically)."""
    pool = PagePool(8, n_shards=2)
    a = pool.alloc(4, shard=0)
    b = pool.alloc(4, shard=1)
    assert a == [0, 1, 2, 3] and b == [4, 5, 6, 7]
    pool.free([5])                             # shard-1 page
    assert pool.alloc(1, shard=0) is None      # shard 0 still empty
    assert pool.alloc(1, shard=1) == [5]


def test_page_pool_cached_but_free_lifecycle():
    """A freed published page stays hittable (cached-but-free) until the
    allocator reuses its physical page, which deregisters it."""
    pool = PagePool(4, n_shards=1)
    (p,) = pool.alloc(1)
    pool.publish(p, "root", "c0", [1, 2])
    pool.free([p])                             # refcount 0, still indexed
    assert pool.lookup(0, "root", "c0") == p
    pool.attach(p)                             # hit revives it off the list
    assert pool.refcount[p] == 1 and pool.in_use == 1
    pool.free([p])
    # exhaust the pool: the cached page is eventually handed back out,
    # and reuse must end its cache life
    got = pool.alloc(4)
    assert got is not None and p in got
    assert pool.lookup(0, "root", "c0") is None
    assert p not in pool.page_meta


# ---------------------------------------------------------------------------
# Prefix caching: golden-token equivalence
# ---------------------------------------------------------------------------
def _solo_response(cfg, ctx, params, sv, req):
    """One request through a fresh engine (no sharing possible)."""
    eng = ServingEngine(cfg, ctx, params, sv)
    eng.submit(req)
    eng.run()
    return eng.responses[req.req]


def test_shared_prefix_batch_equals_solo():
    """A batch sharing a page-aligned 75% prefix pays one prefill over the
    shared span, keeps ONE physical copy of the prefix pages, and still
    answers every request exactly as a solo run would — aliasing is
    invisible to greedy decode."""
    sv = ServeSpec(batch=4, prompt_len=32, gen=4, requests=4,
                   page_budget=12, reduced=True, shared_prefix_frac=0.75)
    cfg, ctx, params = _build(sv)
    eng = ServingEngine(cfg, ctx, params, sv)
    reqs = synthesize_requests(cfg, sv, seed=0, ragged=eng.ragged)
    for r in reqs:
        eng.submit(r)
    eng.admit()                    # round 1: the leader (followers defer)
    assert sum(s is not None for s in eng.slots) == 1
    eng.admit()                    # round 2: followers hit the index
    assert eng.prefix_hits == 3 and eng.prefix_misses == 1
    assert eng.cow_copies == 0     # 24 shared tokens = 3 whole pages
    ps = eng.ps
    assert eng.resident_prefix_pages() == 24 // ps
    # one prefill over the shared span: 32 + 3 private 8-token tails
    assert eng.prefill_tokens == 32 + 3 * 8
    assert eng.cached_tokens == 3 * 24
    eng.run()
    assert len(eng.responses) == 4
    for r in reqs:
        assert eng.responses[r.req] == _solo_response(cfg, ctx, params,
                                                      sv, r), r.req


def test_cow_divergence_mid_page():
    """Two prompts agreeing through token 19 and diverging at token 20
    (mid-page): the follower attaches the 2 whole shared pages, CoW-copies
    the partially-shared third page (4 of 8 tokens reused), prefills only
    the divergent tail — and answers exactly as its solo run."""
    sv = ServeSpec(batch=2, prompt_len=24, gen=4, requests=2,
                   page_budget=12, reduced=True)
    cfg, ctx, params = _build(sv)
    base = np.array(jax.random.randint(
        jax.random.key(3), (24,), 0, cfg.vocab_size))
    fork = base.copy()
    fork[20] = (fork[20] + 1) % cfg.vocab_size
    reqs = [Request(req=0, tokens=base, gen_len=4),
            Request(req=1, tokens=fork, gen_len=4)]

    eng = ServingEngine(cfg, ctx, params, sv)
    for r in reqs:
        eng.submit(r)
    eng.admit()                    # leader prefills + publishes
    eng.admit()                    # follower: 2-page hit + mid-page CoW
    assert eng.prefix_hits == 1 and eng.cow_copies == 1
    assert eng.cached_tokens == 20            # 2 pages + 4-token overlap
    assert eng.prefill_tokens == 24 + 4
    # the CoW page is private: page 2 of the two rows must differ
    recs = [s for s in eng.slots if s is not None]
    assert recs[0].pages[2] != recs[1].pages[2]
    assert recs[0].pages[:2] == recs[1].pages[:2]     # aliased prefix
    eng.run()
    for r in reqs:
        assert eng.responses[r.req] == _solo_response(cfg, ctx, params,
                                                      sv, r), r.req


def test_evict_then_readmit_hits_cached_prefix():
    """Optimistic admission under page pressure with identical prompts:
    the evicted follower's private pages are freed but the shared prefix
    stays cached, so its re-admission is another prefix hit — and the
    final responses match the conservative (never-evicting) run."""
    # the leader generates longer than the follower, so it is still alive
    # (holding the last free page) when the follower's decode crosses its
    # own page boundary one step later — forcing the eviction
    sv = ServeSpec(batch=2, prompt_len=16, gen=12, requests=2,
                   page_budget=6, reduced=True)
    cfg, ctx, params = _build(sv)
    toks = np.array(jax.random.randint(
        jax.random.key(4), (16,), 0, cfg.vocab_size))
    mk = lambda: [Request(req=0, tokens=toks.copy(), gen_len=12),  # noqa: E731
                  Request(req=1, tokens=toks.copy(), gen_len=10)]

    conservative = ServingEngine(cfg, ctx, params, sv)
    for r in mk():
        conservative.submit(r)
    _drive(conservative)
    assert conservative.evictions == 0
    assert conservative.prefix_hits >= 1      # serialized follower still hits

    optimistic = ServingEngine(cfg, ctx, params,
                               dataclasses.replace(sv, overcommit=2.0))
    for r in mk():
        optimistic.submit(r)
    _drive(optimistic)
    assert optimistic.evictions > 0
    assert optimistic.prefix_hits >= 2        # initial admit + re-admit
    assert len(optimistic.responses) == 2
    assert optimistic.responses == conservative.responses


def test_snapshot_restore_with_shared_pages():
    """Kill-mid-stream with shared pages live: snapshots taken while
    prefix pages carry refcount > 1 must round-trip the refcounts, the
    prefix index and the page metadata byte-identically, and a restored
    engine must finish with the uninterrupted run's exact responses."""
    sv = ServeSpec(batch=4, prompt_len=32, gen=6, requests=6,
                   page_budget=16, reduced=True, shared_prefix_frac=0.9)
    cfg, ctx, params = _build(sv)
    golden = ServingEngine(cfg, ctx, params, sv)
    for r in synthesize_requests(cfg, sv, seed=0, ragged=golden.ragged):
        golden.submit(r)
    snaps = _drive(golden, snap_at=(2, 4))
    assert len(golden.responses) == sv.requests
    assert golden.prefix_hits > 0 and golden.cow_copies > 0   # 29-token share
    assert any(c > 1 for c in snaps[2]["refcount"])           # sharing live

    for k, snap in snaps.items():
        eng = ServingEngine(cfg, ctx, params, sv)
        eng.restore(snap)
        rt = eng.snapshot()       # restore → snapshot must be the identity
        assert rt["refcount"] == snap["refcount"], k
        assert rt["page_meta"] == snap["page_meta"], k
        assert rt["prefix_index"] == snap["prefix_index"], k
        _drive(eng)
        assert eng.responses == golden.responses, f"boundary {k}"
