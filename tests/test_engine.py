"""ServingEngine: snapshot/restore golden-token equivalence, the
requeue-on-eviction path (optimistic admission), and PagePool allocator
invariants under random alloc/free traffic (hypothesis-stub properties)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.jobspec import ServeSpec
from repro.launch.engine import (
    PagePool, Request, ServingEngine, synthesize_requests)
from repro.models.layers import Ctx
from repro.models.params import init_params


def _build(sv: ServeSpec):
    cfg = dataclasses.replace(get_config("qwen3-0.6b").reduced(),
                              cache_layout="paged")
    ctx = Ctx(dtype=jnp.float32)
    params = init_params(cfg, jax.random.key(0))
    return cfg, ctx, params


def _drive(engine, snap_at=()):
    """engine.run(), capturing a snapshot after decode step k for every
    k in ``snap_at`` (the boundaries: post-admission, mid-flight, late)."""
    snaps = {}
    while not engine.idle:
        engine.admit()
        if 0 in snap_at and 0 not in snaps:
            snaps[0] = engine.snapshot()         # after the first admission
        if all(s is None for s in engine.slots):
            if not engine.queue:
                break
            continue
        engine.step()
        k = engine.decode_steps
        if k in snap_at and k not in snaps:
            snaps[k] = engine.snapshot()
    return snaps


# ---------------------------------------------------------------------------
# Kill-mid-stream / restore: golden-token equivalence
# ---------------------------------------------------------------------------
def test_snapshot_restore_golden_tokens():
    """Run the engine to completion, snapshotting at several boundaries
    (right after the first admission round, mid-decode, near the end).
    A FRESH engine restored from each snapshot must finish with responses
    byte-identical to the uninterrupted run — the recovery contract the
    platform's killed-server scenario rests on."""
    sv = ServeSpec(batch=2, prompt_len=16, gen=6, requests=5,
                   page_budget=6, reduced=True)
    cfg, ctx, params = _build(sv)

    golden = ServingEngine(cfg, ctx, params, sv)
    for r in synthesize_requests(cfg, sv, seed=0, ragged=golden.ragged):
        golden.submit(r)
    snaps = _drive(golden, snap_at=(0, 3, 7))
    assert len(golden.responses) == sv.requests
    assert set(snaps) == {0, 3, 7}, set(snaps)

    for k, snap in snaps.items():
        eng = ServingEngine(cfg, ctx, params, sv)
        eng.restore(snap)
        _drive(eng)
        assert eng.responses == golden.responses, f"boundary {k}"
        # every request's stream has exactly its generation budget
        for r, toks in eng.responses.items():
            assert len(toks) > 0


def test_snapshot_is_plain_host_data():
    """Snapshots must be device-free (they live on the job volume and are
    restored by a different pod incarnation): numpy arrays + plain
    Python containers only."""
    sv = ServeSpec(batch=2, prompt_len=16, gen=4, requests=2, reduced=True)
    cfg, ctx, params = _build(sv)
    eng = ServingEngine(cfg, ctx, params, sv)
    for r in synthesize_requests(cfg, sv, seed=0, ragged=eng.ragged):
        eng.submit(r)
    eng.admit()
    eng.step()
    snap = eng.snapshot()
    for leaf in jax.tree.leaves(snap["cache"]):
        assert isinstance(leaf, np.ndarray), type(leaf)
    assert isinstance(snap["host_table"], np.ndarray)
    assert snap["journal_len"] == len(eng.journal)


# ---------------------------------------------------------------------------
# Optimistic admission + requeue-on-eviction
# ---------------------------------------------------------------------------
def _two_requests(ps=8):
    toks = np.asarray(jax.random.randint(
        jax.random.key(1), (2, 8), 0, 503))
    # gen 10: decode writes positions 8..16 — the 17th slot forces a third
    # page mid-decode, which a 4-page pool cannot give both sequences
    return [Request(req=0, tokens=toks[0], gen_len=10),
            Request(req=1, tokens=toks[1], gen_len=10)]


def test_overcommit_evicts_and_loses_nothing():
    """Page-starved workload: budget 4 pages, two requests needing 3
    worst-case each.  Conservative admission (1.0) serializes them;
    overcommit 2.0 admits both optimistically, hits page exhaustion
    mid-decode, evicts the youngest back to the queue (requeue path) and
    still completes every request — with responses identical to the
    conservative run (greedy decode re-prefills deterministically)."""
    sv = ServeSpec(batch=2, prompt_len=8, gen=10, requests=2,
                   page_budget=4, reduced=True)
    cfg, ctx, params = _build(sv)

    conservative = ServingEngine(cfg, ctx, params, sv)
    for r in _two_requests():
        conservative.submit(r)
    _drive(conservative)
    assert conservative.evictions == 0
    assert conservative.stalled_admissions > 0   # the pool forced a wait
    assert len(conservative.responses) == 2

    optimistic = ServingEngine(cfg, ctx, params,
                               dataclasses.replace(sv, overcommit=2.0))
    for r in _two_requests():
        optimistic.submit(r)
    _drive(optimistic)
    assert optimistic.evictions > 0              # preemption really fired
    assert len(optimistic.responses) == 2        # no request lost
    assert optimistic.responses == conservative.responses
    evicted = [e["req"] for e in optimistic.journal if e["ev"] == "evict"]
    assert evicted, "journal must record the eviction"
    # the evicted request was re-admitted after its eviction
    j = optimistic.journal
    last_evict = max(i for i, e in enumerate(j) if e["ev"] == "evict")
    assert any(e["ev"] == "admit" and e["req"] == j[last_evict]["req"]
               for e in j[last_evict + 1:])


def test_submit_rejects_undeadlockable_request():
    """A request whose worst-case pages exceed a shard's capacity can
    never be admitted — submit() rejects it up front instead of letting
    admission deadlock on it."""
    sv = ServeSpec(batch=2, prompt_len=8, gen=10, requests=1,
                   page_budget=4, reduced=True)
    cfg, ctx, params = _build(sv)
    eng = ServingEngine(cfg, ctx, params, sv)
    big = Request(req=0, tokens=np.zeros(17, np.int64), gen_len=24)
    with pytest.raises(ValueError, match="worst-case"):
        eng.submit(big)


# ---------------------------------------------------------------------------
# PagePool invariants (hypothesis-stub property tests)
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(n_shards=st.sampled_from([1, 2, 4]),
       per_shard=st.integers(1, 8),
       ops=st.lists(st.tuples(st.integers(0, 3), st.integers(1, 6)),
                    min_size=1, max_size=40))
def test_page_pool_invariants(n_shards, per_shard, ops):
    """Random alloc/free traffic: no page is ever handed out twice, the
    free + in-use partition always covers exactly the pool, and shard
    locality survives any free/realloc interleaving (pages always return
    to — and are always handed out from — their own shard's range)."""
    n_pages = n_shards * per_shard
    pool = PagePool(n_pages, n_shards)
    rng = np.random.default_rng(per_shard * 1000 + len(ops))
    held = []                                  # lists of allocated pages
    for kind, n in ops:
        if kind == 0 and held:                 # free a random allocation
            pages = held.pop(rng.integers(len(held)))
            pool.free(pages)
        else:                                  # alloc n from a random shard
            shard = int(rng.integers(n_shards))
            got = pool.alloc(n, shard)
            if got is None:
                free_in_shard = len(pool.free_lists[shard])
                assert n > free_in_shard       # refusal only when starved
                continue
            assert len(got) == n
            lo, hi = shard * per_shard, (shard + 1) * per_shard
            assert all(lo <= p < hi for p in got)   # shard locality
            held.append(got)
        # global invariants after every operation
        out = [p for pages in held for p in pages]
        assert len(out) == len(set(out))       # no double allocation
        free = [p for fl in pool.free_lists for p in fl]
        assert len(free) == len(set(free))     # no double free
        assert sorted(out + free) == list(range(n_pages))
        assert pool.in_use == len(out)
        assert pool.high_water >= pool.in_use


def test_page_pool_shard_free_realloc_locality():
    """Freeing a foreign-shard page routes it back to its home shard's
    free list, so a later same-shard alloc returns it (the regression the
    property test covers, pinned deterministically)."""
    pool = PagePool(8, n_shards=2)
    a = pool.alloc(4, shard=0)
    b = pool.alloc(4, shard=1)
    assert a == [0, 1, 2, 3] and b == [4, 5, 6, 7]
    pool.free([5])                             # shard-1 page
    assert pool.alloc(1, shard=0) is None      # shard 0 still empty
    assert pool.alloc(1, shard=1) == [5]
