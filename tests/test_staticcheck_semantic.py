"""Semantic checkers (SC201/SC202/SC203): the real repo must be clean,
and injected defects must fire — the checkers are themselves tested by
mutation, not just by the happy path."""
import textwrap

import jax.numpy as jnp

from repro.staticcheck import drift_check, kernel_check, sharding_check
from repro.staticcheck.kernel_check import _check_layout
from repro.staticcheck.sharding_check import MESH_VOCAB, _validate_spec
from repro.kernels.layout import KernelLayout, SpecDesc


# ---------------------------------------------------------------------------
# The shipped repo passes all three checkers
# ---------------------------------------------------------------------------
def test_repo_sharding_clean():
    assert sharding_check.check() == []


def test_repo_kernels_clean():
    assert kernel_check.check() == []


def test_repo_drift_clean():
    assert drift_check.check() == []


# ---------------------------------------------------------------------------
# SC201 — sharding
# ---------------------------------------------------------------------------
def test_sharding_covers_every_config_on_both_meshes(monkeypatch):
    """Acceptance: the checker walks every registered config against the
    single-pod AND multi-pod production meshes."""
    import repro.configs.base as cfg_mod
    import repro.dist.mesh as mesh_mod

    seen_cfgs = []
    seen_meshes = []
    real_get, real_mesh = cfg_mod.get_config, \
        mesh_mod.make_abstract_production_mesh
    monkeypatch.setattr(cfg_mod, "get_config",
                        lambda name: seen_cfgs.append(name) or real_get(name))
    monkeypatch.setattr(
        mesh_mod, "make_abstract_production_mesh",
        lambda **kw: seen_meshes.append(kw.get("multi_pod", False))
        or real_mesh(**kw))

    assert sharding_check.check() == []
    assert set(seen_cfgs) == set(cfg_mod.list_configs())
    assert set(seen_meshes) == {False, True}


def test_sharding_validator_unknown_axis():
    probs = _validate_spec("w", ("tensor",), (16,), {"data": 4, "model": 2})
    assert len(probs) == 1 and "not a mesh axis" in probs[0]


def test_sharding_validator_use_once():
    probs = _validate_spec("w", (("data", "data"),), (16,), {"data": 4})
    assert any("used twice" in p for p in probs)


def test_sharding_validator_divisibility():
    probs = _validate_spec("w", ("data",), (10,), {"data": 4})
    assert len(probs) == 1 and "not divisible" in probs[0]


def test_sharding_validator_clean():
    assert _validate_spec("w", ("data", None), (8, 3), {"data": 4}) == []
    assert _validate_spec("w", (("pod", "data"),), (8,),
                          {"pod": 2, "data": 4}) == []


def test_sharding_injected_bad_rule_fires(monkeypatch):
    # a rule naming an axis outside the mesh vocabulary must be flagged
    import repro.dist.sharding as sh
    assert "bogus" not in MESH_VOCAB
    monkeypatch.setattr(sh, "DEFAULT_RULES",
                        sh.DEFAULT_RULES.override(embed=("bogus",)))
    findings = sharding_check.check()
    assert any("bogus" in f.message and f.rule == "SC201" for f in findings)


# ---------------------------------------------------------------------------
# SC202 — kernel layouts (mutation: broken layouts must fire)
# ---------------------------------------------------------------------------
def _layout(**kw):
    base = dict(
        name="toy",
        grid=(4,),
        in_specs=(SpecDesc("x", (4, 8), (1, 8), lambda i: (i, 0)),),
        out_specs=(SpecDesc("o", (4, 8), (1, 8), lambda i: (i, 0)),),
        scratch=(((8, 8), jnp.float32),),
        dimension_semantics=("parallel",),
    )
    base.update(kw)
    return KernelLayout(**base)


def test_kernel_toy_layout_clean():
    assert _check_layout(_layout(), "toy.py") == []


def test_kernel_out_of_bounds_index():
    bad = _layout(in_specs=(
        SpecDesc("x", (4, 8), (1, 8), lambda i: (i + 1, 0)),))
    fs = _check_layout(bad, "toy.py")
    assert any("outside [0, 4)" in f.message for f in fs)


def test_kernel_wrong_index_arity():
    bad = _layout(in_specs=(
        SpecDesc("x", (4, 8), (1, 8), lambda i: (i,)),))
    fs = _check_layout(bad, "toy.py")
    assert any("1 indices for a 2-dim block" in f.message for f in fs)


def test_kernel_uncovered_output_block():
    bad = _layout(out_specs=(
        SpecDesc("o", (4, 8), (1, 8), lambda i: (0, 0)),))
    fs = _check_layout(bad, "toy.py")
    assert any("never written" in f.message for f in fs)


def test_kernel_parallel_double_write():
    # two parallel grid points writing one output block = a data race;
    # only "arbitrary" (sequential) dims may revisit a block
    bad = _layout(
        grid=(2, 2),
        dimension_semantics=("parallel", "parallel"),
        in_specs=(SpecDesc("x", (2, 8), (1, 8), lambda i, j: (i, 0)),),
        out_specs=(SpecDesc("o", (2, 8), (1, 8), lambda i, j: (i, 0)),))
    fs = _check_layout(bad, "toy.py")
    assert any("twice in parallel" in f.message for f in fs)
    ok = _layout(
        grid=(2, 2),
        dimension_semantics=("parallel", "arbitrary"),
        in_specs=(SpecDesc("x", (2, 8), (1, 8), lambda i, j: (i, 0)),),
        out_specs=(SpecDesc("o", (2, 8), (1, 8), lambda i, j: (i, 0)),))
    assert _check_layout(ok, "toy.py") == []


def test_kernel_low_precision_scratch():
    bad = _layout(scratch=(((8, 8), jnp.bfloat16),))
    fs = _check_layout(bad, "toy.py")
    assert any("must be float32" in f.message for f in fs)


def test_kernel_semantics_arity_mismatch():
    bad = _layout(dimension_semantics=("parallel", "parallel"))
    fs = _check_layout(bad, "toy.py")
    assert any("arity" in f.message for f in fs)


# ---------------------------------------------------------------------------
# SC203 — snapshot/journal drift (mutation: synthetic engine tree)
# ---------------------------------------------------------------------------
GOOD_ENGINE = textwrap.dedent("""\
    class SeqRecord:
        request: object
        done: bool

    def rec_doc(rec):
        return {"req": 0, "tokens": 1, "gen_len": 2, "done": 3}

    def snapshot(self):
        return {
            "next": 1,
            "slots": [],
            "journal_len": 2,
            "stats": {"hits": 0},
        }

    def restore(self, snap):
        self.next = snap["next"]
        st = snap["stats"]
        self.hits = st["hits"]
        for doc in snap["slots"]:
            rec = SeqRecord(doc["req"], doc["tokens"], doc["gen_len"],
                            doc["done"])
        self.journal.append({"ev": "gen", "req": "r1"})
""")

GOOD_SERVER = textwrap.dedent("""\
    def save(engine, snap_doc, vol):
        snap_doc["engine"] = engine.snapshot()
        vol.append("journal", {"ev": "admit", "req": "r1"})

    def load(snap, engine):
        engine.restore(snap["engine"])
""")


def _drift_tree(tmp_path, engine_src=GOOD_ENGINE, server_src=GOOD_SERVER):
    eng = tmp_path / drift_check.ENGINE
    srv = tmp_path / drift_check.SERVER
    eng.parent.mkdir(parents=True, exist_ok=True)
    srv.parent.mkdir(parents=True, exist_ok=True)
    eng.write_text(engine_src)
    srv.write_text(server_src)
    return tmp_path


def _messages(findings):
    return [f.message for f in findings]


def test_drift_synthetic_clean(tmp_path):
    assert drift_check.check(_drift_tree(tmp_path)) == []


def test_drift_snapshot_key_never_restored(tmp_path):
    bad = GOOD_ENGINE.replace('"next": 1,', '"next": 1,\n        "extra": 0,')
    fs = drift_check.check(_drift_tree(tmp_path, engine_src=bad))
    assert any("'extra'" in m and "restore never reads" in m
               for m in _messages(fs))


def test_drift_restore_reads_phantom_key(tmp_path):
    bad = GOOD_ENGINE.replace('self.next = snap["next"]',
                              'self.next = snap["next"]\n'
                              '    self.ghost = snap["ghost"]')
    fs = drift_check.check(_drift_tree(tmp_path, engine_src=bad))
    assert any("snapshot never emits" in m for m in _messages(fs))


def test_drift_seqrecord_field_missing_from_doc(tmp_path):
    bad = GOOD_ENGINE.replace('"done": 3}', '}').replace(
        ',\n                            doc["done"]', '')
    fs = drift_check.check(_drift_tree(tmp_path, engine_src=bad))
    assert any("'done'" in m and "missing from" in m for m in _messages(fs))


def test_drift_stats_key_never_restored(tmp_path):
    bad = GOOD_ENGINE.replace('{"hits": 0}', '{"hits": 0, "miss": 0}')
    fs = drift_check.check(_drift_tree(tmp_path, engine_src=bad))
    assert any("'miss'" in m and "never restored" in m for m in _messages(fs))


def test_drift_snapshot_only_allowlist_pruned(tmp_path):
    bad = GOOD_ENGINE.replace('"journal_len": 2,\n', '')
    fs = drift_check.check(_drift_tree(tmp_path, engine_src=bad))
    assert any("prune the allowlist" in m for m in _messages(fs))


def test_drift_journal_event_missing_req(tmp_path):
    bad = GOOD_ENGINE.replace('{"ev": "gen", "req": "r1"}', '{"ev": "gen"}')
    fs = drift_check.check(_drift_tree(tmp_path, engine_src=bad))
    assert any("replay dispatches on ev/req" in m for m in _messages(fs))


def test_drift_server_orphan_envelope_key(tmp_path):
    bad = GOOD_SERVER.replace(
        'snap_doc["engine"] = engine.snapshot()',
        'snap_doc["engine"] = engine.snapshot()\n'
        '    snap_doc["orphan"] = 1')
    fs = drift_check.check(_drift_tree(tmp_path, server_src=bad))
    assert any("'orphan'" in m and "never read" in m for m in _messages(fs))


def test_drift_missing_engine_is_reported(tmp_path):
    fs = drift_check.check(tmp_path)  # empty tree
    assert fs and all(f.rule == "SC203" for f in fs)
