"""SC301/SC302 mutation-injection tests.

Each mutation plants exactly the bug class the checker claims to catch —
an undeclared transition, a terminal path missing its metering settle,
a dropped Quota.release on an exception path, a resource held across a
crash-point yield — and asserts the checker flags it, alongside
positive controls proving the unmutated idiom passes."""
import textwrap
from pathlib import Path

from repro.core.states import POD, StateMachine
from repro.staticcheck import lifecycle_check, resource_check

REPO = Path(__file__).resolve().parents[1]


def _core_tree(tmp_path, name, src):
    d = tmp_path / "src" / "repro" / "core"
    d.mkdir(parents=True, exist_ok=True)
    (d / name).write_text(textwrap.dedent(src))
    return tmp_path


def _launch_tree(tmp_path, name, src):
    d = tmp_path / "src" / "repro" / "launch"
    d.mkdir(parents=True, exist_ok=True)
    (d / name).write_text(textwrap.dedent(src))
    return tmp_path


# ---------------------------------------------------------------------------
# the live repo is clean under both checkers (baseline stays empty)
# ---------------------------------------------------------------------------
def test_live_tree_sc301_clean():
    assert lifecycle_check.check() == []


def test_live_tree_sc302_clean():
    assert resource_check.check() == []


# ---------------------------------------------------------------------------
# SC301 graph model checks (mutated machines)
# ---------------------------------------------------------------------------
def _job_like(transitions, terminal=("COMPLETED", "FAILED")):
    return StateMachine(name="job", initial="SUBMITTED",
                        transitions=transitions, terminal=terminal)


def test_sc301_flags_undeclared_terminal_outedge(tmp_path):
    # mutation: COMPLETED -> DEPLOYING (a terminal that is not absorbing)
    m = _job_like((
        (None, "SUBMITTED"), ("SUBMITTED", "DEPLOYING"),
        ("DEPLOYING", "COMPLETED"), ("DEPLOYING", "FAILED"),
        ("COMPLETED", "DEPLOYING"),
    ))
    fs = lifecycle_check.check(root=tmp_path, machines=(m, POD))
    assert any("absorbing" in f.message for f in fs)


def test_sc301_flags_unreachable_and_dead_end_states(tmp_path):
    # LIMBO hangs off DEPLOYING with no way out; ORPHan is unreachable
    m = _job_like((
        (None, "SUBMITTED"), ("SUBMITTED", "DEPLOYING"),
        ("DEPLOYING", "COMPLETED"), ("DEPLOYING", "FAILED"),
        ("DEPLOYING", "LIMBO"), ("ORPHAN", "FAILED"),
    ))
    fs = lifecycle_check.check(root=tmp_path, machines=(m, POD))
    msgs = " | ".join(f.message for f in fs)
    assert "'LIMBO' is a sink but not a declared terminal" in msgs
    assert "'LIMBO' has no path to any terminal" in msgs
    assert "'ORPHAN' unreachable" in msgs


def test_sc301_declared_tables_model_check_clean(tmp_path):
    # positive control: the shipped machines pass the model check alone
    assert lifecycle_check.check(root=tmp_path) == []


# ---------------------------------------------------------------------------
# SC301 write-site routing + vocabulary (synthetic core files)
# ---------------------------------------------------------------------------
def test_sc301_flags_raw_state_write_and_bad_vocabulary(tmp_path):
    root = _core_tree(tmp_path, "rogue.py", """\
        def mark(metadata, job_id):
            metadata.update("jobs", job_id, {"state": "LIMBO"})
    """)
    fs = lifecycle_check.check(root=root)
    msgs = " | ".join(f.message for f in fs)
    assert "bypasses states.job_transition" in msgs
    assert "'LIMBO' not in the declared vocabulary" in msgs


def test_sc301_flags_raw_pod_status_assignment(tmp_path):
    root = _core_tree(tmp_path, "rogue.py", """\
        def resurrect(pod):
            pod.status = "RUNNING"
    """)
    fs = lifecycle_check.check(root=root)
    assert any("bypasses states.pod_transition" in f.message for f in fs)


def test_sc301_allows_entry_insert_and_state_echo(tmp_path):
    root = _core_tree(tmp_path, "gateway.py", """\
        from repro.core import states

        def insert(metadata, job_id, now):
            doc = {"id": job_id, "state": states.JOB.initial}
            metadata.insert("jobs", job_id, doc)

        def status_view(doc):
            return {"id": doc["id"], "state": doc["state"]}
    """)
    assert lifecycle_check.check(root=root) == []


# ---------------------------------------------------------------------------
# SC301 terminal settlement (mutation: drop the metering settle)
# ---------------------------------------------------------------------------
FINISH_OK = """\
    def _finish(platform, job_id, spec, store, update_job, state, event):
        yield from _teardown(platform, job_id, spec, store)
        yield from update_job({}, event, state="FAILED")
        platform.tenancy.metering.job_stopped(job_id, platform.sim.now)
"""


def test_sc301_settled_terminal_path_is_clean(tmp_path):
    root = _core_tree(tmp_path, "finisher.py", FINISH_OK)
    assert lifecycle_check.check(root=root) == []


def test_sc301_flags_terminal_path_missing_metering_settle(tmp_path):
    root = _core_tree(tmp_path, "finisher.py", """\
        def _finish(platform, job_id, spec, store, update_job, state, event):
            yield from _teardown(platform, job_id, spec, store)
            yield from update_job({}, event, state="FAILED")
    """)
    fs = lifecycle_check.check(root=root)
    assert any("not covered by a metering settle" in f.message for f in fs)
    assert not any("resource release" in f.message for f in fs)


def test_sc301_flags_terminal_path_missing_resource_release(tmp_path):
    root = _core_tree(tmp_path, "finisher.py", """\
        def _finish(platform, job_id, spec, store, update_job, state, event):
            yield from update_job({}, event, state="FAILED")
            platform.tenancy.metering.job_stopped(job_id, platform.sim.now)
    """)
    fs = lifecycle_check.check(root=root)
    assert any("not covered by a resource release" in f.message for f in fs)


def test_sc301_settlement_on_conditional_path_only_is_flagged(tmp_path):
    # the settle exists but only on one branch: neither dominates nor
    # post-dominates the transition
    root = _core_tree(tmp_path, "finisher.py", """\
        def _finish(platform, job_id, spec, store, update_job, ok):
            yield from _teardown(platform, job_id, spec, store)
            yield from update_job({}, "done", state="COMPLETED")
            if ok:
                platform.tenancy.metering.job_stopped(job_id, 0.0)
    """)
    fs = lifecycle_check.check(root=root)
    assert any("metering settle" in f.message for f in fs)


def test_sc301_nonterminal_constant_needs_no_settlement(tmp_path):
    root = _core_tree(tmp_path, "deployer.py", """\
        def advance(update_job):
            yield from update_job({}, "DEPLOYING", state="DEPLOYING")
    """)
    assert lifecycle_check.check(root=root) == []


# ---------------------------------------------------------------------------
# SC302: dropped Quota.release on the exception path (mutated scheduler)
# ---------------------------------------------------------------------------
def test_sc302_real_scheduler_is_clean(tmp_path):
    src = (REPO / "src/repro/core/scheduler.py").read_text()
    root = _core_tree(tmp_path, "scheduler.py", src)
    assert resource_check.check(root=root) == []


def test_sc302_flags_dropped_quota_release_on_exception_path(tmp_path):
    src = (REPO / "src/repro/core/scheduler.py").read_text()
    drop = "self.tenancy.release(tenant, n_pods * gpus_each)\n"
    assert src.count(drop) >= 1
    # mutation: admit_gang's infeasible arm raises without releasing
    mutated = src.replace(
        "                self.tenancy.release(tenant, n_pods * gpus_each)\n"
        "                raise Unschedulable(",
        "                raise Unschedulable(")
    assert mutated != src
    root = _core_tree(tmp_path, "scheduler.py", mutated)
    fs = resource_check.check(root=root)
    assert any("quota" in f.message and "exception path" in f.message
               for f in fs), [f.message for f in fs]


# ---------------------------------------------------------------------------
# SC302: gang admission crash window (held across a yield)
# ---------------------------------------------------------------------------
def test_sc302_flags_gang_held_across_yield(tmp_path):
    # mutation: the pre-fix guardian shape — a yield lands between
    # admit_gang and the gang_sizes store; a crash there strands quota
    root = _core_tree(tmp_path, "guardian.py", """\
        def proc(platform, cluster, job_id, spec, world, update_job):
            platform.scheduler.admit_gang(cluster, spec.tenant, world, 1)
            yield from update_job({"world": world}, "ELASTIC")
            platform.gang_sizes[job_id] = world
    """)
    fs = resource_check.check(root=root)
    assert any("gang" in f.message and "held across" in f.message
               for f in fs), [f.message for f in fs]


def test_sc302_gang_recorded_before_yield_is_clean(tmp_path):
    root = _core_tree(tmp_path, "guardian.py", """\
        def proc(platform, cluster, job_id, spec, world, update_job):
            platform.scheduler.admit_gang(cluster, spec.tenant, world, 1)
            platform.gang_sizes[job_id] = world
            yield from update_job({"world": world}, "ELASTIC")
    """)
    assert resource_check.check(root=root) == []


# ---------------------------------------------------------------------------
# SC302: PagePool discipline in the serving engine
# ---------------------------------------------------------------------------
def test_sc302_flags_dropped_page_free_on_early_return(tmp_path):
    # mutation: admit() bails on alloc failure without freeing the
    # refcounts it attached for the shared prefix
    root = _launch_tree(tmp_path, "engine.py", """\
        def admit(self, shared, n, shard):
            for p in shared:
                self.pool.attach(p)
            pages = self.pool.alloc(n, shard)
            if pages is None:
                return False
            self.slots[0] = shared + pages
            return True
    """)
    fs = resource_check.check(root=root)
    assert any("pages" in f.message for f in fs), [f.message for f in fs]


def test_sc302_page_free_on_early_return_is_clean(tmp_path):
    root = _launch_tree(tmp_path, "engine.py", """\
        def admit(self, shared, n, shard):
            for p in shared:
                self.pool.attach(p)
            pages = self.pool.alloc(n, shard)
            if pages is None:
                self.pool.free(shared)
                return False
            self.slots[0] = shared + pages
            return True
    """)
    assert resource_check.check(root=root) == []


# ---------------------------------------------------------------------------
# SC302: chief save-window lease (structural pair)
# ---------------------------------------------------------------------------
def test_sc302_flags_unreleased_save_lease(tmp_path):
    # mutation: the chief marks saving=True but never writes the
    # heartbeat that clears it — peers treat it as saving forever
    root = _core_tree(tmp_path, "learner.py", """\
        def chief_save(vol, sim, step, idx):
            vol.write(f"progress/{idx}", {"step": step, "t": sim.now,
                                          "saving": True})
            yield 1.0
    """)
    fs = resource_check.check(root=root)
    assert any("save_lease" in f.message for f in fs), \
        [f.message for f in fs]


def test_sc302_save_lease_released_is_clean(tmp_path):
    root = _core_tree(tmp_path, "learner.py", """\
        def chief_save(vol, sim, step, idx):
            vol.write(f"progress/{idx}", {"step": step, "t": sim.now,
                                          "saving": True})
            yield 1.0
            vol.write(f"progress/{idx}", {"step": step, "t": sim.now})
    """)
    assert resource_check.check(root=root) == []


# ---------------------------------------------------------------------------
# SC302: per-job scheduler node exclusions (self-healing reschedule repair)
# ---------------------------------------------------------------------------
def test_sc302_flags_node_exclusion_held_across_yield(tmp_path):
    # mutation: a non-provider Guardian path excludes the poisoned node,
    # then yields before anything durable records it — a crash at that
    # yield strands the exclusion with no sweep pointed at it
    root = _core_tree(tmp_path, "guardian.py", """\
        def repair(platform, job_id, node, update_job):
            platform.scheduler.exclude_node(job_id, node)
            yield from update_job({}, "REPAIR reschedule_exclude_node")
    """)
    fs = resource_check.check(root=root)
    assert any("node_exclusion" in f.message and "held across" in f.message
               for f in fs), [f.message for f in fs]


def test_sc302_flags_node_exclusion_leaked_on_exit(tmp_path):
    # mutation: an undeclared function acquires an exclusion and returns
    # still holding it — only the `_repair_exclude_node` provider may do
    # that (teardown's clear_exclusions sweep is its counterpart)
    root = _core_tree(tmp_path, "guardian.py", """\
        def quarantine(platform, job_id, node):
            platform.scheduler.exclude_node(job_id, node)
            return True
    """)
    fs = resource_check.check(root=root)
    assert any("node_exclusion" in f.message and "normal exit" in f.message
               for f in fs), [f.message for f in fs]


def test_sc302_node_exclusion_provider_and_sweep_are_clean(tmp_path):
    # positive control: the live shape — the synchronous provider exits
    # holding (declared), and the rollback sweep releases per job
    root = _core_tree(tmp_path, "guardian.py", """\
        def _repair_exclude_node(platform, job_id, node):
            platform.scheduler.exclude_node(job_id, node)

        def _rollback(platform, job_id):
            platform.scheduler.clear_exclusions(job_id)
            yield 0.0
    """)
    assert resource_check.check(root=root) == []
