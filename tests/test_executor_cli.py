"""The three launch CLIs are parse-to-spec layers over one executor
(ISSUE-3 acceptance): each builds a ``JobSpec`` and runs it through
``repro.launch.executor.execute``."""
import json

import pytest

from repro.core.jobspec import JobSpec
from repro.launch import dryrun, serve, train
from repro.launch.executor import execute


def test_train_cli_builds_and_executes_jobspec():
    spec = train.parse_spec(["--arch", "paper-overhead-100m", "--reduced",
                             "--steps", "2", "--batch", "2", "--seq", "16",
                             "--remat", "dots", "--lr", "2e-3"])
    assert isinstance(spec, JobSpec)
    assert spec.kind == "train" and spec.framework == "paper-overhead-100m"
    t = spec.train
    assert (t.total_steps, t.global_batch, t.seq_len) == (2, 2, 16)
    assert t.remat_policy == "dots" and t.learning_rate == 2e-3 and t.reduced
    assert execute(spec) == 0


def test_serve_cli_builds_jobspec():
    spec = serve.parse_spec(["--arch", "qwen3-0.6b", "--reduced",
                             "--batch", "2", "--prompt-len", "16", "--gen",
                             "6", "--continuous", "--requests", "4",
                             "--page-budget", "3"])
    assert spec.kind == "serve" and spec.framework == "qwen3-0.6b"
    sv = spec.serve
    assert (sv.batch, sv.prompt_len, sv.gen) == (2, 16, 6)
    assert sv.continuous and sv.requests == 4 and sv.page_budget == 3
    # serve.main IS execute(parse_spec(...)) — executed end-to-end by the
    # serving smoke tests in test_paged_cache.py


def test_dryrun_cli_builds_jobspec_and_executes_cached(monkeypatch, tmp_path):
    spec, args = dryrun.parse_spec(["--arch", "qwen3-0.6b",
                                    "--shape", "decode_32k"])
    assert spec.kind == "dryrun" and not args.cell_worker
    assert spec.resources.gpus_per_replica == 0
    (cell,) = spec.dryrun.cells
    assert (cell.arch, cell.shape, cell.multi_pod) == \
        ("qwen3-0.6b", "decode_32k", False)

    # executor dispatch without compiling: the cell's artifact is cached
    monkeypatch.setattr(dryrun, "ARTIFACTS", tmp_path)
    (tmp_path / "qwen3-0.6b__decode_32k__16x16.json").write_text(
        json.dumps({"ok": True}))
    assert execute(spec) == 0


def test_dryrun_cli_sweep_all_spec():
    spec, _ = dryrun.parse_spec(["--all", "--force"])
    assert spec.dryrun.sweep_all and spec.dryrun.force
    from repro.core.jobspec import resolve_cells
    cells = resolve_cells(spec.dryrun)
    assert len(cells) > 20                 # arch × shape × both meshes
    assert all(c.arch != "paper-overhead-100m" for c in cells)


def test_executor_rejects_invalid_spec():
    with pytest.raises(SystemExit, match="unknown framework"):
        execute(JobSpec(name="x", framework="not-a-framework"))
