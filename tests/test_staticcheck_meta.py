"""Tier-1 meta-test: the shipped tree passes its own static-analysis
gate, end to end through the CLI (AST rules + semantic checkers +
baseline ratchet) — the same invocation `make staticcheck` and CI run."""
import json
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def run_cli(*argv):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.staticcheck", *argv],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=600)


def test_repo_is_staticcheck_clean():
    # the full CI scan set: tests/ and benchmarks/ ride along with src/
    proc = run_cli("src", "tests", "benchmarks", "--check-baseline")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


def test_baseline_has_no_grandfathered_findings():
    # core/ and launch/ were burned to zero: the checked-in baseline must
    # stay empty, and CI's --check-baseline keeps it shrink-only
    doc = json.loads((REPO / "staticcheck_baseline.json").read_text())
    assert doc["findings"] == []


def test_cli_exits_nonzero_on_findings(tmp_path):
    bad = tmp_path / "repro" / "core" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\nt = time.time()\n")
    proc = run_cli(str(bad), "--ast-only",
                   "--baseline", str(tmp_path / "bl.json"))
    assert proc.returncode == 1
    assert "SC105" in proc.stdout


def test_cli_json_output(tmp_path):
    bad = tmp_path / "repro" / "core" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\nt = time.time()\n")
    proc = run_cli(str(bad), "--ast-only", "--json",
                   "--baseline", str(tmp_path / "bl.json"))
    assert proc.returncode == 1
    doc = json.loads(proc.stdout)
    assert [f["rule"] for f in doc] == ["SC105"]


def test_cli_baseline_roundtrip_and_ratchet(tmp_path):
    bad = tmp_path / "repro" / "core" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\nt = time.time()\n")
    bl = tmp_path / "bl.json"
    # grandfather the finding: the gate goes green without fixing it
    assert run_cli(str(bad), "--ast-only", "--baseline", str(bl),
                   "--write-baseline").returncode == 0
    assert run_cli(str(bad), "--ast-only",
                   "--baseline", str(bl)).returncode == 0
    # fix the finding: the ratchet now demands the baseline entry go too
    bad.write_text("import time\nt = time.perf_counter()\n")
    assert run_cli(str(bad), "--ast-only",
                   "--baseline", str(bl)).returncode == 0
    proc = run_cli(str(bad), "--ast-only", "--baseline", str(bl),
                   "--check-baseline")
    assert proc.returncode == 1
    assert "ratchet" in proc.stdout


def test_stale_suppression_fails_check_baseline(tmp_path):
    # an ignore marker with nothing left to suppress is itself a finding
    # under --check-baseline (and only there: plain runs stay green so
    # the fix-then-clean-up workflow isn't blocked mid-edit)
    bad = tmp_path / "repro" / "core" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\n"
                   "t = time.perf_counter()  # staticcheck: ignore[SC105]\n")
    bl = tmp_path / "bl.json"
    assert run_cli(str(bad), "--ast-only",
                   "--baseline", str(bl)).returncode == 0
    proc = run_cli(str(bad), "--ast-only", "--baseline", str(bl),
                   "--check-baseline")
    assert proc.returncode == 1
    assert "suppression ratchet" in proc.stdout
    assert "stale suppression" in proc.stdout


def test_used_suppression_is_not_stale(tmp_path):
    bad = tmp_path / "repro" / "core" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\n"
                   "t = time.time()  # staticcheck: ignore[SC105]\n")
    proc = run_cli(str(bad), "--ast-only", "--check-baseline",
                   "--baseline", str(tmp_path / "bl.json"))
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_report_flag_writes_json_artifact(tmp_path):
    bad = tmp_path / "repro" / "core" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\nt = time.time()\n")
    report = tmp_path / "artifacts" / "report.json"
    proc = run_cli(str(bad), "--ast-only", "--report", str(report),
                   "--baseline", str(tmp_path / "bl.json"))
    assert proc.returncode == 1
    doc = json.loads(report.read_text())
    assert set(doc) == {"findings", "new", "grandfathered",
                        "stale_baseline", "stale_suppressions"}
    assert [f["rule"] for f in doc["findings"]] == ["SC105"]
    assert doc["stale_suppressions"] == []
