"""Import-smoke: every ``repro.*`` module must import cleanly.

The seed's tier-1 suite once died wholesale at collection on a single
missing module (``repro.dist``).  This test walks the whole package so a
future phantom import / missing dependency fails ONE test loudly instead
of killing collection for everything.
"""
import importlib
import os
import pkgutil

import pytest

import repro

# These set XLA_FLAGS (512 fake host devices) at import for subprocess
# use; importing them here is safe (jax is already initialized) but the
# env var must be restored so later tests aren't affected.
_SETS_XLA_FLAGS = {"repro.launch.dryrun", "repro.launch.perf",
                   "repro.launch.analysis"}


def _walk(pkg):
    yield pkg.__name__
    for m in pkgutil.walk_packages(pkg.__path__, prefix=pkg.__name__ + "."):
        yield m.name


ALL_MODULES = sorted(set(_walk(repro)))


def test_module_list_is_complete():
    """The walk really covers the subsystems (guards against the package
    silently becoming a namespace package again)."""
    tops = {m.split(".")[1] for m in ALL_MODULES if m.count(".") >= 1}
    for expected in ("core", "dist", "models", "train", "optim", "launch",
                     "configs", "kernels", "data", "testing"):
        assert expected in tops, f"subsystem {expected} missing from walk"


@pytest.mark.parametrize("name", ALL_MODULES)
def test_import(name):
    saved = os.environ.get("XLA_FLAGS")
    try:
        importlib.import_module(name)
    finally:
        if name in _SETS_XLA_FLAGS:
            if saved is None:
                os.environ.pop("XLA_FLAGS", None)
            else:
                os.environ["XLA_FLAGS"] = saved


def test_dist_public_api():
    """The distribution subsystem's contract surface."""
    from repro import dist
    for sym in ("ShardingRules", "DEFAULT_RULES", "logical_to_spec",
                "make_named_sharding", "tree_shardings", "tree_shard_bytes",
                "CompressionConfig", "compress_grads", "init_error_buffers",
                "resolve_compression", "make_production_mesh",
                "make_host_mesh", "make_device_mesh", "axis_sizes"):
        assert hasattr(dist, sym), sym
