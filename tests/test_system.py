"""End-to-end system test: a REAL JAX training job (paper-overhead-100m,
reduced) runs under the full platform, is crash-injected mid-training, and
recovers from a real checkpoint with loss continuity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig, get_config
from repro.core import DLaaSPlatform, JobManifest
from repro.core.learner import RealPayload
from repro.data.pipeline import SyntheticLMData
from repro.models.layers import Ctx
from repro.train.steps import init_train_state, make_train_step


def make_payload(cfg, run):
    data = SyntheticLMData(cfg.vocab_size, 32, 4, seed=0)
    step = jax.jit(make_train_step(cfg, Ctx(dtype=jnp.float32), run))
    return RealPayload(
        make_state=lambda: init_train_state(cfg, jax.random.key(0), run),
        train_step=step, data=data)


def test_real_training_job_with_crash_and_restore():
    cfg = get_config("paper-overhead-100m").reduced()
    run = RunConfig(learning_rate=2e-3, warmup_steps=5, total_steps=60)

    p = DLaaSPlatform(seed=21)
    p.run(10)
    h = p.submit(JobManifest(name="real", learners=1, total_steps=60,
                             step_time_s=0.5, checkpoint_interval_s=10,
                             real_compute=True))
    p.run(5)
    assert h.acked
    p.register_payload(h.job_id, make_payload(cfg, run))

    # into training, then kill the learner
    p.run(40)
    vol = p.volumes.get(f"vol-{h.job_id}")
    loss_before = vol.read("last_loss")
    assert loss_before is not None
    assert p.kill_pod(f"learner-{h.job_id}-0")

    assert p.run_until_terminal(h.job_id, timeout=900) == "COMPLETED"
    logs = p.client.logs(h.job_id, 0)
    assert "restored checkpoint" in logs
    loss_after = vol.read("last_loss") if vol else None

    # compare against an uninterrupted run of the same payload
    state = init_train_state(cfg, jax.random.key(0), run)
    data = SyntheticLMData(cfg.vocab_size, 32, 4, seed=0)
    step = jax.jit(make_train_step(cfg, Ctx(dtype=jnp.float32), run))
    losses = []
    for i in range(60):
        state, m = step(state, data.batch_at(i))
        losses.append(float(m["loss"]))
    # the platform run must have trained (loss well below init ~ln(V))
    final_platform_loss = float(loss_after) if loss_after is not None else None
    assert final_platform_loss is not None
    assert final_platform_loss < losses[0]
    # and land in the vicinity of the uninterrupted trajectory's tail
    assert abs(final_platform_loss - losses[-1]) < 0.5, \
        (final_platform_loss, losses[-1])


def test_checkpoint_restore_bitexact_same_step():
    """Restoring a checkpoint and re-running from it reproduces the exact
    same parameters as never crashing (pure-function training + stateless
    data pipeline = deterministic recovery)."""
    cfg = get_config("paper-overhead-100m").reduced()
    run = RunConfig(learning_rate=1e-3, warmup_steps=2, total_steps=30)
    data = SyntheticLMData(cfg.vocab_size, 32, 4, seed=1)
    step = jax.jit(make_train_step(cfg, Ctx(dtype=jnp.float32), run))

    s = init_train_state(cfg, jax.random.key(0), run)
    for i in range(20):
        s, _ = step(s, data.batch_at(i))
    # "checkpoint" at step 10 by re-running 10 steps
    s10 = init_train_state(cfg, jax.random.key(0), run)
    for i in range(10):
        s10, _ = step(s10, data.batch_at(i))
    from repro.core import CheckpointManager, ObjectStore
    store = ObjectStore()
    ck = CheckpointManager(store, "bit")
    ck.save(10, jax.tree.map(np.asarray, s10))
    _, restored = ck.load()
    r = jax.tree.map(lambda c, n: jnp.asarray(n).astype(c.dtype), s10, restored)
    for i in range(10, 20):
        r, _ = step(r, data.batch_at(i))
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
