"""Training substrate: loss decreases, grad-accum equivalence, optimizer
math, gradient compression, data pipeline determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import RunConfig, get_config
from repro.data.pipeline import SyntheticLMData
from repro.dist.compression import compress_grads, init_error_buffers
from repro.models.layers import Ctx
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.train.steps import init_train_state, loss_fn, make_train_step


def test_loss_decreases():
    cfg = get_config("qwen3-0.6b").reduced()
    run = RunConfig(learning_rate=2e-3, warmup_steps=5, total_steps=200)
    state = init_train_state(cfg, jax.random.key(0), run)
    data = SyntheticLMData(cfg.vocab_size, 64, 8, seed=0)
    step = jax.jit(make_train_step(cfg, Ctx(dtype=jnp.float32), run))
    losses = []
    for i in range(40):
        state, m = step(state, data.batch_at(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[:3] + losses[-3:]


def test_grad_accum_equivalence():
    """mb=1 and mb=4 produce (nearly) identical parameter updates."""
    cfg = get_config("qwen3-0.6b").reduced()
    data = SyntheticLMData(cfg.vocab_size, 32, 8, seed=1)
    batch = data.batch_at(0)
    outs = {}
    for mb in (1, 4):
        run = RunConfig(num_microbatches=mb, learning_rate=1e-3,
                        warmup_steps=1, total_steps=10)
        state = init_train_state(cfg, jax.random.key(0), run)
        step = jax.jit(make_train_step(cfg, Ctx(dtype=jnp.float32), run))
        new_state, m = step(state, batch)
        outs[mb] = (new_state, float(m["loss"]))
    p1 = jax.tree.leaves(outs[1][0]["params"])
    p4 = jax.tree.leaves(outs[4][0]["params"])
    for a, b in zip(p1, p4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)
    assert abs(outs[1][1] - outs[4][1]) < 1e-3


def test_remat_grad_equivalence():
    """Activation checkpointing must not change gradients."""
    cfg = get_config("gemma2-9b").reduced()
    data = SyntheticLMData(cfg.vocab_size, 32, 4, seed=2)
    batch = data.batch_at(0)
    ctx = Ctx(dtype=jnp.float32)
    state = init_train_state(cfg, jax.random.key(0))
    grads = {}
    for policy in ("none", "full"):
        g = jax.grad(lambda p: loss_fn(cfg, p, batch, ctx, policy)[0])(
            state["params"])
        grads[policy] = g
    for a, b in zip(jax.tree.leaves(grads["none"]), jax.tree.leaves(grads["full"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


def test_adamw_against_manual():
    cfg = AdamWConfig(learning_rate=0.1, b1=0.9, b2=0.99, weight_decay=0.0,
                      warmup_steps=0, total_steps=100, min_lr_frac=1.0,
                      grad_clip_norm=1e9)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    st_ = adamw_init(p)
    new_p, st2, _ = adamw_update(cfg, g, p, st_)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mh, vh = m / 0.1, v / 0.01
    expect = 1.0 - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(float(new_p["w"][0]), expect, rtol=1e-5)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(learning_rate=1.0, warmup_steps=10, total_steps=110,
                      min_lr_frac=0.1)
    assert float(cosine_schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(cosine_schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(cosine_schedule(cfg, jnp.int32(110))) == pytest.approx(0.1)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_compression_error_feedback(seed):
    """int8 compression with error feedback: per-step quantized values plus
    the carried error reconstruct the running gradient sum exactly."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(32,)) * rng.uniform(0.01, 10),
                          jnp.float32)}
    err = init_error_buffers(g)
    total_sent = np.zeros(32)
    n = 4
    for _ in range(n):
        deq, err = compress_grads(g, err)
        total_sent += np.asarray(deq["w"])
    # cumulative(sent) + residual == cumulative(true)
    np.testing.assert_allclose(
        total_sent + np.asarray(err["w"]), n * np.asarray(g["w"]),
        rtol=1e-4, atol=1e-5)


def test_sharded_allreduce_int8_single_device():
    """On a 1-device mesh the int8 all-reduce degenerates to the pack/
    unpack round-trip: pmax of one local scale is that scale."""
    from repro.dist.compression import (
        CompressionConfig, pack_int8, sharded_allreduce_int8, unpack_int8)
    from repro.dist.mesh import make_device_mesh

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(1, 7, 11)), jnp.float32)
    cfg = CompressionConfig(chunk_size=16)
    # pin a single explicit device: other test modules force a 512-way
    # host platform via XLA_FLAGS, and data=1 over 512 devices would
    # fall back to a full-width mesh the size-1 batch can't shard over
    mesh = make_device_mesh(data=1, devices=jax.devices()[:1])
    out = sharded_allreduce_int8(x, mesh, axis="data", cfg=cfg)
    payload, scales = pack_int8(x[0], cfg)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(unpack_int8(payload, scales, (7, 11))),
        rtol=0, atol=0)


def test_sharded_allreduce_int8_multidevice():
    """4 fake host devices: the packed-wire psum (shared pmax scale, int32
    payload sum, one dequant) matches the dense fp32 psum within the
    documented ndev·scale/2 per-element bound — even with per-device
    magnitudes 100× apart, where unreconciled scales would be garbage."""
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import numpy as np
        import jax
        import jax.numpy as jnp
        from repro.dist.compression import (CompressionConfig,
                                            sharded_allreduce_int8)
        from repro.dist.mesh import make_device_mesh

        ndev = jax.device_count()
        assert ndev == 4, ndev
        rng = np.random.default_rng(11)
        # magnitudes 100x apart across devices: scale reconciliation is
        # load-bearing, not decorative
        mags = np.array([0.03, 0.5, 1.0, 3.0])[:, None, None]
        x = (rng.normal(size=(ndev, 13, 9)) * mags).astype(np.float32)
        cfg = CompressionConfig(chunk_size=16)
        mesh = make_device_mesh(data=ndev)
        out = np.asarray(sharded_allreduce_int8(
            jnp.asarray(x), mesh, axis="data", cfg=cfg))
        exact = x.sum(axis=0)

        # per-element bound from the shared chunk scales
        flat = x.reshape(ndev, -1)
        pad = (-flat.shape[1]) % cfg.chunk_size
        fp = np.pad(flat, ((0, 0), (0, pad)))
        blocks = fp.reshape(ndev, -1, cfg.chunk_size)
        scales = (np.abs(blocks).max(axis=2) / cfg.levels).max(axis=0)
        bound = np.repeat(scales, cfg.chunk_size)[:flat.shape[1]] \
            .reshape(exact.shape) * ndev / 2
        err = np.abs(out - exact)
        assert (err <= bound + 1e-6).all(), (err.max(), bound.min())
        # and the bound is doing work: int8 is lossy but close
        assert err.max() > 0
        assert np.abs(out - exact).max() / np.abs(exact).max() < 0.05
        print("OK")
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0 and "OK" in res.stdout, res.stderr[-2000:]


def test_compression_train_still_converges():
    cfg = get_config("qwen3-0.6b").reduced()
    run = RunConfig(learning_rate=2e-3, warmup_steps=5, total_steps=200)
    state = init_train_state(cfg, jax.random.key(0), run, grad_compression=True)
    data = SyntheticLMData(cfg.vocab_size, 64, 8, seed=0)
    step = jax.jit(make_train_step(cfg, Ctx(dtype=jnp.float32), run,
                                   grad_compression=True))
    losses = []
    for i in range(30):
        state, m = step(state, data.batch_at(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_data_deterministic_and_resumable():
    d = SyntheticLMData(1000, 64, 4, seed=9)
    b1, b2 = d.batch_at(17), d.batch_at(17)
    assert (b1["tokens"] == b2["tokens"]).all()
    # labels are next-token shifted
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()
    # different steps differ
    assert not (d.batch_at(18)["tokens"] == b1["tokens"]).all()


def test_data_learnable_structure():
    """Markov structure: next token is the affine map most of the time."""
    d = SyntheticLMData(1000, 256, 2, seed=3, noise=0.1)
    b = d.batch_at(0)
    t, l = np.asarray(b["tokens"]), np.asarray(b["labels"])
    frac = np.mean((31 * t + 17) % 1000 == l)
    assert frac > 0.8
