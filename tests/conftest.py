import os

# Tests run on the single real CPU device (the 512-device override is ONLY
# for the dry-run, which sets it itself before importing jax).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The CI container ships no hypothesis; fall back to the deterministic
# in-repo stub so property tests still run (see repro/testing).
from repro.testing import hypothesis_stub
hypothesis_stub.install()
