"""Raft/statestore: election safety, durability, availability — including
randomized crash schedules (hypothesis)."""
from hypothesis import given, settings, strategies as st

from repro.core.sim import Sim
from repro.core.statestore import StateStore


def boot(seed=0):
    sim = Sim(seed=seed)
    ss = StateStore(sim)
    sim.run_for(2.0)
    assert ss.leader() is not None
    return sim, ss


def put(sim, ss, key, val, timeout=5.0):
    out = {}

    def client():
        out["ok"] = yield from ss.put(key, val, timeout=timeout)
    sim.spawn(client())
    sim.run_for(timeout + 1.0)
    return out.get("ok", False)


def test_put_get():
    sim, ss = boot()
    assert put(sim, ss, "a", 1)
    assert ss.get("a") == 1


def test_write_survives_leader_crash():
    sim, ss = boot(seed=3)
    assert put(sim, ss, "k", "v")
    ldr = ss.leader()
    ss.crash_replica(ldr.idx)
    sim.run_for(2.0)
    assert ss.leader() is not None and ss.leader().idx != ldr.idx
    assert ss.get("k") == "v"


def test_unavailable_without_quorum_then_recovers():
    sim, ss = boot(seed=4)
    a = ss.leader().idx
    ss.crash_replica(a)
    sim.run_for(1.0)
    b = ss.leader().idx
    ss.crash_replica(b)
    sim.run_for(1.0)
    assert not ss.available()
    assert not put(sim, ss, "x", 1, timeout=1.0)       # stalls, times out
    ss.restart_replica(a)
    sim.run_for(3.0)
    assert put(sim, ss, "x", 2)
    assert ss.get("x") == 2


def test_restarted_replica_catches_up():
    sim, ss = boot(seed=5)
    assert put(sim, ss, "k1", 1)
    victim = (ss.leader().idx + 1) % 3
    ss.crash_replica(victim)
    assert put(sim, ss, "k2", 2)
    ss.restart_replica(victim)
    sim.run_for(2.0)                                    # heartbeats replicate
    node = ss.replicas[victim]
    assert node.kv.get("k1") == 1 and node.kv.get("k2") == 2


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       crashes=st.lists(st.tuples(st.integers(0, 2), st.floats(0.2, 3.0)),
                        max_size=4))
def test_election_safety_under_crashes(seed, crashes):
    """At most one leader is ever elected per term, whatever the crash/restart
    schedule (Raft's core safety property)."""
    sim = Sim(seed=seed)
    ss = StateStore(sim)
    for idx, when in crashes:
        sim.schedule(when, ss.crash_replica, idx)
        sim.schedule(when + 1.0, ss.restart_replica, idx)
    results = []

    def client():
        ok = yield from ss.put("key", "val", timeout=8.0)
        results.append(ok)
    sim.schedule(2.0, lambda: sim.spawn(client()))
    sim.run_for(12.0)

    hist = []
    for r in ss.replicas:
        hist.extend(r.leader_history)
    terms = [t for t, _ in hist]
    assert len(terms) == len(set(terms)), hist
    # committed writes must be durable and consistent across live replicas
    if results and results[0]:
        vals = {r.kv.get("key") for r in ss.replicas if r.alive and
                r.commit_index >= 1}
        assert vals <= {"val"}
