"""Paged flash-decode kernel + ragged prefill: op-level equivalence on
ragged page tables, the unallocated-page gather bugfix, the int8 wire
round-trip, and the serve-decode benchmark smoke."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.ops import paged_decode_bhd
from repro.kernels.paged_attention import paged_decode_jnp
from repro.models.attention import decode_attention_jnp, decode_attention_paged
from repro.models.layers import Ctx
from repro.models.model import forward, init_cache
from repro.models.params import init_params

RNG = np.random.default_rng(7)


def _pool(B, K, hd, ps, pps, pool=None):
    P = pool or B * pps
    q = jnp.asarray(RNG.normal(size=(B, 1, 2 * K, hd)), jnp.float32)
    kp = jnp.asarray(RNG.normal(size=(P, K, ps, hd)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(P, K, ps, hd)), jnp.float32)
    return q, kp, vp, P


def _ragged_tables(B, pps, P, live_pages):
    """Contiguous-prefix allocations of distinct physical pages, -1 tail."""
    table = np.full((B, pps), -1, np.int32)
    perm = RNG.permutation(P)
    used = 0
    for b, n in enumerate(live_pages):
        table[b, :n] = perm[used:used + n]
        used += n
    return jnp.asarray(table)


# ---------------------------------------------------------------------------
# Kernel ≡ reference ≡ scan fallback ≡ dense, on ragged tables
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("logit_cap", [0.0, 30.0])
def test_kernel_matches_reference_ragged(logit_cap):
    """Ragged tables (different live-page counts per row, partially filled
    last page, -1 holes in the tail) with per-sequence pos_q: the Pallas
    kernel (interpret), the lax.scan fallback, and the gather reference
    agree in fp32 on every active row."""
    B, K, hd, ps, pps = 4, 2, 16, 8, 6
    q, kp, vp, P = _pool(B, K, hd, ps, pps)
    table = _ragged_tables(B, pps, P, [3, 6, 1, 4])
    # row positions: partial last page (19 in page 2 of 3), full table,
    # single token, inactive slot
    pos = jnp.asarray([19, 47, 0, -1], jnp.int32)
    kw = dict(scale=hd ** -0.5, logit_cap=logit_cap)

    ref = decode_attention_paged(q, kp, vp, table, pos, **kw)
    ker = paged_decode_bhd(q, kp, vp, table, pos, **kw)
    H = q.shape[2]
    scan = paged_decode_jnp(q.reshape(B, K, H // K, hd), kp, vp, table, pos,
                            **kw).reshape(B, 1, H, hd)
    active = slice(0, 3)                       # row 3 is the inactive slot
    np.testing.assert_allclose(np.asarray(ker[active]),
                               np.asarray(ref[active]), atol=2e-6)
    np.testing.assert_allclose(np.asarray(scan[active]),
                               np.asarray(ref[active]), atol=2e-6)
    # inactive rows: the kernel/scan contract is zeros (ignored by callers)
    assert float(jnp.abs(ker[3]).max()) == 0.0
    assert float(jnp.abs(scan[3]).max()) == 0.0


@pytest.mark.parametrize("logit_cap", [0.0, 30.0])
def test_grouped_kernel_matches_ungrouped_and_oracle(logit_cap):
    """The grouped (head-tiled, one-MXU-call-per-page) variant ≡ the
    per-kv-head grid ≡ the scan fallback ≡ the gather oracle, on the same
    ragged tables.  G sweeps both sides of the old ``G <= 4`` auto-cap
    (since removed — grouped is the default for every G) plus the
    non-divisor boundary G=5, where the head tile clamps to kt=1."""
    from repro.kernels.paged_attention import paged_decode_attention

    for G in (1, 2, 4, 5, 8):
        B, K, hd, ps, pps = 4, 2, 16, 8, 6
        P = B * pps
        q = jnp.asarray(RNG.normal(size=(B, K, G, hd)), jnp.float32)
        kp = jnp.asarray(RNG.normal(size=(P, K, ps, hd)), jnp.float32)
        vp = jnp.asarray(RNG.normal(size=(P, K, ps, hd)), jnp.float32)
        table = _ragged_tables(B, pps, P, [3, 6, 1, 4])
        pos = jnp.asarray([19, 47, 0, -1], jnp.int32)
        kw = dict(scale=hd ** -0.5, logit_cap=logit_cap)

        grp = paged_decode_attention(q, kp, vp, table, pos, interpret=True,
                                     grouped=True, **kw)
        ung = paged_decode_attention(q, kp, vp, table, pos, interpret=True,
                                     grouped=False, **kw)
        scan = paged_decode_jnp(q, kp, vp, table, pos, **kw)
        ref = decode_attention_paged(
            q.reshape(B, 1, K * G, hd), kp, vp, table, pos,
            **kw).reshape(B, K, G, hd)
        act = slice(0, 3)                      # row 3 is the inactive slot
        np.testing.assert_allclose(np.asarray(grp[act]), np.asarray(ung[act]),
                                   atol=2e-6)
        np.testing.assert_allclose(np.asarray(grp[act]),
                                   np.asarray(scan[act]), atol=2e-6)
        np.testing.assert_allclose(np.asarray(grp[act]), np.asarray(ref[act]),
                                   atol=2e-6)
        assert float(jnp.abs(grp[3]).max()) == 0.0  # inactive row → zeros


def test_group_tile_and_default_grouped():
    """The head tiler returns the largest divisor of K whose fused block
    stays within the MXU budget, and ``grouped=None`` now defaults to the
    grouped grid for every G (the old ``G <= 4`` auto-cap is gone)."""
    from repro.kernels.paged_attention import group_tile, paged_decode_attention

    assert group_tile(2, 2) == 2      # whole K fuses: 2·2 ≤ 8
    assert group_tile(8, 1) == 8
    assert group_tile(4, 4) == 2      # 4·4 > 8 → tile at 2
    assert group_tile(2, 5) == 1      # 2·5 > 8 → per-head
    assert group_tile(2, 8) == 1      # G > budget: one head per tile
    assert group_tile(3, 4) == 1      # non-divisor G, prime-ish K

    B, K, G, hd, ps, pps = 2, 2, 8, 16, 8, 3
    P = B * pps
    q = jnp.asarray(RNG.normal(size=(B, K, G, hd)), jnp.float32)
    kp = jnp.asarray(RNG.normal(size=(P, K, ps, hd)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(P, K, ps, hd)), jnp.float32)
    table = _ragged_tables(B, pps, P, [2, 3])
    pos = jnp.asarray([10, 23], jnp.int32)
    kw = dict(scale=hd ** -0.5, logit_cap=0.0)
    auto = paged_decode_attention(q, kp, vp, table, pos, interpret=True, **kw)
    grp = paged_decode_attention(q, kp, vp, table, pos, interpret=True,
                                 grouped=True, **kw)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(grp))


def test_mla_kernel_matches_scan_and_oracle():
    """MLA latent flash-decode on ragged tables: the Pallas kernel
    (interpret), the lax.scan fallback, and a dense gather oracle agree —
    scores over concat(ckv, k_rope) latents, values = ckv, inactive rows
    zero."""
    from repro.kernels.ops import mla_paged_decode_bhd
    from repro.kernels.paged_attention import (
        mla_paged_decode_attention, mla_paged_decode_jnp)

    B, H, lora, rd, ps, pps = 4, 3, 16, 8, 8, 6
    P = B * pps
    q = jnp.asarray(RNG.normal(size=(B, H, lora + rd)), jnp.float32)
    ckv = jnp.asarray(RNG.normal(size=(P, ps, lora)), jnp.float32)
    krope = jnp.asarray(RNG.normal(size=(P, ps, rd)), jnp.float32)
    table = _ragged_tables(B, pps, P, [3, 6, 1, 4])
    pos = jnp.asarray([19, 47, 0, -1], jnp.int32)
    scale = (lora + rd) ** -0.5

    ker = mla_paged_decode_attention(q, ckv, krope, table, pos, scale=scale,
                                     interpret=True)
    scan = mla_paged_decode_jnp(q, ckv, krope, table, pos, scale=scale)
    ops = mla_paged_decode_bhd(q, ckv, krope, table, pos, scale=scale)

    # dense oracle: gather each row's live tokens, full softmax in fp64
    tnp, pnp = np.asarray(table), np.asarray(pos)
    qn = np.asarray(q, np.float64)
    oracle = np.zeros((B, H, lora))
    for b in range(B):
        if pnp[b] < 0:
            continue
        ks, vs = [], []
        for t in range(pnp[b] + 1):
            page = tnp[b, t // ps]
            assert page >= 0
            ks.append(np.concatenate([np.asarray(ckv[page, t % ps]),
                                      np.asarray(krope[page, t % ps])]))
            vs.append(np.asarray(ckv[page, t % ps]))
        kmat, vmat = np.stack(ks), np.stack(vs)          # (T, lora+rd/lora)
        s = qn[b] @ kmat.T * scale                       # (H, T)
        p = np.exp(s - s.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        oracle[b] = p @ vmat
    act = slice(0, 3)
    np.testing.assert_allclose(np.asarray(ker[act]), oracle[act], atol=2e-6)
    np.testing.assert_allclose(np.asarray(scan[act]), oracle[act], atol=2e-6)
    np.testing.assert_allclose(np.asarray(ops[act]), oracle[act], atol=2e-6)
    assert float(jnp.abs(ker[3]).max()) == 0.0
    assert float(jnp.abs(scan[3]).max()) == 0.0


def test_kernel_matches_dense_layout():
    """Paged walks ≡ the dense cache layout: pack the same K/V into a
    dense (B, K, T, hd) buffer and into pages, same masked softmax."""
    B, K, hd, ps, pps = 3, 2, 16, 8, 4
    T = pps * ps
    q, kp, vp, P = _pool(B, K, hd, ps, pps)
    live = [4, 2, 3]
    table = _ragged_tables(B, pps, P, live)
    pos = jnp.asarray([T - 1, 11, 17], jnp.int32)
    scale = hd ** -0.5

    # scatter pages into the dense layout
    kd = np.zeros((B, K, T, hd), np.float32)
    vd = np.zeros((B, K, T, hd), np.float32)
    tnp = np.asarray(table)
    for b in range(B):
        for i in range(pps):
            if tnp[b, i] >= 0:
                kd[b, :, i * ps:(i + 1) * ps] = np.asarray(kp[tnp[b, i]])
                vd[b, :, i * ps:(i + 1) * ps] = np.asarray(vp[tnp[b, i]])
    pos_k = np.broadcast_to(np.arange(T, dtype=np.int32), (B, T)).copy()
    for b in range(B):
        pos_k[b, np.repeat(tnp[b] < 0, ps)] = -1

    dense = decode_attention_jnp(q, jnp.asarray(kd), jnp.asarray(vd),
                                 jnp.asarray(pos_k), pos, scale=scale)
    ker = paged_decode_bhd(q, kp, vp, table, pos, scale=scale)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(dense), atol=2e-6)


def test_unallocated_pages_never_gathered():
    """Bugfix: the old reference clamped -1 table entries to 0 and gathered
    physical page 0 for every hole.  Poison page 0 with NaN and keep it
    out of every table: no walk may touch it."""
    B, K, hd, ps, pps = 2, 2, 16, 8, 4
    q, kp, vp, P = _pool(B, K, hd, ps, pps)
    kp = kp.at[0].set(jnp.nan)
    vp = vp.at[0].set(jnp.nan)
    table = np.full((B, pps), -1, np.int32)
    table[0, :2] = [3, 5]                      # page 0 unused everywhere
    table[1, :1] = [7]
    table = jnp.asarray(table)
    pos = jnp.asarray([12, 4], jnp.int32)
    kw = dict(scale=hd ** -0.5)
    for out in (decode_attention_paged(q, kp, vp, table, pos, **kw),
                paged_decode_bhd(q, kp, vp, table, pos, **kw)):
        assert not bool(jnp.isnan(out).any()), "page 0 leaked into the walk"


def test_aliased_prefix_pages_match_dealiased_oracle():
    """Prefix caching aliases ONE physical page into many rows' tables.
    Every decode walk reads K/V pages without mutation, so rows sharing
    physical prefix pages must produce bitwise the same output as rows
    reading private de-aliased copies of those pages — for the gather
    reference, the scan fallback, and the Pallas kernel (interpret)."""
    from repro.kernels.paged_attention import paged_decode_attention

    B, K, hd, ps, pps = 3, 2, 16, 8, 5
    q, kp, vp, _ = _pool(B, K, hd, ps, pps, pool=8)
    # pages 0,1 are the shared prefix in every row; private tails differ
    aliased = jnp.asarray([[0, 1, 2, 3, -1],
                           [0, 1, 4, -1, -1],
                           [0, 1, 5, 6, 7]], jnp.int32)
    # oracle pool: rows 1 and 2 get their own verbatim copies at 8..11
    kp2 = jnp.concatenate([kp, kp[jnp.asarray([0, 1, 0, 1])]], axis=0)
    vp2 = jnp.concatenate([vp, vp[jnp.asarray([0, 1, 0, 1])]], axis=0)
    dealiased = jnp.asarray([[0, 1, 2, 3, -1],
                             [8, 9, 4, -1, -1],
                             [10, 11, 5, 6, 7]], jnp.int32)
    pos = jnp.asarray([27, 20, 39], jnp.int32)
    kw = dict(scale=hd ** -0.5)

    for fn in (
        lambda k_, v_, t: decode_attention_paged(q, k_, v_, t, pos, **kw),
        lambda k_, v_, t: paged_decode_jnp(
            q.reshape(B, K, 2, hd), k_, v_, t, pos, **kw),
        lambda k_, v_, t: paged_decode_attention(
            q.reshape(B, K, 2, hd), k_, v_, t, pos, interpret=True, **kw),
    ):
        shared = fn(kp, vp, aliased)
        oracle = fn(kp2, vp2, dealiased)
        np.testing.assert_array_equal(np.asarray(shared), np.asarray(oracle))


# ---------------------------------------------------------------------------
# Ragged prefill
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen3-0.6b", "gemma2-9b",
                                  "recurrentgemma-9b", "rwkv6-7b",
                                  "deepseek-v2-236b"])
def test_ragged_prefill_matches_padded(arch):
    """One batched ragged prefill (prompts padded to the batch max, per-row
    lengths) must produce, per row, the same last-token logits as prefilling
    that row alone at its exact length — and identical follow-on decode.
    Covers attention (paged writes masked per row), MLA (latent scatter),
    and recurrent/RWKV stacks (length-masked carries)."""
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              cache_layout="paged")
    ctx = Ctx(dtype=jnp.float32)
    params = init_params(cfg, jax.random.key(0))
    B, S = 3, 40
    lens = [28, 17, 9]
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)

    cache = init_cache(cfg, B, S)
    padded = jnp.where(jnp.arange(max(lens))[None, :] <
                       jnp.asarray(lens)[:, None],
                       toks[:, :max(lens)], 0)
    rag_logits, rag_cache, _ = forward(
        cfg, params, {"tokens": padded}, ctx, mode="prefill", cache=cache,
        lengths=jnp.asarray(lens, jnp.int32))

    for b, L in enumerate(lens):
        solo_cache = init_cache(cfg, 1, S)
        solo_logits, _, _ = forward(
            cfg, params, {"tokens": toks[b:b + 1, :L]}, ctx,
            mode="prefill", cache=solo_cache)
        err = float(jnp.abs(rag_logits[b] - solo_logits[0]).max())
        assert err < 1e-4, (arch, b, err)

    # follow-on decode at per-row positions stays consistent with a
    # lockstep decode of row 0 alone
    solo_cache = init_cache(cfg, 1, S)
    _, solo_cache, _ = forward(cfg, params, {"tokens": toks[:1, :lens[0]]},
                               ctx, mode="prefill", cache=solo_cache)
    tok = toks[:, lens[0]:lens[0] + 1]
    pos = jnp.asarray([lens[0], -1, -1], jnp.int32)
    d_rag, _, _ = forward(cfg, params, {"tokens": tok}, ctx, mode="decode",
                          cache=rag_cache, pos=pos)
    d_solo, _, _ = forward(cfg, params, {"tokens": tok[:1]}, ctx,
                           mode="decode", cache=solo_cache,
                           pos=jnp.asarray([lens[0]], jnp.int32))
    err = float(jnp.abs(d_rag[0] - d_solo[0]).max())
    assert err < 1e-4, (arch, err)


@pytest.mark.parametrize("arch", ["gemma2-9b", "recurrentgemma-9b",
                                  "rwkv6-7b", "deepseek-v2-236b"])
def test_ragged_prefill_preserves_other_rows(arch):
    """Length-0 rows (continuous-batching slots mid-decode) must come out
    of a ragged prefill byte-identical — the padded batch writes nothing
    through their page tables, ring buffers, latent pools, or recurrent
    carries."""
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              cache_layout="paged")
    ctx = Ctx(dtype=jnp.float32)
    params = init_params(cfg, jax.random.key(0))
    B, S = 2, 32
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    cache = init_cache(cfg, B, S)
    _, cache, _ = forward(cfg, params, {"tokens": toks}, ctx,
                          mode="prefill", cache=cache)
    before = jax.tree.map(lambda x: np.asarray(x).copy(), cache)
    # ragged prefill that touches only... nobody (both rows length 0)
    _, after, _ = forward(cfg, params, {"tokens": toks[:, :8]}, ctx,
                          mode="prefill", cache=cache,
                          lengths=jnp.zeros((B,), jnp.int32))
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_continuous_pallas_smoke():
    """End-to-end: continuous batching decoding through the interpret-mode
    Pallas kernel with ragged batched prefill."""
    from repro.launch import serve
    assert serve.main(["--reduced", "--batch", "2", "--prompt-len", "16",
                       "--gen", "4", "--continuous", "--requests", "3",
                       "--use-pallas"]) == 0


# ---------------------------------------------------------------------------
# int8 wire packing (dist.compression satellite)
# ---------------------------------------------------------------------------
def test_int8_pack_roundtrip():
    from repro.dist.compression import (
        CompressionConfig, _int8_leaf, pack_int8, unpack_int8,
        wire_bytes_int8)
    t = jnp.asarray(RNG.normal(size=(13, 29)) *
                    np.exp(3 * RNG.normal(size=(13, 29))), jnp.float32)
    # per-tensor packing reproduces the historical values path exactly
    cfg = CompressionConfig()
    payload, scales = pack_int8(t, cfg)
    assert payload.dtype == jnp.int8 and scales.shape == (1,)
    rt = unpack_int8(payload, scales, t.shape)
    np.testing.assert_array_equal(np.asarray(rt), np.asarray(_int8_leaf(t, cfg)))
    # per-chunk scales: tighter than per-tensor on heavy-tailed data,
    # odd sizes pad the payload, wire accounting matches
    cfgc = CompressionConfig(chunk_size=64)
    pc, sc = pack_int8(t, cfgc)
    assert pc.size == -(-t.size // 64) * 64
    assert sc.shape == (-(-t.size // 64),)
    assert wire_bytes_int8(t, cfgc) == pc.size + 4 * sc.size
    rtc = unpack_int8(pc, sc, t.shape)
    assert float(jnp.abs(rtc - t).mean()) < float(jnp.abs(rt - t).mean())
    # zero tensors ship scale 0 and decode to exact zeros
    pz, sz = pack_int8(jnp.zeros((5,)), cfgc)
    np.testing.assert_array_equal(np.asarray(unpack_int8(pz, sz, (5,))),
                                  np.zeros(5, np.float32))


def test_int8_error_feedback_still_exact():
    """Cumulative transmitted gradient stays exact through the *packed*
    wire path (error feedback carries the quantization residual)."""
    from repro.dist.compression import CompressionConfig, compress_grads
    cfg = CompressionConfig(chunk_size=32)
    g = {"w": jnp.asarray(RNG.normal(size=(50,)), jnp.float32)}
    err = {"w": jnp.zeros((50,), jnp.float32)}
    total_sent = jnp.zeros((50,))
    for _ in range(6):
        sent, err = compress_grads(g, err, cfg)
        total_sent = total_sent + sent["w"]
    target = 6 * g["w"]
    np.testing.assert_allclose(np.asarray(total_sent + err["w"]),
                               np.asarray(target), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Benchmark lane smoke (make bench-smoke / CI)
# ---------------------------------------------------------------------------
def test_serve_decode_bench_smoke():
    from benchmarks import serve_decode
    assert serve_decode.main(["--smoke", "--no-write"]) == 0


def test_decode_attn_bytes_pricing():
    """Reference pricing is occupancy-flat (table-bounded); kernel pricing
    scales with resident pages — 4x at 25% occupancy."""
    from repro.configs import SHAPES, RunConfig, get_config
    from repro.launch.specs import decode_attn_bytes
    cfg = dataclasses.replace(get_config("qwen3-0.6b"), cache_layout="paged")
    sh = SHAPES["decode_32k"]
    full = RunConfig(page_occupancy=1.0)
    quarter = RunConfig(page_occupancy=0.25)
    ref_f = decode_attn_bytes(cfg, sh, full, "reference")
    ref_q = decode_attn_bytes(cfg, sh, quarter, "reference")
    kern_q = decode_attn_bytes(cfg, sh, quarter, "kernel")
    assert ref_f == ref_q
    assert ref_q >= 4 * kern_q
    assert decode_attn_bytes(cfg, sh, full, "kernel") == ref_f
