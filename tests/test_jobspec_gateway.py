"""Job API v2 gateway — the ISSUE-3 redesign, each guarantee tested.

Covers: idempotent resubmission (including across an API-pod kill
mid-submit), metadata-backed id allocation surviving API restarts and
platform co-residency, list filtering + pagination, serve/dryrun kinds as
first-class platform jobs (quota, metering, halt), uniform not-found
semantics, registry-backed validation at submission, the v1 manifest shim,
and the satellite fixes (NetworkPolicy prefix anchoring, in-flight
metering)."""
import pytest

from repro.core import (
    DLaaSPlatform, DryRunSpec, InvalidJobState, JobManifest, JobNotFound,
    JobSpec, Resources, ServeSpec, SweepCell, TrainSpec,
)
from repro.core.tenancy import Metering, NetworkPolicy


def boot(seed=0, **kw):
    p = DLaaSPlatform(seed=seed, **kw)
    p.run(10)            # core services come up
    return p


def submit(p, spec, request_id=None, run=5):
    h = p.submit(spec, request_id=request_id)
    p.run(run)
    assert h.acked and h.job_id, h.rejected
    return h


def train_spec(name="job", **train_kw):
    res = train_kw.pop("resources", Resources(1, 1))
    train_kw.setdefault("step_time_s", 0.2)
    train_kw.setdefault("total_steps", 10)
    return JobSpec(name=name, kind="train", resources=res,
                   train=TrainSpec(**train_kw))


# ---------------------------------------------------------------------------
# Idempotent submission
# ---------------------------------------------------------------------------
def test_resubmit_same_request_id_returns_same_job():
    p = boot(seed=1)
    h1 = submit(p, train_spec(), request_id="rid-A")
    h2 = submit(p, train_spec(), request_id="rid-A")
    assert h2.job_id == h1.job_id and h2.deduplicated
    docs = p.metadata.find("jobs", lambda d: d.get("request_id") == "rid-A")
    assert len(docs) == 1


def test_resubmit_after_api_pod_crash_no_duplicate():
    """The acceptance scenario: ack lands, every API pod dies, the client
    resubmits the same request_id — same job id, one job document."""
    p = boot(seed=2)
    h1 = submit(p, train_spec(total_steps=50))
    for pod in ("api-0", "api-1"):
        p.kill_pod(pod)
    p.run(10)                              # deployment restarts replicas
    h2 = submit(p, train_spec(total_steps=50), request_id=h1.request_id)
    assert h2.job_id == h1.job_id and h2.deduplicated
    docs = p.metadata.find(
        "jobs", lambda d: d.get("request_id") == h1.request_id)
    assert len(docs) == 1


def test_resubmit_across_api_kill_mid_submit():
    """Kill the API pod while it is mid-submit (wedged retrying against a
    down metadata store, ack not yet produced): the client's resubmission
    must yield exactly one job."""
    p = boot(seed=3)
    p.metadata.crash()
    h1 = p.submit(train_spec(), request_id="rid-B")
    p.run(2)                               # popped from the queue, unacked
    assert not h1.acked
    for pod in ("api-0", "api-1"):
        p.kill_pod(pod)                    # in-flight submission dies
    p.metadata.restart()
    p.run(10)
    h2 = submit(p, train_spec(), request_id="rid-B", run=10)
    docs = p.metadata.find("jobs", lambda d: d.get("request_id") == "rid-B")
    assert len(docs) == 1
    assert docs[0]["id"] == h2.job_id


# ---------------------------------------------------------------------------
# Metadata-backed job-id allocation
# ---------------------------------------------------------------------------
def test_job_ids_do_not_bleed_across_platforms():
    """The old module-global counter made a second platform in the same
    process start at job-0002; ids now come from each platform's own
    metadata store."""
    p1, p2 = boot(seed=4), boot(seed=5)
    h1 = submit(p1, train_spec())
    h2 = submit(p2, train_spec())
    assert h1.job_id == "job-0001"
    assert h2.job_id == "job-0001"


def test_job_ids_survive_api_pod_restart():
    p = boot(seed=6)
    h1 = submit(p, train_spec())
    for pod in ("api-0", "api-1"):
        p.kill_pod(pod)
    p.run(10)
    h2 = submit(p, train_spec())
    assert h2.job_id != h1.job_id          # no collision after restart
    assert h2.job_id > h1.job_id           # counter never rewinds


# ---------------------------------------------------------------------------
# list: filtering + pagination
# ---------------------------------------------------------------------------
def test_list_filters_and_paginates():
    p = boot(seed=7)
    p.tenancy.add_tenant("acme", gpu_quota=64)
    for i in range(3):
        submit(p, train_spec(name=f"t{i}"), run=2)
    sv = JobSpec(name="sv", kind="serve", tenant="acme",
                 framework="qwen3-0.6b",
                 serve=ServeSpec(requests=0, request_time_s=0.2))
    hs = submit(p, sv, run=2)
    p.run(5)

    jobs, _ = p.client.list(kind="serve")
    assert [j["id"] for j in jobs] == [hs.job_id]
    jobs, _ = p.client.list(tenant="acme")
    assert [j["id"] for j in jobs] == [hs.job_id]
    assert p.client.list(state="COMPLETED")[0] == []

    # paginate in pages of 2 over all four jobs; no dupes, full coverage
    seen, token = [], None
    while True:
        page, token = p.client.list(limit=2, page_token=token)
        seen += [j["id"] for j in page]
        if token is None:
            break
    assert len(seen) == len(set(seen)) == 4


# ---------------------------------------------------------------------------
# serve + dryrun kinds are first-class platform jobs
# ---------------------------------------------------------------------------
def test_serve_job_quota_metering_completion():
    """Acceptance: a serve-kind job submitted via ApiClient reaches a
    terminal state with quota reserved and GPU-seconds metered."""
    p = boot(seed=8)
    spec = JobSpec(name="sv", kind="serve", framework="qwen3-0.6b",
                   resources=Resources(replicas=2, gpus_per_replica=2),
                   serve=ServeSpec(requests=200, request_time_s=0.2))
    h = submit(p, spec)
    p.run(15)                              # servers deployed and serving
    assert p.client.get(h.job_id)["kind"] == "serve"
    assert p.tenancy.allocated.get("default", 0) == 4      # quota reserved
    mid = p.client.gpu_seconds("default")
    assert mid > 0                         # in-flight metering (satellite)
    assert p.run_until_terminal(h.job_id, timeout=600) == "COMPLETED"
    assert p.client.gpu_seconds("default") >= mid
    assert p.tenancy.allocated.get("default", 0) == 0      # released
    assert p.volumes.active() == []
    assert "server 0" in p.client.logs(h.job_id, 0)


def test_serve_job_halt_and_server_restart():
    p = boot(seed=9)
    spec = JobSpec(name="svc", kind="serve", framework="qwen3-0.6b",
                   serve=ServeSpec(requests=0))   # serve until halted
    h = submit(p, spec)
    p.run(30)
    assert p.client.get(h.job_id)["state"] == "PROCESSING"
    assert p.kill_pod(f"server-{h.job_id}-0")     # replica recreated in place
    p.run(30)
    assert p.client.get(h.job_id)["restarts"] >= 1
    p.client.halt(h.job_id)
    assert p.run_until_terminal(h.job_id, timeout=300) == "HALTED"
    assert p.tenancy.allocated.get("default", 0) == 0
    assert p.volumes.active() == []


def test_serve_job_honors_tenant_quota():
    p = boot(seed=10)
    p.tenancy.add_tenant("small", gpu_quota=2)
    spec = JobSpec(name="big-serve", kind="serve", tenant="small",
                   framework="qwen3-0.6b",
                   resources=Resources(replicas=4, gpus_per_replica=1),
                   serve=ServeSpec(requests=10))
    h = submit(p, spec)
    assert p.run_until_terminal(h.job_id, timeout=300) == "FAILED"
    assert p.tenancy.allocated.get("small", 0) == 0


def test_dryrun_job_publishes_artifacts():
    p = boot(seed=11)
    spec = JobSpec(name="sweep", kind="dryrun",
                   resources=Resources(replicas=1, gpus_per_replica=0),
                   dryrun=DryRunSpec(cells=(
                       SweepCell("qwen3-0.6b", "decode_32k"),
                       SweepCell("gemma2-9b", "train_4k", multi_pod=True))))
    h = submit(p, spec)
    assert p.run_until_terminal(h.job_id, timeout=300) == "COMPLETED"
    keys = p.objectstore.list_prefix(f"cos/{h.job_id}/dryrun/")
    assert keys == [
        f"cos/{h.job_id}/dryrun/gemma2-9b__train_4k__2x16x16.json",
        f"cos/{h.job_id}/dryrun/qwen3-0.6b__decode_32k__16x16.json"]
    assert p.volumes.active() == []


# ---------------------------------------------------------------------------
# Validation at the gateway
# ---------------------------------------------------------------------------
def test_unknown_framework_rejected_at_submission():
    p = boot(seed=12)
    h = p.submit(JobSpec(name="bad", framework="caffe-nope"))
    p.run(3)
    assert h.rejected and "unknown framework" in h.rejected
    assert not h.acked
    assert p.metadata.find("jobs", lambda d: True) == []


@pytest.mark.parametrize("spec, needle", [
    (JobSpec(name="s", train=TrainSpec(total_steps=0)), "total_steps"),
    (JobSpec(name="s", max_restarts=-1), "max_restarts"),
    (JobSpec(name="s", resources=Resources(replicas=0)), "replicas"),
    (JobSpec(name="s", kind="serve", serve=ServeSpec(gen=0)), "gen"),
    (JobSpec(name="s", kind="dryrun"), "cells"),
    (JobSpec(name="s", kind="serve",
             serve=ServeSpec(continuous=True, cache_layout="dense")),
     "paged"),
    (JobSpec(name="s", kind="train", serve=ServeSpec(batch=8)),
     "spec block"),        # mismatched block must be rejected, not ignored
])
def test_invalid_specs_rejected(spec, needle):
    p = boot(seed=13)
    h = p.submit(spec)
    p.run(3)
    assert h.rejected and needle in h.rejected, h.rejected


# ---------------------------------------------------------------------------
# Uniform verb semantics
# ---------------------------------------------------------------------------
def test_uniform_not_found_semantics():
    p = boot(seed=14)
    for call in (p.client.get, p.client.events, p.client.logs,
                 p.client.halt, p.client.delete):
        with pytest.raises(JobNotFound):
            call("job-9999")


def test_delete_terminal_only():
    p = boot(seed=15)
    h = submit(p, train_spec(total_steps=2000))
    p.run(10)
    with pytest.raises(InvalidJobState):
        p.client.delete(h.job_id)          # still running
    p.client.halt(h.job_id)
    assert p.run_until_terminal(h.job_id, timeout=300) == "HALTED"
    p.client.delete(h.job_id)
    with pytest.raises(JobNotFound):
        p.client.get(h.job_id)


# ---------------------------------------------------------------------------
# v1 manifest shim
# ---------------------------------------------------------------------------
def test_manifest_to_jobspec_equivalence():
    m = JobManifest(name="legacy", tenant="default", framework="gemma2-9b",
                    learners=3, gpus_per_learner=2, total_steps=77,
                    step_time_s=0.3, checkpoint_interval_s=9.0,
                    max_restarts=5, elastic=True, priority=2,
                    dataset_gb=2.5, real_compute=False, seed=7,
                    extras={"recovery_mode": "rejoin"})
    s = m.to_jobspec()
    assert s.kind == "train" and s.framework == m.framework
    assert (s.learners, s.gpus_per_learner) == (3, 2)
    assert s.total_steps == 77 and s.step_time_s == 0.3
    assert s.checkpoint_interval_s == 9.0 and s.max_restarts == 5
    assert s.elastic and s.priority == 2 and s.seed == 7
    assert s.dataset_gb == 2.5 and s.recovery_mode == "rejoin"
    # doc round-trip is lossless (what Mongo stores is what the LCM reads)
    assert JobSpec.from_doc(s.to_doc()) == s


def test_manifest_and_spec_submissions_equivalent():
    """A v1 manifest and its converted spec must produce identical job
    documents (modulo ids/timestamps) and identical outcomes."""
    m = JobManifest(name="eq", learners=2, total_steps=15, step_time_s=0.2)
    p = boot(seed=16)
    h1 = submit(p, m)
    h2 = submit(p, m.to_jobspec())
    assert p.run_until_terminal(h1.job_id, timeout=600) == "COMPLETED"
    assert p.run_until_terminal(h2.job_id, timeout=600) == "COMPLETED"
    d1 = p.metadata.get("jobs", h1.job_id)
    d2 = p.metadata.get("jobs", h2.job_id)
    assert d1["spec"] == d2["spec"]
    assert d1["kind"] == d2["kind"] == "train"


def test_legacy_v1_job_documents_still_reconcile():
    """Job docs persisted before the redesign carry ``manifest`` instead of
    ``spec`` — the LCM must still run them (upgrade path)."""
    from dataclasses import asdict
    p = boot(seed=17)
    m = JobManifest(name="old-doc", learners=1, total_steps=10,
                    step_time_s=0.2)
    doc = {"id": "job-legacy", "manifest": asdict(m), "state": "SUBMITTED",
           "desired_state": "RUNNING", "restarts": 0,
           "events": [{"t": p.sim.now, "event": "SUBMITTED"}]}
    p.metadata.insert("jobs", "job-legacy", doc)
    assert p.run_until_terminal("job-legacy", timeout=300) == "COMPLETED"


# ---------------------------------------------------------------------------
# Satellite fixes
# ---------------------------------------------------------------------------
def test_network_policy_prefix_anchored():
    """job-001 must not reach into cos/job-0010/... (prefix confusion)."""
    labels = {"role": "learner", "job": "job-001", "tenant": "t1"}
    assert NetworkPolicy.allowed(labels, "cos/job-001/logs/0")
    assert NetworkPolicy.allowed(labels, "cos/job-001")
    assert not NetworkPolicy.allowed(labels, "cos/job-0010/logs/0")
    assert not NetworkPolicy.allowed(labels, "cos/job-0010")
    assert NetworkPolicy.allowed(labels, "cos/datasets/imagenet")
    assert not NetworkPolicy.allowed(labels, "cos/datasets-private/x")
    # server and dryrun pods are workload roles, equally restricted
    for role in ("server", "dryrun"):
        lbl = {"role": role, "job": "job-001"}
        assert not NetworkPolicy.allowed(lbl, "mongo")
        assert not NetworkPolicy.allowed(lbl, "cos/job-0010/x")
        assert NetworkPolicy.allowed(lbl, "cos/job-001/x")


def test_dedup_is_tenant_scoped():
    """Tenant B reusing tenant A's request_id must get its OWN job, never
    a handle onto A's job."""
    p = boot(seed=21)
    p.tenancy.add_tenant("acme", gpu_quota=64)
    a = train_spec(name="a")
    b = JobSpec(name="b", kind="train", tenant="acme",
                resources=Resources(1, 1),
                train=TrainSpec(step_time_s=0.2, total_steps=10))
    ha = submit(p, a, request_id="retry-1")
    hb = submit(p, b, request_id="retry-1")
    assert hb.job_id != ha.job_id and not hb.deduplicated
    # same tenant + same rid still dedups
    ha2 = submit(p, a, request_id="retry-1")
    assert ha2.job_id == ha.job_id and ha2.deduplicated


def test_serve_gang_serves_exactly_requests():
    """Claim-then-serve: a 3-replica gang must serve exactly ``requests``,
    not overshoot by stale reads of the shared counter."""
    import re
    p = boot(seed=22)
    spec = JobSpec(name="exact", kind="serve", framework="qwen3-0.6b",
                   resources=Resources(replicas=3, gpus_per_replica=1),
                   serve=ServeSpec(requests=10, request_time_s=0.3))
    h = submit(p, spec)
    assert p.run_until_terminal(h.job_id, timeout=600) == "COMPLETED"
    logs = "".join(p.client.logs(h.job_id, i) for i in range(3))
    totals = [int(m) for m in re.findall(r"\((\d+) served\)", logs)]
    assert totals and max(totals) == 10, totals


def test_list_limit_zero_is_empty_not_crash():
    p = boot(seed=18)
    submit(p, train_spec())
    assert p.client.list(limit=0) == ([], None)


def test_two_clients_do_not_dedup_each_other():
    """Auto request_ids are unique per PLATFORM: a second ApiClient must
    not silently collide with the first client's submissions."""
    from repro.core.api import ApiClient
    p = boot(seed=19)
    c2 = ApiClient(p)
    h1 = submit(p, train_spec(name="a"))
    h2 = c2.submit(train_spec(name="b"))
    p.run(5)
    assert h2.acked and h2.job_id != h1.job_id and not h2.deduplicated


def test_guardian_exhaustion_settles_metering():
    """Guardian backoff exhaustion FAILs the job via the LCM reaper —
    which must stop the meter, or the dead job accrues in-flight
    GPU-seconds forever."""
    p = boot(seed=20)
    h = submit(p, train_spec(total_steps=1000, step_time_s=0.5,
                             resources=Resources(2, 1)))

    def keep_killing():
        p.kill_pod(f"guardian-{h.job_id}")
        p.sim.schedule(2.0, keep_killing)
    keep_killing()
    assert p.run_until_terminal(h.job_id, timeout=400) == "FAILED"
    settled = p.client.gpu_seconds("default")
    p.run(50)
    assert p.client.gpu_seconds("default") == pytest.approx(settled)


def test_metering_counts_in_flight_usage():
    m = Metering()
    m.job_started("j1", "acme", gpus=4, now=100.0)
    assert m.gpu_seconds("acme") == 0.0            # legacy view: settled only
    assert m.gpu_seconds("acme", now=110.0) == pytest.approx(40.0)
    m.job_stopped("j1", now=120.0)
    assert m.gpu_seconds("acme", now=500.0) == pytest.approx(80.0)
