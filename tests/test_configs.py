"""Config registry + shape grid + parameter counting."""
import pytest

from repro.configs import SHAPES, get_config, shape_applicable
from repro.models.params import count_params

ASSIGNED = [
    "recurrentgemma-9b", "rwkv6-7b", "qwen3-0.6b", "gemma2-9b",
    "mistral-large-123b", "qwen2.5-32b", "seamless-m4t-medium",
    "internvl2-76b", "deepseek-v2-236b", "granite-moe-1b-a400m",
]

# Published non-embedding parameter counts (approximate, ±15%)
EXPECTED_PARAMS = {
    "mistral-large-123b": 122e9,
    "qwen2.5-32b": 31e9,
    "gemma2-9b": 8.3e9,         # 9B includes embeddings (256k vocab)
    "rwkv6-7b": 6.8e9,
    "recurrentgemma-9b": 7.6e9, # 9B includes embeddings
    "internvl2-76b": 69e9,      # LLM backbone (frontend is a stub)
    "deepseek-v2-236b": 232e9,
}


def test_all_assigned_archs_registered():
    for a in ASSIGNED:
        cfg = get_config(a)
        assert cfg.name == a


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        get_config("nope-13b")


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_counts_sane(arch):
    cfg = get_config(arch)
    n = count_params(cfg)
    assert n > 1e8, arch
    if arch in EXPECTED_PARAMS:
        exp = EXPECTED_PARAMS[arch]
        assert 0.8 * exp < n < 1.2 * exp, (arch, n, exp)


def test_moe_active_params():
    cfg = get_config("deepseek-v2-236b")
    total = count_params(cfg)
    active = count_params(cfg, active_only=True)
    # DeepSeek-V2: 236B total, 21B active
    assert active < 0.15 * total
    assert 15e9 < active < 30e9, active


def test_long_500k_applicability():
    long = SHAPES["long_500k"]
    ok_archs = {a for a in ASSIGNED if shape_applicable(get_config(a), long)[0]}
    assert ok_archs == {"recurrentgemma-9b", "rwkv6-7b"}


def test_padded_vocab_multiple():
    for a in ASSIGNED:
        cfg = get_config(a)
        assert cfg.padded_vocab % cfg.pad_vocab_multiple == 0
        assert cfg.padded_vocab >= cfg.vocab_size


def test_reduced_preserves_family():
    for a in ASSIGNED:
        cfg = get_config(a)
        r = cfg.reduced()
        assert r.block_pattern == cfg.block_pattern
        assert r.is_moe == cfg.is_moe
        assert r.use_mla == cfg.use_mla
        assert r.is_encoder_decoder == cfg.is_encoder_decoder
        assert r.sub_quadratic == cfg.sub_quadratic
        assert count_params(r) < 3e6


def test_layer_kinds_pattern():
    cfg = get_config("recurrentgemma-9b")
    kinds = cfg.layer_kinds()
    assert len(kinds) == 38
    assert kinds[0] == kinds[1] == "recurrent"
    assert kinds[2] == "local"
    g2 = get_config("gemma2-9b").layer_kinds()
    assert g2[0] == "local" and g2[1] == "global" and len(g2) == 42
