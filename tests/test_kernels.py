"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + property tests
(interpret mode executes the kernel bodies in Python on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.models.attention import flash_attention_jnp

RNG = np.random.default_rng(42)


def _attn_inputs(B, S, H, K, hd, dtype):
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, K, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, K, hd)), dtype)
    return q, k, v


def _ref_bshd(q, k, v, **kw):
    B, S, H, hd = q.shape
    K = k.shape[2]
    out = ref.attention_ref(
        q.transpose(0, 2, 1, 3).reshape(B * H, S, hd),
        k.transpose(0, 2, 1, 3).reshape(B * K, S, hd),
        v.transpose(0, 2, 1, 3).reshape(B * K, S, hd),
        group=H // K, **kw)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("S", [128, 256, 384])
@pytest.mark.parametrize("H,K", [(4, 4), (8, 2), (8, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, H, K, dtype):
    q, k, v = _attn_inputs(2, S, H, K, 64, dtype)
    kw = dict(scale=64 ** -0.5, causal=True, window=0, logit_cap=0.0)
    out = ops.flash_attention_bshd(q, k, v, q_blk=128, kv_blk=128, **kw)
    expect = _ref_bshd(q, k, v, **kw)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol)


@pytest.mark.parametrize("window", [32, 128, 500])
@pytest.mark.parametrize("cap", [0.0, 30.0])
def test_flash_attention_window_softcap(window, cap):
    q, k, v = _attn_inputs(1, 256, 4, 2, 64, jnp.float32)
    kw = dict(scale=64 ** -0.5, causal=True, window=window, logit_cap=cap)
    out = ops.flash_attention_bshd(q, k, v, q_blk=128, kv_blk=128, **kw)
    expect = _ref_bshd(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


def test_flash_matches_model_jnp_path():
    """The model's blocked-jnp attention and the Pallas kernel agree."""
    q, k, v = _attn_inputs(2, 256, 8, 4, 64, jnp.float32)
    a = flash_attention_jnp(q, k, v, scale=0.125, causal=True, window=64,
                            q_block=128, kv_block=128)
    b = ops.flash_attention_bshd(q, k, v, scale=0.125, causal=True,
                                 window=64, q_blk=128, kv_blk=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(S=st.integers(2, 50), R=st.sampled_from([8, 128, 256]),
       seed=st.integers(0, 2**31 - 1))
def test_rglru_property(S, R, seed):
    r = np.random.default_rng(seed)
    la = -jnp.asarray(r.uniform(0.01, 3.0, (2, S, R)), jnp.float32)
    b = jnp.asarray(r.normal(size=(2, S, R)), jnp.float32)
    h0 = jnp.asarray(r.normal(size=(2, R)), jnp.float32)
    out = ops.rglru_scan_bsr(la, b, h0)
    expect = ref.rglru_ref(la, b, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


def test_rglru_decay_bound():
    """|h| stays bounded by |b|/(1-a) for constant decay (stability)."""
    la = jnp.full((1, 500, 8), -0.1, jnp.float32)
    b = jnp.ones((1, 500, 8), jnp.float32)
    out = ops.rglru_scan_bsr(la, b, jnp.zeros((1, 8), jnp.float32))
    bound = 1.0 / (1.0 - float(jnp.exp(-0.1))) + 1e-3
    assert float(jnp.abs(out).max()) <= bound


@pytest.mark.parametrize("S", [32, 64, 70, 128])
@pytest.mark.parametrize("chunk", [16, 32])
def test_wkv6_sweep(S, chunk):
    B, H, N = 2, 4, 64
    r = jnp.asarray(RNG.normal(size=(B, S, H, N)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, H, N)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, H, N)), jnp.float32)
    lw = -jnp.exp(jnp.asarray(RNG.uniform(-6, -1, (B, S, H, N)), jnp.float32))
    u = jnp.asarray(RNG.normal(size=(H, N)), jnp.float32) * 0.1
    s0 = jnp.asarray(RNG.normal(size=(B, H, N, N)), jnp.float32) * 0.1
    o, sf = ops.wkv6_bshn(r, k, v, lw, u, s0, chunk=chunk)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, N)
    o_r, sf_r = ref.wkv6_ref(fold(r), fold(k), fold(v), fold(lw),
                             jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, 1, N),
                             s0.reshape(B * H, N, N))
    o_r = o_r.reshape(B, H, S, N).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r),
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sf.reshape(B * H, N, N)),
                               np.asarray(sf_r), atol=2e-4, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), decay=st.floats(-5.0, -0.5))
def test_wkv6_state_decay_property(seed, decay):
    """With r=0 the output is 0 and the state decays exactly by exp(lw)."""
    B, S, H, N = 1, 32, 2, 64
    rng = np.random.default_rng(seed)
    zero = jnp.zeros((B, S, H, N), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32) * 0.0
    lw = jnp.full((B, S, H, N), decay, jnp.float32)
    u = jnp.zeros((H, N), jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(B, H, N, N)), jnp.float32)
    o, sf = ops.wkv6_bshn(zero, k, zero, lw, u, s0)
    assert float(jnp.abs(o).max()) == 0.0
    expect = s0 * np.exp(decay * S)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(expect),
                               atol=1e-5, rtol=1e-4)
