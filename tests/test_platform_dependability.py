"""Platform dependability — the paper's §II guarantees, each one tested.

Virtual-time platform; every failure here is injected mid-flight and the
assertion is about the *system invariant*, not about timing.
"""
import pytest

from repro.core import DLaaSPlatform, JobManifest
from repro.core.tenancy import NetworkPolicy


def boot(seed=0, **kw):
    p = DLaaSPlatform(seed=seed, **kw)
    p.run(10)            # core services come up
    return p


def submit(p, **kw):
    kw.setdefault("name", "job")
    h = p.submit(JobManifest(**kw))
    p.run(5)
    assert h.acked and h.job_id
    return h


# ---------------------------------------------------------------------------
# Submission / metadata durability
# ---------------------------------------------------------------------------
def test_job_never_lost_once_acked():
    """Ack only after Mongo persist: kill EVERYTHING right after the ack —
    the job must still run to completion."""
    p = boot(seed=1)
    h = submit(p, learners=2, total_steps=20, step_time_s=0.2)
    for pod in ("api-0", "api-1", "lcm-0"):
        p.kill_pod(pod)
    assert p.run_until_terminal(h.job_id, timeout=600) == "COMPLETED"


def test_submission_blocks_while_metadata_down():
    """API does not ack while Mongo is down; acks after it heals; no loss."""
    p = boot(seed=2)
    p.metadata.crash()
    h = p.submit(JobManifest(name="j", learners=1, total_steps=10,
                             step_time_s=0.2))
    p.run(5)
    assert not h.acked
    p.metadata.restart()
    p.run(5)
    assert h.acked
    assert p.run_until_terminal(h.job_id, timeout=300) == "COMPLETED"


def test_invalid_manifest_rejected():
    p = boot()
    h = p.submit(JobManifest(name="bad", learners=0))
    p.run(3)
    assert h.rejected and not h.acked


def test_api_failover():
    """Two API replicas: killing one leaves the service usable; killing both
    makes calls fail until K8S restarts a replica (3-5 s)."""
    from repro.core.cluster import RpcError
    p = boot(seed=3)
    h = submit(p, learners=1, total_steps=50, step_time_s=0.3)
    p.kill_pod("api-0")
    p.run(0.5)
    assert p.client.status(h.job_id)["state"]        # still served
    p.kill_pod("api-1")
    p.run(0.5)
    with pytest.raises(RpcError):
        p.client.status(h.job_id)
    p.run(10)                                        # deployment restarts pods
    assert p.client.status(h.job_id)["state"]


# ---------------------------------------------------------------------------
# Atomic deployment (Guardian under K8S-Job semantics)
# ---------------------------------------------------------------------------
def test_guardian_crash_mid_deploy_rolls_back_and_redeploys():
    p = boot(seed=13)
    h = submit(p, learners=2, total_steps=20, step_time_s=0.3)
    p.run(1.5)                                        # guardian mid-deploy
    assert p.kill_pod(f"guardian-{h.job_id}")
    assert p.run_until_terminal(h.job_id, timeout=600) == "COMPLETED"
    events = [e["event"] for e in p.client.events(h.job_id)]
    assert any("ROLLBACK" in e for e in events)
    # no leaked resources or quota
    assert p.volumes.active() == []
    assert p.tenancy.allocated.get("default", 0) == 0


def test_guardian_repeated_crashes_exhaust_backoff_and_fail_job():
    p = boot(seed=17)
    h = submit(p, learners=1, total_steps=1000, step_time_s=0.5)

    def keep_killing():
        if p.kill_pod(f"guardian-{h.job_id}") is not None:
            pass
        p.sim.schedule(2.0, keep_killing)
    keep_killing()
    state = p.run_until_terminal(h.job_id, timeout=400)
    assert state == "FAILED"
    assert p.tenancy.allocated.get("default", 0) == 0


# ---------------------------------------------------------------------------
# Learner / node failures
# ---------------------------------------------------------------------------
def test_learner_crash_recovers_from_checkpoint():
    p = boot(seed=11)
    h = submit(p, learners=4, gpus_per_learner=1, total_steps=80,
               step_time_s=0.5, checkpoint_interval_s=8)
    p.run(45)
    assert p.kill_pod(f"learner-{h.job_id}-2")
    assert p.run_until_terminal(h.job_id, timeout=900) == "COMPLETED"
    st = p.client.status(h.job_id)
    assert st["restarts"] >= 1
    logs = p.client.logs(h.job_id, 2)
    assert "restored checkpoint" in logs or "rolled back" in logs


def test_learner_crash_rejoin_mode():
    p = boot(seed=11)
    h = submit(p, learners=4, gpus_per_learner=1, total_steps=80,
               step_time_s=0.5, checkpoint_interval_s=8,
               extras={"recovery_mode": "rejoin"})
    p.run(45)
    p.kill_pod(f"learner-{h.job_id}-2")
    assert p.run_until_terminal(h.job_id, timeout=900) == "COMPLETED"
    assert "rejoined" in p.client.logs(h.job_id, 2)


def test_node_crash_recovery():
    p = boot(seed=5, n_nodes=8, gpus_per_node=4)
    h = submit(p, learners=3, gpus_per_learner=2, total_steps=60,
               step_time_s=0.5, checkpoint_interval_s=10)
    p.run(40)
    node = p.crash_node_of(f"learner-{h.job_id}-0")
    assert node is not None
    assert p.run_until_terminal(h.job_id, timeout=1200) == "COMPLETED"


def test_max_restarts_exceeded_fails_job():
    p = boot(seed=23)
    h = submit(p, learners=2, total_steps=2000, step_time_s=0.5,
               checkpoint_interval_s=10, max_restarts=2)

    def kill_loop():
        p.kill_pod(f"learner-{h.job_id}-0")
        p.sim.schedule(40.0, kill_loop)
    p.sim.schedule(30.0, kill_loop)
    assert p.run_until_terminal(h.job_id, timeout=2000) == "FAILED"
    assert p.volumes.active() == []


# ---------------------------------------------------------------------------
# Status / logs reliability
# ---------------------------------------------------------------------------
def test_status_updates_survive_statestore_replica_crash():
    p = boot(seed=7)
    h = submit(p, learners=2, total_steps=60, step_time_s=0.5)
    p.run(30)
    ldr = p.statestore.leader()
    p.statestore.crash_replica(ldr.idx)               # 2/3 keep quorum
    assert p.run_until_terminal(h.job_id, timeout=600) == "COMPLETED"


def test_statuses_timestamped_and_ordered():
    p = boot(seed=8)
    h = submit(p, learners=1, total_steps=20, step_time_s=0.2)
    p.run_until_terminal(h.job_id, timeout=300)
    ev = p.client.events(h.job_id)
    times = [e["t"] for e in ev]
    assert times == sorted(times)
    names = " ".join(e["event"] for e in ev)
    for marker in ("SUBMITTED", "DEPLOYING", "PROCESSING", "COMPLETED"):
        assert marker in names


def test_logs_stream_despite_learner_crash():
    p = boot(seed=9)
    h = submit(p, learners=1, total_steps=200, step_time_s=0.3,
               checkpoint_interval_s=10, max_restarts=5)
    p.run(40)
    p.kill_pod(f"learner-{h.job_id}-0")
    p.run(10)
    # logs written before the crash are already shipped to the object store
    assert "step" in p.client.logs(h.job_id) or \
           "checkpoint" in p.client.logs(h.job_id)


def test_halt():
    p = boot(seed=10)
    h = submit(p, learners=2, total_steps=10_000, step_time_s=0.5)
    p.run(20)
    p.client.halt(h.job_id)
    assert p.run_until_terminal(h.job_id, timeout=300) == "HALTED"
    assert p.volumes.active() == []
    assert p.tenancy.allocated.get("default", 0) == 0


# ---------------------------------------------------------------------------
# Multi-tenancy
# ---------------------------------------------------------------------------
def test_tenant_quota_enforced():
    p = boot(seed=12)
    p.tenancy.add_tenant("small", gpu_quota=2)
    h = p.submit(JobManifest(name="big", tenant="small", learners=4,
                             gpus_per_learner=1, total_steps=10))
    p.run(10)
    assert p.run_until_terminal(h.job_id, timeout=300) == "FAILED"


def test_gang_scheduling_all_or_nothing():
    p = boot(seed=14, n_nodes=2, gpus_per_node=4)       # 8 GPUs total
    h = p.submit(JobManifest(name="toobig", learners=3, gpus_per_learner=4,
                             total_steps=10))
    p.run(10)
    assert p.run_until_terminal(h.job_id, timeout=300) == "FAILED"
    assert p.tenancy.allocated.get("default", 0) == 0   # nothing leaked


def test_metering_accumulates():
    p = boot(seed=15)
    h = submit(p, learners=2, gpus_per_learner=2, total_steps=20,
               step_time_s=0.5)
    p.run_until_terminal(h.job_id, timeout=300)
    assert p.client.gpu_seconds("default") > 0


def test_network_isolation():
    labels = {"role": "learner", "job": "job-1", "tenant": "t1"}
    assert not NetworkPolicy.allowed(labels, "mongo")
    assert not NetworkPolicy.allowed(labels, "dlaas-lcm")
    assert not NetworkPolicy.allowed(labels, "volume/job-2")
    assert not NetworkPolicy.allowed(labels, "status/job-2/learner/0")
    assert NetworkPolicy.allowed(labels, "volume/job-1")
    assert NetworkPolicy.allowed(labels, "status/job-1/learner/0")
    assert NetworkPolicy.allowed(labels, "cos/datasets/imagenet")
    assert NetworkPolicy.allowed({"role": "guardian"}, "mongo")


# ---------------------------------------------------------------------------
# Multi-job concurrency
# ---------------------------------------------------------------------------
def test_many_concurrent_jobs():
    p = boot(seed=16, n_nodes=32)
    handles = []
    for i in range(6):
        handles.append(submit(p, name=f"j{i}", learners=2,
                              gpus_per_learner=1,
                              total_steps=20 + 5 * i, step_time_s=0.3))
    for h in handles:
        assert p.run_until_terminal(h.job_id, timeout=900) == "COMPLETED"
    assert p.volumes.active() == []
    assert p.tenancy.allocated.get("default", 0) == 0


# ---------------------------------------------------------------------------
# Elasticity
# ---------------------------------------------------------------------------
def test_elastic_shrink_on_capacity_loss():
    """Node dies, no spare GPUs: a non-elastic job stalls on the PENDING
    replacement, an elastic job shrinks its DP world and completes."""
    p = boot(seed=31, n_nodes=3, gpus_per_node=4)
    h = submit(p, learners=3, gpus_per_learner=4, total_steps=100,
               step_time_s=0.4, checkpoint_interval_s=15, elastic=True,
               max_restarts=10)
    p.run(40)                                   # training underway
    node = p.crash_node_of(f"learner-{h.job_id}-1")
    assert node is not None
    assert p.run_until_terminal(h.job_id, timeout=1500) == "COMPLETED"
    events = " | ".join(e["event"] for e in p.client.events(h.job_id))
    assert "ELASTIC shrink 3 -> 2" in events, events
    # released quota for the shrunk-away learner
    assert p.tenancy.allocated.get("default", 0) == 0


def test_pending_pod_schedules_after_heal():
    """Without elasticity, the replacement stays PENDING until the node
    heals, then training resumes and completes (no crash of the control
    plane on unschedulable pods)."""
    p = boot(seed=33, n_nodes=3, gpus_per_node=4)
    h = submit(p, learners=3, gpus_per_learner=4, total_steps=60,
               step_time_s=0.4, checkpoint_interval_s=15, max_restarts=10)
    p.run(30)
    node = p.crash_node_of(f"learner-{h.job_id}-2")
    p.run(60)                                    # stalled, pod PENDING
    st = p.client.status(h.job_id)
    assert st["state"] == "PROCESSING"
    p.cluster.heal_node(node)
    assert p.run_until_terminal(h.job_id, timeout=1500) == "COMPLETED"
