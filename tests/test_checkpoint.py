"""Checkpoint manager: round-trip, atomicity, corruption fallback, retention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.checkpoint import CheckpointManager
from repro.core.objectstore import ObjectStore


def tree(seed, scale=1.0):
    r = np.random.default_rng(seed)
    return {"params": {"w": r.normal(size=(4, 8)).astype(np.float32) * scale,
                       "b": r.normal(size=(8,)).astype(np.float32)},
            "step": np.asarray(seed)}


def test_roundtrip():
    store = ObjectStore()
    ck = CheckpointManager(store, "job-x")
    t = tree(7)
    ck.save(7, t)
    step, loaded = ck.load()
    assert step == 7
    np.testing.assert_array_equal(loaded["params"]["w"], t["params"]["w"])
    np.testing.assert_array_equal(loaded["step"], t["step"])


def test_bf16_roundtrip():
    store = ObjectStore()
    ck = CheckpointManager(store, "job-bf")
    t = {"w": jnp.ones((4, 4), jnp.bfloat16) * 1.5}
    ck.save(1, jax.tree.map(np.asarray, t))
    _, loaded = ck.load()
    assert loaded["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(loaded["w"], np.float32), 1.5)


def test_corruption_falls_back_to_previous():
    store = ObjectStore()
    ck = CheckpointManager(store, "job-c")
    ck.save(10, tree(10))
    ck.save(20, tree(20))
    # corrupt a blob of step 20
    blob = [p for p in store.list_prefix("ckpt/job-c/000000000020/blob/")][0]
    store.corrupt(blob, 3)
    assert ck.latest_valid_step() == 10
    step, loaded = ck.load()
    assert step == 10
    np.testing.assert_array_equal(loaded["params"]["w"], tree(10)["params"]["w"])


def test_torn_manifest_invisible():
    """A checkpoint without a valid manifest does not exist."""
    store = ObjectStore()
    ck = CheckpointManager(store, "job-t")
    ck.save(5, tree(5))
    # simulate crash-during-save of step 9: blobs written, manifest corrupt
    store.put("ckpt/job-t/000000000009/blob/x", b"partial")
    store.put("ckpt/job-t/000000000009/manifest", b"{not json")
    assert ck.latest_valid_step() == 5
    assert ck.load()[0] == 5


def test_retention():
    store = ObjectStore()
    ck = CheckpointManager(store, "job-r", keep_last=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree(s))
    assert ck.steps() == [3, 4]


def test_retention_keep_last_zero():
    """keep_last=0 keeps only the just-saved checkpoint (the historical
    ``steps[:-0]`` slice deleted nothing at all)."""
    store = ObjectStore()
    ck = CheckpointManager(store, "job-z", keep_last=0)
    for s in (1, 2, 3):
        ck.save(s, tree(s))
        assert ck.steps() == [s]
    step, loaded = ck.load()
    assert step == 3
    np.testing.assert_array_equal(loaded["params"]["w"], tree(3)["params"]["w"])
    # nothing but step 3 remains in the store
    assert all("000000000003" in p for p in store.list_prefix("ckpt/job-z/"))


def test_job_id_with_slash_rejected():
    """A '/' in the job id would fold extra levels into the key layout and
    mis-parse steps; reject it at construction."""
    store = ObjectStore()
    with pytest.raises(ValueError):
        CheckpointManager(store, "tenant/job")
    with pytest.raises(ValueError):
        CheckpointManager(store, "")
    with pytest.raises(ValueError):
        CheckpointManager(store, "job-ok", keep_last=-1)


def test_steps_ignores_foreign_keys():
    """steps() parses relative to the listing prefix and skips non-step
    entries that happen to live under it."""
    store = ObjectStore()
    ck = CheckpointManager(store, "job-f")
    ck.save(4, tree(4))
    store.put("ckpt/job-f/notes/manifest", b"{}")       # foreign key
    store.put("ckpt/job-f/manifest", b"{}")             # no step level
    assert ck.steps() == [4]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), nleaves=st.integers(1, 6))
def test_roundtrip_property(seed, nleaves):
    r = np.random.default_rng(seed)
    t = {f"l{i}": r.normal(size=r.integers(1, 20, size=2)).astype(np.float32)
         for i in range(nleaves)}
    store = ObjectStore()
    ck = CheckpointManager(store, "job-p")
    ck.save(seed, t)
    step, loaded = ck.load()
    assert step == seed
    for k in t:
        np.testing.assert_array_equal(loaded[k], t[k])
