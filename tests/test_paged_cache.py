"""Paged KV cache: dense/paged decode equivalence, per-sequence decode
positions (continuous batching), sharded-cache placement, gather/scatter
locality well-formedness, and serving-driver smoke tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, RunConfig, get_config
from repro.dist.mesh import make_abstract_production_mesh
from repro.dist.sharding import DEFAULT_RULES, check_cache_locality
from repro.launch.specs import placement_report
from repro.models.layers import Ctx
from repro.models.model import abstract_cache, forward, init_cache, num_pages
from repro.models.params import init_params

B, S, S0 = 2, 40, 28      # S0 deliberately not a multiple of page_size=8


def _setup(arch):
    cfg = get_config(arch).reduced()
    ctx = Ctx(dtype=jnp.float32)
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    return cfg, ctx, params, toks


def _run_serve(cfg, ctx, params, toks):
    cache = init_cache(cfg, B, S)
    logits, cache, _ = forward(cfg, params, {"tokens": toks[:, :S0]}, ctx,
                               mode="prefill", cache=cache)
    outs = [logits]
    for t in range(S0, S):
        logits, cache, _ = forward(cfg, params, {"tokens": toks[:, t:t + 1]},
                                   ctx, mode="decode", cache=cache, pos=t)
        outs.append(logits)
    return jnp.concatenate(outs, axis=1), cache


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "gemma2-9b", "qwen2.5-32b",
                                  "deepseek-v2-236b"])
def test_paged_matches_dense_decode(arch):
    """Decode logits must agree (fp32) between the dense fallback and the
    paged layout.  The paged model path is the O(pages) online-softmax walk
    (kernels.paged_attention), which reorders the reduction vs the dense
    full softmax — so the bound is a tight fp32 tolerance rather than the
    bitwise equality the old gather-reference permitted; op-level
    equivalence at ~1e-6 is covered in test_paged_kernel.py."""
    cfg, ctx, params, toks = _setup(arch)
    dense, _ = _run_serve(dataclasses.replace(cfg, cache_layout="dense"),
                          ctx, params, toks)
    paged, _ = _run_serve(dataclasses.replace(cfg, cache_layout="paged"),
                          ctx, params, toks)
    err = float(jnp.abs(dense - paged).max())
    assert err < 1e-4, (arch, err)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "gemma2-9b",
                                  "deepseek-v2-236b"])
def test_per_sequence_decode_positions(arch):
    """Continuous batching decodes rows at different positions: an active
    row with a (B,) position vector must produce the same logits as the
    lockstep run, and inactive rows (pos = -1) must not corrupt it."""
    cfg, ctx, params, toks = _setup(arch)
    cfg = dataclasses.replace(cfg, cache_layout="paged")
    lock, _ = _run_serve(cfg, ctx, params, toks)

    cache = init_cache(cfg, B, S)
    _, cache, _ = forward(cfg, params, {"tokens": toks[:, :S0]}, ctx,
                          mode="prefill", cache=cache)
    for t in range(S0, S):
        # row 1 inactive: feeds a junk token at pos -1 (dropped write)
        step_toks = jnp.stack([toks[0, t:t + 1], jnp.zeros((1,), toks.dtype)])
        pos = jnp.asarray([t, -1], jnp.int32)
        logits, cache, _ = forward(cfg, params, {"tokens": step_toks}, ctx,
                                   mode="decode", cache=cache, pos=pos)
        err = float(jnp.abs(logits[0, 0] - lock[0, t - S0 + 1]).max())
        assert err < 1e-5, (arch, t, err)


def test_paged_cache_is_smaller_in_specs():
    """Pool + tables with a reduced page budget must spec out smaller than
    the dense worst-case cache (unsharded byte count)."""
    import jax.tree_util as jtu
    cfg = get_config("qwen3-0.6b")
    dense = abstract_cache(cfg, 8, 4096, layout="dense")
    paged = abstract_cache(dataclasses.replace(cfg, cache_layout="paged"),
                           8, 4096, layout="paged", page_budget=64)
    size = lambda tree: sum(
        int(np.prod(ab.shape)) for ab in jtu.tree_leaves(
            tree, is_leaf=lambda x: hasattr(x, "logical_axes")))
    assert size(paged) < size(dense) / 4


def test_decode_32k_placement_4x_reduction():
    """Acceptance: decode_32k on the 16×16 production mesh — the paged +
    sequence-sharded layout must report ≥4× lower cache_gb than the seed
    placement (kv_seq/cache_pages replicated)."""
    mesh = make_abstract_production_mesh()
    shape = SHAPES["decode_32k"]
    run = RunConfig()
    legacy = DEFAULT_RULES.override(kv_seq=(), cache_pages=())
    for arch in ("qwen3-0.6b", "mistral-large-123b", "gemma2-9b"):
        cfg = get_config(arch)
        seed_gb = placement_report(cfg, shape, run, mesh, legacy)["cache_gb"]
        paged = placement_report(
            dataclasses.replace(cfg, cache_layout="paged"), shape, run, mesh)
        assert paged["cache_gb"] * 4 <= seed_gb, (arch, seed_gb, paged)
        assert paged["cache_pages"] > 0
        # dense fallback with the new kv_seq rule also stops replicating
        dense_gb = placement_report(cfg, shape, run, mesh)["cache_gb"]
        assert dense_gb * 4 <= seed_gb, (arch, seed_gb, dense_gb)


def test_page_occupancy_scales_budget():
    mesh = make_abstract_production_mesh()
    shape = SHAPES["decode_32k"]
    cfg = dataclasses.replace(get_config("qwen3-0.6b"), cache_layout="paged")
    full = placement_report(cfg, shape, RunConfig(), mesh)
    half = placement_report(cfg, shape, RunConfig(page_occupancy=0.5), mesh)
    assert half["cache_pages"] * 2 == full["cache_pages"]
    assert half["cache_gb"] < full["cache_gb"]


def test_cache_locality_check_rejects_sharded_ring():
    """A rules override that shards the ring-buffer slot dim must be
    rejected: the pos%window scatter would cross shards every step."""
    cfg = get_config("gemma2-9b")           # has local-attention layers
    mesh = make_abstract_production_mesh()
    ab = abstract_cache(cfg, 128, 4096)
    check_cache_locality(ab, mesh, DEFAULT_RULES)          # well-formed
    bad = DEFAULT_RULES.override(window_seq=("model",))
    with pytest.raises(ValueError, match="window_seq|ring"):
        check_cache_locality(ab, mesh, bad)


def test_identity_tables_need_worst_case_pool():
    cfg = dataclasses.replace(get_config("qwen3-0.6b").reduced(),
                              cache_layout="paged")
    with pytest.raises(ValueError, match="identity"):
        init_cache(cfg, 4, 64, page_budget=3)
    # empty tables are fine with any budget
    c = init_cache(cfg, 4, 64, page_budget=3, paged_tables="empty")
    flat = jax.tree.leaves(c)
    assert all(jnp.all(l == -1) for l in flat if l.dtype == jnp.int32)


def test_num_pages():
    assert num_pages(64, 8) == 8
    assert num_pages(65, 8) == 9
    assert num_pages(1, 8) == 1


# ---------------------------------------------------------------------------
# Serving-driver smoke tests (ISSUE 2 satellite: launch.serve --reduced)
# ---------------------------------------------------------------------------
def test_serve_reduced_smoke():
    from repro.launch import serve
    assert serve.main(["--reduced", "--batch", "2", "--prompt-len", "16",
                       "--gen", "6"]) == 0


def test_serve_continuous_smoke():
    """Continuous batching: more requests than slots, a squeezed page
    budget (forces admission stalls), every request must complete."""
    from repro.launch import serve
    assert serve.main(["--reduced", "--batch", "2", "--prompt-len", "16",
                       "--gen", "6", "--continuous", "--requests", "4",
                       "--page-budget", "3"]) == 0


def test_serve_continuous_gen_one(capsys):
    """gen_len == 1 requests are done at prefill: no extra decode token
    (the prefill output IS the single requested token)."""
    from repro.launch import serve
    assert serve.main(["--reduced", "--batch", "2", "--prompt-len", "16",
                       "--gen", "1", "--continuous", "--requests", "3"]) == 0
    out = capsys.readouterr().out
    assert "completed 3/3 in 0 decode steps" in out, out


def test_page_pool_shard_partitioning():
    from repro.launch.serve import PagePool
    pool = PagePool(8, n_shards=2)
    a = pool.alloc(3, shard=0)
    b = pool.alloc(3, shard=1)
    assert all(p < 4 for p in a) and all(p >= 4 for p in b)
    assert pool.alloc(1, shard=0) == [3]
    assert pool.alloc(1, shard=0) is None       # shard 0 exhausted
    assert pool.high_water == 7
    pool.free(a)                                 # returns to shard 0's list
    assert pool.alloc(3, shard=0) == a
    assert pool.in_use == 7                      # shard 1 still has one free
