"""Prefill + decode must reproduce the train-mode (teacher-forced) logits.

This is the strongest correctness property the serving path has: every
cache mechanism (positional KV, ring-buffer window, MLA latent+absorption,
RG-LRU state, RWKV6 state, cross-attention K/V) must agree with the
parallel formulation.  MoE archs pin capacity_factor high because capacity
token-dropping legitimately differs between batched and incremental
dispatch (see models/moe.py).
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.layers import Ctx
from repro.models.model import forward, init_cache
from repro.models.params import init_params

ARCHS = [
    "qwen3-0.6b", "gemma2-9b", "rwkv6-7b", "recurrentgemma-9b",
    "mistral-large-123b", "qwen2.5-32b", "internvl2-76b",
    "seamless-m4t-medium", "deepseek-v2-236b", "granite-moe-1b-a400m",
]

B, S, S0 = 2, 40, 32


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_train(arch):
    cfg = get_config(arch).reduced()
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    ctx = Ctx(dtype=jnp.float32)
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    src_len = 0
    if cfg.is_encoder_decoder:
        src_len = 16
        batch["src_embeds"] = 0.02 * jax.random.normal(
            jax.random.key(3), (B, src_len, cfg.d_model))

    full, _, _ = forward(cfg, params, batch, ctx, mode="train")
    real = full[..., :cfg.vocab_size]
    scale = float(jnp.abs(real).max())

    cache = init_cache(cfg, B, S, src_len=src_len)
    pb = dict(batch)
    pb["tokens"] = toks[:, :S0]
    pl_, cache, _ = forward(cfg, params, pb, ctx, mode="prefill", cache=cache)
    errs = [float(jnp.abs(pl_[:, 0, :cfg.vocab_size] - real[:, S0 - 1]).max())]
    for t in range(S0, S):
        dl, cache, _ = forward(cfg, params, {"tokens": toks[:, t:t + 1]},
                               ctx, mode="decode", cache=cache, pos=t)
        errs.append(float(jnp.abs(dl[:, 0, :cfg.vocab_size] - real[:, t]).max()))
    # fp32 reassociation across ~30 layers (flash online-softmax vs decode
    # einsum) leaves ~1e-2 absolute noise on O(1) logits; a real cache bug
    # produces O(scale) errors.  Combined absolute + relative tolerance.
    assert max(errs) < max(2e-3 * scale, 1.5e-2), (arch, max(errs), scale)
