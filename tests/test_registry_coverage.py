"""Universal fast path: EVERY registered config serves through the
ServingEngine on the paged layout — admit, decode, snapshot, restore
byte-identically, and drain — with no arch-specific skips.

This is the acceptance gate for the fast-path coverage matrix: attention
stacks (global/local/GQA), MLA latent caches, recurrent and RWKV
carries, MoE, vision frontends, and encoder-decoder stacks all go
through the same admit/step/evict/snapshot/restore state machine."""
import dataclasses
import pickle

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_configs
from repro.core.jobspec import ServeSpec
from repro.launch.engine import ServingEngine, synthesize_requests
from repro.models.layers import Ctx
from repro.models.params import init_params


@pytest.mark.parametrize("arch", list_configs())
def test_every_config_serves_paged(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              cache_layout="paged")
    sv = ServeSpec(batch=2, prompt_len=12, gen=4, requests=3,
                   continuous=True, cache_layout="paged")
    ctx = Ctx(dtype=jnp.float32)
    params = init_params(cfg, jax.random.key(0))

    eng = ServingEngine(cfg, ctx, params, sv)
    for r in synthesize_requests(cfg, sv, seed=7, ragged=eng.ragged):
        eng.submit(r)

    admitted = eng.admit()
    assert admitted, arch
    for _ in range(2):
        eng.step()

    # snapshot → restore on a fresh engine must reproduce the state
    # byte-for-byte (the platform's migrate/repair contract)
    snap = eng.snapshot()
    eng2 = ServingEngine(cfg, ctx, params, sv)
    eng2.restore(snap)
    assert pickle.dumps(eng2.snapshot()) == pickle.dumps(snap), arch

    # both incarnations drain to the same responses
    eng.run()
    eng2.run()
    assert eng.responses == eng2.responses, arch
    assert len(eng.responses) == sv.requests, (arch, eng.responses)
