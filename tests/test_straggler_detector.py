"""StragglerDetector unit tests: boundary semantics of the lag detector
that feeds the Guardian's STRAGGLER reports (rejoin-mode recovery)."""
from repro.core.recovery import StragglerDetector


def _feed(det, t0, rows, window=10.0):
    """Feed one row per window tick; returns the flagged lists."""
    out = []
    for k, row in enumerate(rows):
        out.append(det.update(t0 + k * window, list(row)))
    return out


def test_needs_three_learners_to_judge():
    det = StragglerDetector(2)
    assert _feed(det, 0.0, [(0, 0)] * 10) == [[]] * 10


def test_flags_after_patience_consecutive_lagging_windows():
    det = StragglerDetector(4, lag_factor=0.5, patience=3)
    # peers advance 20/window, learner 3 advances 5 (< 0.5 * median)
    rows = [(20 * k, 20 * k, 20 * k, 5 * k) for k in range(5)]
    flagged = _feed(det, 0.0, rows)
    assert flagged[:3] == [[], [], []]      # first row seeds; strikes 1, 2
    assert flagged[3] == [3]                # third strike: flag + reset
    assert flagged[4] == []                 # strikes restart from zero


def test_lag_factor_boundary_is_strict():
    # delta exactly at lag_factor * median is NOT lagging (strict <)
    det = StragglerDetector(4, lag_factor=0.5, patience=1)
    rows = [(20 * k, 20 * k, 20 * k, 10 * k) for k in range(4)]
    assert _feed(det, 0.0, rows) == [[]] * 4
    det = StragglerDetector(4, lag_factor=0.5, patience=1)
    rows = [(20 * k, 20 * k, 20 * k, 9 * k) for k in range(4)]
    assert _feed(det, 0.0, rows)[1:] == [[3]] * 3


def test_all_none_steps_never_flag():
    det = StragglerDetector(4)
    assert _feed(det, 0.0, [(None,) * 4] * 6) == [[]] * 6


def test_unknown_learner_is_not_judged():
    # a restarting learner reports None — no strike either way
    det = StragglerDetector(4, patience=1)
    rows = [(20 * k, 20 * k, 20 * k, None) for k in range(4)]
    assert _feed(det, 0.0, rows) == [[]] * 4


def test_whole_group_stall_is_not_a_straggler():
    det = StragglerDetector(4, patience=1)
    rows = [(7, 7, 7, 3)] * 5               # nobody advances: median 0
    assert _feed(det, 0.0, rows) == [[]] * 5


def test_recovered_learner_resets_strikes():
    det = StragglerDetector(4, lag_factor=0.5, patience=3)
    flagged = []
    steps = [0, 0, 0, 0]
    rates = [(20, 20, 20, 5),               # 2 lagging windows (strikes 1, 2)
             (20, 20, 20, 5),
             (20, 20, 20, 20),              # recovery window: strikes reset
             (20, 20, 20, 5),               # lagging resumes: strikes 1, 2, 3
             (20, 20, 20, 5),
             (20, 20, 20, 5)]
    det.update(0.0, steps)                  # seed
    for k, rate in enumerate(rates):
        steps = [s + r for s, r in zip(steps, rate)]
        flagged.append(det.update(10.0 * (k + 1), list(steps)))
    # without the reset the flag would fire at index 3; with it, index 5
    assert flagged == [[], [], [], [], [], [3]]


def test_sub_window_updates_are_ignored():
    det = StragglerDetector(4, patience=1)
    det.update(0.0, [0, 0, 0, 0])
    # 5s later (< window_s): no evaluation, no state clobber
    assert det.update(5.0, [10, 10, 10, 1]) == []
    # full window from seed: learner 3 lagging vs median 20
    assert det.update(10.0, [20, 20, 20, 2]) == [3]
