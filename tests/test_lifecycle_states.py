"""Declared lifecycle state machines (core/states.py) and the runtime
that routes through them: transition validation + event journaling,
learner-status aggregation priority, pod lifecycle strictness (no
zombie resurrection), and the rollback safety-net sweep."""
import pytest

from repro.core import states
from repro.core.guardian import _aggregate
from repro.core.states import (InvalidTransition, JOB, LEARNER_PRIORITY,
                               LEARNER_STATES, POD, UNKNOWN)


# ---------------------------------------------------------------------------
# state machine tables
# ---------------------------------------------------------------------------
def test_job_machine_shape():
    assert JOB.initial == "SUBMITTED"
    assert set(JOB.terminal) == {"COMPLETED", "FAILED", "HALTED"}
    # the restart back-edge the guardian redeploy depends on
    assert ("PROCESSING", "DEPLOYING") in JOB.transitions


def test_allowed_and_check():
    assert JOB.allowed("SUBMITTED", "DEPLOYING")
    assert JOB.allowed("PROCESSING", "PROCESSING")     # idempotent re-assert
    assert not JOB.allowed("COMPLETED", "DEPLOYING")   # terminals absorb
    assert not JOB.allowed("SUBMITTED", "COMPLETED")
    with pytest.raises(InvalidTransition):
        JOB.check("COMPLETED", "DEPLOYING")
    # InvalidTransition keeps the in-pod error contract
    assert issubclass(InvalidTransition, ValueError)


def test_pod_machine_shape():
    assert POD.allowed("PENDING", "RUNNING")
    assert POD.allowed("RUNNING", "FAILED")
    assert not POD.allowed("FAILED", "RUNNING")        # no resurrection
    assert not POD.allowed("SUCCEEDED", "RUNNING")
    assert not POD.allowed("PENDING", "SUCCEEDED")     # must run first


# ---------------------------------------------------------------------------
# job_transition helper
# ---------------------------------------------------------------------------
class FakeMetadata:
    def __init__(self, doc):
        self.doc = doc
        self.events = []

    def get(self, coll, doc_id):
        return self.doc

    def update(self, coll, doc_id, fields):
        self.doc.update(fields)

    def append_event(self, coll, doc_id, event):
        self.events.append(event)


def test_job_transition_updates_and_journals():
    md = FakeMetadata({"id": "j1", "state": "PROCESSING"})
    states.job_transition(md, 12.5, "j1", "COMPLETED",
                          fields={"note": "done"}, event="COMPLETED")
    assert md.doc["state"] == "COMPLETED"
    assert md.doc["note"] == "done"
    assert md.events == [{"t": 12.5, "event": "COMPLETED",
                          "from": "PROCESSING", "to": "COMPLETED"}]


def test_job_transition_rejects_undeclared_edge():
    md = FakeMetadata({"id": "j1", "state": "COMPLETED"})
    with pytest.raises(InvalidTransition):
        states.job_transition(md, 1.0, "j1", "DEPLOYING")
    assert md.doc["state"] == "COMPLETED"      # rejected before any write
    assert md.events == []


def test_job_transition_idempotent_retry():
    # a retry after a partially-committed write re-asserts the same state
    md = FakeMetadata({"id": "j1", "state": "DEPLOYING"})
    states.job_transition(md, 2.0, "j1", "DEPLOYING")
    assert md.doc["state"] == "DEPLOYING"


def test_learner_status_validates_vocabulary():
    st = states.learner_status("RUNNING", step=7, t=1.0)
    assert st == {"state": "RUNNING", "step": 7, "t": 1.0}
    with pytest.raises(InvalidTransition):
        states.learner_status("LIMBO", t=1.0)


# ---------------------------------------------------------------------------
# _aggregate priority (ISSUE satellite: UNKNOWN/UNREACHABLE vs RUNNING)
# ---------------------------------------------------------------------------
def _st(state, step=None):
    d = {"state": state}
    if step is not None:
        d["step"] = step
    return d


def test_aggregate_failed_dominates_everything():
    sts = [_st("RUNNING", 5), _st("FAILED"), _st("UNREACHABLE", 3)]
    assert _aggregate(sts).startswith("FAILED")


def test_aggregate_unreachable_beats_running():
    sts = [_st("RUNNING", 9), _st("UNREACHABLE", 2), _st("RUNNING", 4)]
    assert _aggregate(sts).startswith("UNREACHABLE")


def test_aggregate_missing_status_is_unknown_and_beats_running():
    # a learner with no status doc yet degrades the gang below RUNNING
    sts = [_st("RUNNING", 5), None]
    assert _aggregate(sts).startswith(UNKNOWN)


def test_aggregate_starting_beats_unknown():
    sts = [_st("STARTING"), None]
    assert _aggregate(sts).startswith("STARTING")


def test_aggregate_all_succeeded_and_min_step():
    sts = [_st("SUCCEEDED", 10), _st("SUCCEEDED", 7)]
    assert _aggregate(sts) == "SUCCEEDED (min step 7)"


def test_aggregate_total_over_declared_vocabulary():
    # every declared learner state (plus the synthetic UNKNOWN) aggregates
    # without KeyError/UnboundLocalError, and maps to itself when alone
    for s in sorted(LEARNER_STATES):
        assert _aggregate([_st(s)]).startswith(s)
    assert _aggregate([None]).startswith(UNKNOWN)
    assert set(LEARNER_PRIORITY) == LEARNER_STATES | {UNKNOWN}


# ---------------------------------------------------------------------------
# pod lifecycle strictness: no zombie resurrection
# ---------------------------------------------------------------------------
def test_pod_start_after_fail_stays_dead():
    from repro.core.cluster import Cluster, ContainerSpec, Pod, PodSpec
    from repro.core.sim import Sim
    sim = Sim(seed=0)
    cluster = Cluster(sim, n_nodes=1, gpus_per_node=8)
    spec = PodSpec(name="p0", containers=[ContainerSpec(
        "c", lambda pod: iter(()))])
    pod = Pod(spec, cluster.nodes[0], cluster)
    pod.uid = "p0#0"
    cluster.pods[pod.uid] = pod
    assert pod.status == "PENDING"
    pod.fail()                      # e.g. node crashed while PENDING
    assert pod.status == "FAILED"
    pod._start()                    # the queued start fires anyway
    assert pod.status == "FAILED"   # guard: FAILED -> RUNNING is undeclared


def test_pod_transition_rejects_resurrection():
    class P:
        status = "FAILED"
    with pytest.raises(InvalidTransition):
        states.pod_transition(P(), "RUNNING")


# ---------------------------------------------------------------------------
# rollback safety net: unrecorded leftovers are settled idempotently
# ---------------------------------------------------------------------------
def test_rollback_sweeps_unrecorded_gang_and_volume():
    """A guardian crash between a resource's creation and its ETCD record
    leaves no record — the next rollback must still release it."""
    from repro.core.guardian import _rollback
    from repro.core.jobspec import JobSpec, Resources
    from repro.core.platform import DLaaSPlatform

    p = DLaaSPlatform(n_nodes=2, gpus_per_node=8)
    spec = JobSpec(name="j", kind="train",
                   resources=Resources(replicas=2, gpus_per_replica=1))
    job_id = "job-0001"
    # simulate the crash window: gang admitted + volume provisioned, but
    # the deploy record list is still empty
    p.scheduler.admit_gang(p.cluster, spec.tenant, 2, 1)
    p.gang_sizes[job_id] = 2
    p.volumes.provision(f"vol-{job_id}")
    assert p.tenancy.allocated.get("default", 0) == 2

    def run():
        yield from _rollback(p, job_id, spec, [])   # empty record
    p.sim.spawn(run())
    p.sim.run(until=60.0)

    assert p.tenancy.allocated.get("default", 0) == 0
    assert p.volumes.active() == []
    assert job_id not in p.gang_sizes


def test_rollback_without_admitted_gang_releases_nothing():
    """The old default (pop(job_id, spec.learners)) released quota that
    was never admitted, corrupting the tenant's allocation downward."""
    from repro.core.guardian import _rollback
    from repro.core.jobspec import JobSpec, Resources
    from repro.core.platform import DLaaSPlatform

    p = DLaaSPlatform(n_nodes=2, gpus_per_node=8)
    spec = JobSpec(name="j", kind="train",
                   resources=Resources(replicas=4, gpus_per_replica=1))
    # another job holds quota under the same tenant
    p.scheduler.admit_gang(p.cluster, "default", 3, 1)
    before = p.tenancy.allocated.get("default", 0)

    def run():
        # job-0002 recorded a gang it never actually admitted (crash
        # before admission): rollback must not release someone else's
        yield from _rollback(p, "job-0002", spec, ["gang/job-0002"])
    p.sim.spawn(run())
    p.sim.run(until=60.0)
    assert p.tenancy.allocated.get("default", 0) == before


# ---------------------------------------------------------------------------
# README carries the rendered diagrams (ISSUE satellite)
# ---------------------------------------------------------------------------
def test_readme_state_diagrams_match_declared_tables():
    from pathlib import Path
    readme = (Path(__file__).resolve().parents[1] / "README.md").read_text()
    for machine in (JOB, POD):
        diagram = states.render_mermaid(machine)
        assert diagram in readme, (
            f"README state diagram for {machine.name} is out of date — "
            f"re-render with states.render_mermaid()")
