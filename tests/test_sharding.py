"""Logical-axis sharding rules: auto-drop, mesh portability, properties."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import DEFAULT_RULES, logical_to_spec


def mesh2():
    return jax.make_mesh((1, 1), ("data", "model"))


def fake_mesh(shape, axes):
    """Abstract mesh for spec computation only (uses the 1 real device via
    reshaping is impossible — so compute specs against a 1x1 mesh and a
    synthetic sizes table)."""
    return jax.make_mesh(shape, axes)


def test_basic_mapping():
    mesh = mesh2()
    spec = logical_to_spec(("batch", None, "embed_act"), (8, 4, 16), mesh)
    assert spec == P("data") or spec == P(("data",))


def test_auto_drop_indivisible():
    # kv_heads=8 cannot shard over model=1? trivially ok; test the divisibility
    # logic with a rules table mapping to a 1-sized axis (always divides) and
    # an axis absent from the mesh (dropped).
    mesh = mesh2()
    rules = DEFAULT_RULES.override(heads=("model", "pod"))  # pod absent
    spec = logical_to_spec(("embed", "heads"), (64, 48), mesh, rules)
    assert spec in (P("data", "model"), P("data", ("model",)))


def test_axis_used_once():
    mesh = mesh2()
    rules = DEFAULT_RULES.override(a=("model",), b=("model",))
    spec = logical_to_spec(("a", "b"), (4, 4), mesh, rules)
    # second dim cannot reuse "model"
    assert spec == P("model")


def test_unknown_axis_raises():
    with pytest.raises(KeyError):
        logical_to_spec(("nonsense",), (4,), mesh2())


@settings(max_examples=30, deadline=None)
@given(dim=st.integers(1, 64))
def test_autodrop_always_valid(dim):
    """Whatever the dim, the produced spec's axis sizes divide it."""
    mesh = mesh2()
    spec = logical_to_spec(("ffn",), (dim,), mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for entry in spec:
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = int(np.prod([sizes[a] for a in axes]))
        assert dim % n == 0


def test_production_rules_cover_model_axes():
    """Every logical axis the models use has a rule."""
    used = ["batch", "seq", "resid_seq", "embed", "embed_act", "vocab",
            "vocab_act", "heads", "kv_heads", "kv_seq", "head_dim", "ffn",
            "experts", "expert_ffn", "rnn", "layers", "lora", "conv",
            "capacity"]
    table = DEFAULT_RULES.as_dict()
    for name in used:
        assert name in table, name
