"""Self-healing Guardian: classification, safe repairs, per-category budgets.

Unit layer: FailureClassifier evidence rules against a stub platform,
the safe-repair registry contract, journal validation, the bounded
checkpoint fallback, and scheduler node exclusions.  End-to-end layer:
per-category restart budgets are genuinely independent — a flaky-pod
storm cannot exhaust the OOM budget and vice versa.
"""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import DLaaSPlatform
from repro.core.checkpoint import CheckpointManager
from repro.core.cluster import PodRecord
from repro.core.failures import (
    OOM_SIGNATURE, SAFE_REPAIRS, FailureClassifier, FailureReport, Fault,
    FaultPlan, SelfHealer, action_for,
)
from repro.core.jobspec import JobSpec, Resources, TrainSpec
from repro.core.objectstore import ObjectStore
from repro.core.states import InvalidTransition, journal_failure


# ---------------------------------------------------------------------------
# stubs for classifier unit tests (no platform boot needed)
# ---------------------------------------------------------------------------
def _stub_platform(records=(), nodes=(), now=100.0, store=None):
    return SimpleNamespace(
        cluster=SimpleNamespace(pod_history=list(records), nodes=list(nodes)),
        sim=SimpleNamespace(now=now),
        statestore=SimpleNamespace(try_get=lambda key: None),
        objectstore=store if store is not None else ObjectStore(),
    )


def _rec(name, node="node-0", detail="", finished=95.0):
    return PodRecord(uid=name, name=name, status="FAILED", started_at=50.0,
                     finished_at=finished, node=node, exit_detail=detail)


def _node(name="node-0", alive=True):
    return SimpleNamespace(name=name, alive=alive)


SERVE_SPEC = SimpleNamespace(kind="serve")


# ---------------------------------------------------------------------------
# FailureClassifier: one test per evidence rule
# ---------------------------------------------------------------------------
def test_classifies_oom_from_exit_signature():
    p = _stub_platform([_rec("learner-j-0", detail=OOM_SIGNATURE)],
                       [_node()])
    r = FailureClassifier(p, "j", SERVE_SPEC).classify(0)
    assert r.category == "OOM" and r.confidence >= 0.9
    assert OOM_SIGNATURE in r.evidence["exit_detail"]


def test_classifies_flaky_pod_from_detail_free_crash():
    p = _stub_platform([_rec("learner-j-0")], [_node()])
    r = FailureClassifier(p, "j", SERVE_SPEC).classify(0)
    assert r.category == "FLAKY_POD"


def test_classifies_unknown_from_unrecognized_detail():
    p = _stub_platform([_rec("learner-j-0", detail="status 139 (segfault?)")],
                       [_node()])
    r = FailureClassifier(p, "j", SERVE_SPEC).classify(0)
    assert r.category == "UNKNOWN"
    assert r.confidence < 0.6          # never clears the repair threshold


def test_classifies_poisoned_node_from_co_occurrence():
    recs = [_rec("learner-j-0"), _rec("learner-j-1")]
    p = _stub_platform(recs, [_node()])
    r = FailureClassifier(p, "j", SERVE_SPEC).classify(0)
    assert r.category == "POISONED_NODE" and r.node == "node-0"
    assert r.evidence["co_failed"] == ["learner-j-0", "learner-j-1"]


def test_dead_node_is_not_poisoned():
    # a dead node is the scheduler's problem; co-occurrence on it must
    # not trigger the exclusion repair
    recs = [_rec("learner-j-0"), _rec("learner-j-1")]
    p = _stub_platform(recs, [_node(alive=False)])
    r = FailureClassifier(p, "j", SERVE_SPEC).classify(0)
    assert r.category == "FLAKY_POD"


def test_stale_co_failures_outside_window_ignored():
    recs = [_rec("learner-j-0", finished=95.0),
            _rec("learner-j-1", finished=95.0 - 500.0)]
    p = _stub_platform(recs, [_node()], now=100.0)
    r = FailureClassifier(p, "j", SERVE_SPEC).classify(0)
    assert r.category == "FLAKY_POD"


def test_classifies_ckpt_corrupt_from_invalid_newest_generation():
    store = ObjectStore()
    ck = CheckpointManager(store, "j")
    ck.save(10, {"w": np.arange(8.0)})
    for path in store.list_prefix(f"ckpt/j/{10:012d}/blob/"):
        store.corrupt(path)
    p = _stub_platform([_rec("learner-j-0")], [_node()], store=store)
    spec = SimpleNamespace(kind="train")
    r = FailureClassifier(p, "j", spec).classify(0)
    assert r.category == "CKPT_CORRUPT"
    assert r.evidence["corrupt_step"] == 10


def test_straggler_report_carries_detector_evidence():
    p = _stub_platform()
    r = FailureClassifier(p, "j", SERVE_SPEC).straggler_report(
        2, lag_factor=0.5)
    assert r.category == "STRAGGLER" and r.learner == 2
    assert r.evidence["detector"] == "progress-lag"


# ---------------------------------------------------------------------------
# safe-repair registry contract
# ---------------------------------------------------------------------------
def test_unknown_has_no_registered_repair():
    assert "UNKNOWN" not in SAFE_REPAIRS
    action, is_repair = action_for(FailureReport("UNKNOWN", 0.3))
    assert action == "restart" and not is_repair


def test_low_confidence_falls_back_to_plain_restart():
    action, is_repair = action_for(FailureReport("OOM", 0.4))
    assert action == "restart" and not is_repair


def test_restart_only_policy_never_repairs():
    action, is_repair = action_for(FailureReport("OOM", 0.95),
                                   policy="restart-only")
    assert action == "restart" and not is_repair


def test_auto_policy_resolves_registered_repairs():
    for cat, expected in SAFE_REPAIRS.items():
        action, is_repair = action_for(FailureReport(cat, 0.9))
        assert (action, is_repair) == (expected, True), cat


# ---------------------------------------------------------------------------
# journal validation (same contract as job_transition)
# ---------------------------------------------------------------------------
class _Journal:
    def __init__(self):
        self.events = []

    def append_event(self, coll, key, doc):
        self.events.append(doc)


def test_journal_failure_rejects_unknown_category():
    with pytest.raises(InvalidTransition):
        journal_failure(_Journal(), 1.0, "j",
                        {"category": "GREMLINS", "confidence": 0.9})


def test_journal_failure_rejects_out_of_range_confidence():
    with pytest.raises(InvalidTransition):
        journal_failure(_Journal(), 1.0, "j",
                        {"category": "OOM", "confidence": 1.5})


def test_journal_failure_never_writes_a_state_key():
    j = _Journal()
    journal_failure(j, 1.0, "j", FailureReport("OOM", 0.95,
                                               pod="learner-j-0").to_doc())
    (doc,) = j.events
    assert "state" not in doc            # classification moves no machine
    assert doc["failure"]["category"] == "OOM"
    assert "FAILURE OOM" in doc["event"]


# ---------------------------------------------------------------------------
# FaultPlan validation
# ---------------------------------------------------------------------------
def test_fault_plan_validation():
    assert FaultPlan((Fault(kind="oom", job="j"),)).validate() is None
    assert FaultPlan((Fault(kind="gremlin", job="j"),)).validate()
    assert FaultPlan((Fault(kind="flaky_pod"),)).validate()   # no target
    assert FaultPlan((Fault(kind="straggler", job="j",
                            slow_factor=1.0),)).validate()


def test_platform_inject_rejects_invalid_plan():
    p = DLaaSPlatform(seed=9)
    with pytest.raises(ValueError):
        p.inject(FaultPlan((Fault(kind="gremlin", job="j"),)))


# ---------------------------------------------------------------------------
# bounded checkpoint fallback (the CKPT_CORRUPT repair primitive)
# ---------------------------------------------------------------------------
def test_fallback_one_deletes_only_the_corrupt_newest_generation():
    store = ObjectStore()
    ck = CheckpointManager(store, "fb")
    ck.save(10, {"w": np.arange(8.0)})
    ck.save(20, {"w": np.arange(8.0) + 1})
    for path in store.list_prefix(f"ckpt/fb/{20:012d}/blob/"):
        store.corrupt(path)
    assert ck.newest_invalid() == 20
    assert ck.fallback_one() == 10
    assert ck.steps() == [10]
    # idempotent: with everything valid it deletes nothing
    assert ck.newest_invalid() is None
    assert ck.fallback_one() == 10
    assert ck.steps() == [10]


# ---------------------------------------------------------------------------
# scheduler node exclusions (the POISONED_NODE repair primitive)
# ---------------------------------------------------------------------------
def test_scheduler_exclusions_are_per_job_and_clearable():
    p = DLaaSPlatform(seed=7)
    p.run(5)
    p.scheduler.exclude_node("j1", "node-0")
    assert p.scheduler.excluded_for("j1") == frozenset({"node-0"})
    assert p.scheduler.excluded_for("j2") == frozenset()
    p.scheduler.clear_exclusions("j1")
    assert p.scheduler.excluded_for("j1") == frozenset()


# ---------------------------------------------------------------------------
# SelfHealer bookkeeping
# ---------------------------------------------------------------------------
def _healer(budgets=None, policy="auto"):
    spec = SimpleNamespace(
        kind="train", max_restarts=5,
        train=SimpleNamespace(restart_budgets=budgets or {},
                              repair_policy=policy,
                              min_repair_confidence=0.6))
    return SelfHealer(_stub_platform(), "j", spec, "learner", n=2)


def test_budget_falls_back_to_max_restarts():
    h = _healer(budgets={"OOM": 1})
    assert h.budget_for("OOM") == 1
    assert h.budget_for("FLAKY_POD") == 5


def test_charges_accumulate_per_category():
    h = _healer()
    assert h.charge("FLAKY_POD") == 1
    assert h.charge("FLAKY_POD") == 2
    assert h.charge("OOM") == 1          # independent counter
    with pytest.raises(ValueError):
        h.charge("GREMLINS")


def test_expected_restarts_are_absorbed_once():
    h = _healer()
    h.expect_restart(1)
    assert h.absorb_expected(1)
    assert not h.absorb_expected(1)
    assert not h.absorb_expected(0)


def test_poison_incident_dedup_window():
    h = _healer()
    rep = FailureReport("POISONED_NODE", 0.85, node="node-3")
    assert not h.absorb_poison_incident(rep)
    h.note_poison_repaired("node-3")
    assert h.absorb_poison_incident(rep)
    h.platform.sim.now += SelfHealer.POISON_INCIDENT_S + 1
    assert not h.absorb_poison_incident(rep)


# ---------------------------------------------------------------------------
# end-to-end: per-category budgets are independent
# ---------------------------------------------------------------------------
def _submit_train(p, *, budgets, policy="auto", total_steps=400):
    h = p.submit(JobSpec(
        name="budget",
        resources=Resources(replicas=2, gpus_per_replica=1),
        max_restarts=50,
        train=TrainSpec(total_steps=total_steps, step_time_s=0.5,
                        checkpoint_interval_s=15.0,
                        restart_budgets=budgets, repair_policy=policy)))
    p.run(5)
    assert h.acked and h.job_id
    return h


def test_flaky_storm_exhausts_only_the_flaky_budget():
    """Repeated detail-free kills charge FLAKY_POD, never OOM; the job
    fails naming FLAKY_POD once ITS budget (2) is exceeded — nowhere near
    the envelope max_restarts of 50."""
    p = DLaaSPlatform(seed=21)
    p.run(10)
    h = _submit_train(p, budgets={"FLAKY_POD": 2, "OOM": 50})
    for _ in range(4):
        p.run(30)
        p.kill_pod(f"learner-{h.job_id}-0")
    assert p.run_until_terminal(h.job_id, timeout=600) == "FAILED"
    doc = p.client.status(h.job_id)
    by_cat = doc.get("failures_by_category", {})
    assert by_cat.get("FLAKY_POD", 0) == 3       # budget 2 + the fatal one
    assert by_cat.get("OOM", 0) == 0
    ev = [e["event"] for e in p.client.events(h.job_id)]
    assert any(e.startswith("FAILED: FLAKY_POD") for e in ev), ev


def test_oom_loop_exhausts_only_the_oom_budget():
    """Under restart-only policy nothing lowers the memory knob, so the
    armed OOM gate refires every incarnation: OOM budget (2) exhausts
    while the generous FLAKY_POD budget is untouched."""
    p = DLaaSPlatform(seed=22)
    p.run(10)
    h = _submit_train(p, budgets={"OOM": 2, "FLAKY_POD": 50},
                      policy="restart-only")
    p.inject(FaultPlan((Fault(kind="oom", at=p.sim.now, job=h.job_id,
                              learner=0, at_step=5),)))
    assert p.run_until_terminal(h.job_id, timeout=600) == "FAILED"
    doc = p.client.status(h.job_id)
    by_cat = doc.get("failures_by_category", {})
    assert by_cat.get("OOM", 0) == 3
    assert by_cat.get("FLAKY_POD", 0) == 0
    ev = [e["event"] for e in p.client.events(h.job_id)]
    assert any(e.startswith("FAILED: OOM") for e in ev), ev
    # restart-only: the safe-list repair must never have been applied
    assert not any(e.startswith("REPAIR ") for e in ev), ev


def test_oom_auto_repair_completes_within_budget():
    """With auto policy the reduce_memory repair halves the knob past the
    gate's clearing threshold, so the same fault that kills the
    restart-only job lets this one COMPLETE."""
    p = DLaaSPlatform(seed=23)
    p.run(10)
    h = _submit_train(p, budgets={"OOM": 5}, total_steps=40)
    p.inject(FaultPlan((Fault(kind="oom", at=p.sim.now, job=h.job_id,
                              learner=0, at_step=5),)))
    assert p.run_until_terminal(h.job_id, timeout=600) == "COMPLETED"
    ev = [e["event"] for e in p.client.events(h.job_id)]
    assert any("REPAIR reduce_memory" in e for e in ev), ev
