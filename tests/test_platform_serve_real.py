"""Real payloads on the platform: serve jobs drive the actual
ServingEngine inside their pods (claim-then-serve exactly-once, journal +
snapshots on the job volume, byte-identical recovery from a mid-stream
kill), dryrun jobs execute real compile cells, and log shipping goes
through ``ObjectStore.append`` (O(total) bytes, not O(n²))."""
import json

from repro.core import DLaaSPlatform
from repro.core.jobspec import (
    DryRunSpec, JobSpec, Resources, ServeSpec, SweepCell)
from repro.core.objectstore import ObjectStore


def boot(seed=0, **kw):
    p = DLaaSPlatform(seed=seed, **kw)
    p.run(10)            # core services come up
    return p


def _serve_spec(name, **kw):
    sv = dict(batch=2, prompt_len=16, gen=6, requests=4, reduced=True,
              real_compute=True, snapshot_every=2, request_time_s=0.5)
    sv.update(kw)
    replicas = sv.pop("replicas", 1)
    return JobSpec(name=name, kind="serve", framework="qwen3-0.6b",
                   resources=Resources(replicas=replicas),
                   serve=ServeSpec(**sv))


def _cos_responses(p, job_id, n_req):
    out = {}
    for r in range(n_req):
        key = f"cos/{job_id}/responses/{r}"
        assert p.objectstore.exists(key), f"request {r} never completed"
        out[r] = json.loads(p.objectstore.get(key).decode())["tokens"]
    return out


def _direct_responses(spec):
    """The same workload served directly by the engine (no platform)."""
    from repro.launch.engine import RealServePayload
    engine, requests = RealServePayload(spec).build()
    for r in requests:
        engine.submit(r)
    engine.run()
    return engine.responses


# ---------------------------------------------------------------------------
# Platform serve job with the real engine payload
# ---------------------------------------------------------------------------
def test_platform_serve_real_payload_smoke():
    """A kind=serve job with serve.real_compute runs the actual engine in
    its pod: the job completes, every response lands in the job's COS
    prefix, and the streams equal a direct (platform-free) engine run."""
    p = boot(seed=31)
    spec = _serve_spec("real-serve")
    h = p.submit(spec)
    p.run(5)
    assert h.acked
    assert p.run_until_terminal(h.job_id, timeout=600) == "COMPLETED"

    got = _cos_responses(p, h.job_id, spec.serve.requests)
    assert got == _direct_responses(spec)
    vol = p.volumes.get(f"vol-{h.job_id}")
    assert vol is None                       # torn down after completion
    assert "server 0 up" in p.client.logs(h.job_id, 0)


def test_platform_serve_kill_mid_stream_recovers_byte_identical():
    """The headline dependability scenario: kill the server pod while it
    is mid-stream.  The Guardian restarts it, the engine restores from
    the volume snapshot + journal replay, and the shipped token streams
    are byte-identical to an uninterrupted platform run — exactly-once,
    nothing lost, nothing re-served."""
    # slow virtual pacing (request_time_s) widens the mid-stream window so
    # the poll below reliably lands between the first and last completion
    spec = _serve_spec("real-serve-kill", requests=6, request_time_s=2.0)

    # golden: uninterrupted platform run
    pa = boot(seed=32)
    ha = pa.submit(spec)
    pa.run(5)
    assert pa.run_until_terminal(ha.job_id, timeout=600) == "COMPLETED"
    golden = _cos_responses(pa, ha.job_id, spec.serve.requests)

    # victim: same spec, killed once the stream is flowing
    pb = boot(seed=32)
    hb = pb.submit(spec)
    pb.run(5)
    assert hb.acked
    caught = False
    for _ in range(600):
        pb.run(0.2)
        vol = pb.volumes.get(f"vol-{hb.job_id}")
        if vol is not None and 0 < vol.read("served", 0) \
                < spec.serve.requests:
            caught = True
            break
    assert caught, "never caught the job mid-stream"
    assert pb.kill_pod(f"server-{hb.job_id}-0")

    assert pb.run_until_terminal(hb.job_id, timeout=900) == "COMPLETED"
    assert _cos_responses(pb, hb.job_id, spec.serve.requests) == golden
    assert pb.client.get(hb.job_id)["restarts"] >= 1
    logs = pb.client.logs(hb.job_id, 0)
    assert "engine restored" in logs         # recovery actually exercised
    events = [e["event"] for e in pb.client.events(hb.job_id)]
    assert any("RESTARTED" in e for e in events)


def test_platform_serve_gang_exactly_once():
    """Two replicas share the claim counter: between them every request is
    served exactly once, each response matches the direct engine run
    (per-request greedy decode is batch-composition independent), and the
    shared served counter equals the request count."""
    spec = _serve_spec("real-serve-gang", requests=6, replicas=2)
    p = boot(seed=33)
    h = p.submit(spec)
    p.run(5)
    assert p.run_until_terminal(h.job_id, timeout=900) == "COMPLETED"
    got = _cos_responses(p, h.job_id, spec.serve.requests)
    assert got == _direct_responses(spec)
    # both replicas came up and shipped logs through their own COS keys
    assert "server 0 up" in p.client.logs(h.job_id, 0)
    assert "server 1 up" in p.client.logs(h.job_id, 1)


def test_platform_serve_ships_prefill_completed_requests():
    """gen_len == 1 requests finish inside admit() (the prefill token IS
    the response) — their responses must still ship to COS.  gen=2 draws
    gen_lens from {1, 2}, so the workload always contains such requests."""
    p = boot(seed=38)
    spec = _serve_spec("gen-one", gen=2, requests=5)
    h = p.submit(spec)
    p.run(5)
    assert p.run_until_terminal(h.job_id, timeout=600) == "COMPLETED"
    got = _cos_responses(p, h.job_id, spec.serve.requests)
    assert got == _direct_responses(spec)
    assert any(len(t) == 1 for t in got.values()), \
        "workload never exercised a gen_len==1 request"


def test_gateway_rejects_unbuildable_real_serve():
    """Engine-constructor failures (page budget too small for even one
    request) are rejected at the API gateway, not discovered inside the
    pod where they would burn the job's whole restart budget — and never
    leak a SystemExit into the simulator."""
    p = boot(seed=37)
    h = p.submit(_serve_spec("bad-budget", page_budget=1))
    p.run(5)
    assert h.rejected and "page_budget" in h.rejected, h.rejected
    h2 = p.submit(_serve_spec("ok", requests=0))
    p.run(5)
    assert h2.rejected and "bounded request count" in h2.rejected


# ---------------------------------------------------------------------------
# Dryrun jobs execute real compile cells through the payload seam
# ---------------------------------------------------------------------------
def test_platform_dryrun_real_cells():
    """dryrun.real_compute routes each sweep cell through the payload's
    ``run_cell`` (really ``launch.dryrun.run_cell`` lower+compile; the
    test injects a recorded runner via the registered-payload override so
    it stays fast) and publishes the REAL artifact record to COS."""
    from repro.launch.engine import RealDryRunPayload

    p = boot(seed=34)
    spec = JobSpec(
        name="real-dryrun", kind="dryrun", framework="qwen3-0.6b",
        dryrun=DryRunSpec(cells=(SweepCell("qwen3-0.6b", "decode_32k"),),
                          real_compute=True))
    h = p.submit(spec)
    p.run(5)
    assert h.acked
    ran = []

    def fake_cell(cell):
        ran.append((cell.arch, cell.shape))
        return {"ok": True, "lower_s": 0.5, "compile_s": 1.5,
                "memory": {"temp_size_in_bytes": 1 << 20}}

    p.register_payload(h.job_id, RealDryRunPayload(spec, run_cell=fake_cell))
    assert p.run_until_terminal(h.job_id, timeout=600) == "COMPLETED"
    assert ran == [("qwen3-0.6b", "decode_32k")]
    key = f"cos/{h.job_id}/dryrun/qwen3-0.6b__decode_32k__16x16.json"
    rec = json.loads(p.objectstore.get(key).decode())
    assert rec["compile_s"] == 1.5           # the real record, not virtual
    assert rec["arch"] == "qwen3-0.6b" and rec["job"] == h.job_id


def test_virtual_serve_and_dryrun_unchanged():
    """Without real_compute the virtual-time loops still run — the default
    stays fast and jax-free for platform tests."""
    p = boot(seed=35)
    h = p.submit(JobSpec(name="virt", kind="serve",
                         framework="paper-overhead-100m",
                         serve=ServeSpec(requests=5, request_time_s=0.2)))
    p.run(5)
    assert p.run_until_terminal(h.job_id, timeout=300) == "COMPLETED"
    vol_served = [e["event"] for e in p.client.events(h.job_id)]
    assert any("COMPLETED" in e for e in vol_served)
    # no engine artifacts: the virtual loop never ships responses
    assert not p.objectstore.list_prefix(f"cos/{h.job_id}/responses/")


# ---------------------------------------------------------------------------
# ObjectStore.append — the O(n²) log-shipping fix
# ---------------------------------------------------------------------------
def test_objectstore_append_linear_bytes():
    """Appending n lines writes O(total) bytes, not O(n²): the old
    read-modify-write shipped the whole blob again per line."""
    os_ = ObjectStore()
    lines = [f"line {i:04d}\n".encode() for i in range(200)]
    for ln in lines:
        os_.append("cos/j/logs/0", ln)
    total = sum(len(ln) for ln in lines)
    assert os_.get("cos/j/logs/0") == b"".join(lines)
    assert os_.bytes_written == total        # linear, not quadratic
    assert isinstance(os_.get("cos/j/logs/0"), bytes)


def test_objectstore_append_interops_with_put_and_corrupt():
    os_ = ObjectStore()
    os_.put("k", b"abc")
    os_.append("k", b"def")
    assert os_.get("k") == b"abcdef"
    os_.corrupt("k", 0)
    assert os_.get("k") != b"abcdef"
    os_.put("k", b"fresh")                   # put replaces appended blob
    assert os_.get("k") == b"fresh"
    assert os_.list_prefix("k") == ["k"]


def test_ship_log_routes_through_append():
    """Server pods ship logs via ObjectStore.append — per-line cost is the
    line, and ApiClient.logs still reads the same key."""
    from repro.core.server import _ship_log

    p = boot(seed=36)
    h = p.submit(JobSpec(name="logs", kind="serve",
                         framework="paper-overhead-100m",
                         serve=ServeSpec(requests=3)))
    p.run(5)
    before = p.objectstore.bytes_written
    for i in range(50):
        _ship_log(p, h.job_id, 0, f"x{i}")
    delta = p.objectstore.bytes_written - before
    assert delta == sum(len(f"x{i}") + 1 for i in range(50))
    assert p.run_until_terminal(h.job_id, timeout=300) == "COMPLETED"
    assert "x49" in p.client.logs(h.job_id, 0)
