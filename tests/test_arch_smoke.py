"""Per-architecture smoke tests (required): reduced same-family config,
one forward + one train step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import RunConfig, get_config
from repro.models.layers import Ctx
from repro.models.model import forward
from repro.models.params import init_params
from repro.train.steps import init_train_state, make_train_step

ASSIGNED = [
    "recurrentgemma-9b", "rwkv6-7b", "qwen3-0.6b", "gemma2-9b",
    "mistral-large-123b", "qwen2.5-32b", "seamless-m4t-medium",
    "internvl2-76b", "deepseek-v2-236b", "granite-moe-1b-a400m",
]

B, S = 2, 64


def make_batch(cfg, key=1, with_labels=False):
    batch = {"tokens": jax.random.randint(
        jax.random.key(key), (B, S), 0, cfg.vocab_size)}
    if with_labels:
        batch["labels"] = jax.random.randint(
            jax.random.key(key + 1), (B, S), 0, cfg.vocab_size)
    if cfg.is_encoder_decoder:
        batch["src_embeds"] = 0.02 * jax.random.normal(
            jax.random.key(3), (B, 16, cfg.d_model))
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = 0.02 * jax.random.normal(
            jax.random.key(4), (B, cfg.frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_shapes_no_nans(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(0))
    logits, cache, aux = forward(cfg, params, make_batch(cfg),
                                 Ctx(dtype=jnp.float32), mode="train")
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))
    if cfg.is_moe:
        assert float(aux) > 0.0          # load-balance loss is live


@pytest.mark.parametrize("arch", ASSIGNED)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    run = RunConfig(num_microbatches=2, remat_policy="dots",
                    warmup_steps=2, total_steps=10)
    state = init_train_state(cfg, jax.random.key(0), run)
    step = jax.jit(make_train_step(cfg, ctx=Ctx(dtype=jnp.float32), run=run))
    batch = make_batch(cfg, with_labels=True)
    new_state, metrics = step(state, batch)
    assert not bool(jnp.isnan(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    assert int(new_state["step"]) == 1
    # params actually changed
    p0 = jax.tree.leaves(state["params"])[0]
    p1 = jax.tree.leaves(new_state["params"])[0]
    assert not bool(jnp.allclose(p0, p1))


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "gemma2-9b"])
def test_bf16_compute_path(arch):
    """Mixed precision: bf16 matrices, fp32 master/logits — finite loss."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(0))
    logits, _, _ = forward(cfg, params, make_batch(cfg),
                           Ctx(dtype=jnp.bfloat16), mode="train")
    assert logits.dtype == jnp.float32        # loss path is always fp32
    assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())
