"""Elastic re-meshing plan properties."""
from hypothesis import given, settings, strategies as st

from repro.core.elastic import ElasticPolicy


def test_decide():
    pol = ElasticPolicy(min_world=2)
    assert pol.decide(8, 8) == 8
    assert pol.decide(8, 5) == 5
    assert pol.decide(8, 1) is None


@settings(max_examples=50, deadline=None)
@given(old=st.integers(1, 64), new=st.integers(1, 64),
       batch=st.integers(1, 4096))
def test_remesh_plan_properties(old, new, batch):
    plan = ElasticPolicy().remesh_plan(old, new, batch)
    # every old shard is owned by exactly one survivor
    owned = sorted(s for shards in plan.shard_map.values() for s in shards)
    assert owned == list(range(old))
    # batch conserved and balanced within 1
    per = list(plan.per_learner_batch.values())
    assert sum(per) == batch
    assert max(per) - min(per) <= 1
