"""Regression tests for the recovery-path fixes (ISSUE 2 satellites):

* rejoin-mode recovery with ``real_compute`` must restore parameters (from
  the peers' volume snapshot or the latest checkpoint) before stepping;
* the chief's checkpoint save window must not read as a dead heartbeat
  (no spurious gang stall);
* top-k gradient compression must stay top-k on sparse tensors (the
  zero-threshold degeneration sent everything with zero residual).
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core.learner as learner_mod
from repro.configs import RunConfig, get_config
from repro.core import DLaaSPlatform, JobManifest
from repro.core.learner import RealPayload
from repro.data.pipeline import SyntheticLMData
from repro.dist.compression import (
    CompressionConfig,
    _topk_leaf,
    compress_grads,
    init_error_buffers,
)
from repro.models.layers import Ctx
from repro.train.steps import init_train_state, make_train_step


def make_payload(cfg, run):
    data = SyntheticLMData(cfg.vocab_size, 32, 4, seed=0)
    step = jax.jit(make_train_step(cfg, Ctx(dtype=jnp.float32), run))
    return RealPayload(
        make_state=lambda: init_train_state(cfg, jax.random.key(0), run),
        train_step=step, data=data)


# ---------------------------------------------------------------------------
# rejoin + real_compute end-to-end restore
# ---------------------------------------------------------------------------
def test_rejoin_real_compute_restores_parameters():
    """Kill a real-compute learner in rejoin mode AND wipe its in-memory
    state (a restarted container has no parameters).  Pre-fix the rejoin
    branch never called payload.restore, so the first payload.step()
    crashed on state=None and the job failed; now it must refetch the
    peers' snapshot from the volume and complete with loss continuity."""
    cfg = get_config("paper-overhead-100m").reduced()
    run = RunConfig(learning_rate=2e-3, warmup_steps=5, total_steps=60)

    p = DLaaSPlatform(seed=21)
    p.run(10)
    h = p.submit(JobManifest(name="rejoin-real", learners=1, total_steps=60,
                             step_time_s=0.5, checkpoint_interval_s=10,
                             real_compute=True,
                             extras={"recovery_mode": "rejoin"}))
    p.run(5)
    assert h.acked
    payload = make_payload(cfg, run)
    p.register_payload(h.job_id, payload)

    p.run(40)                                  # training underway
    vol = p.volumes.get(f"vol-{h.job_id}")
    assert vol.read("last_loss") is not None
    step_before = vol.read("progress/0")["step"]
    assert step_before > 0
    assert p.kill_pod(f"learner-{h.job_id}-0")
    payload.state = None                       # restarted pod: memory gone

    assert p.run_until_terminal(h.job_id, timeout=900) == "COMPLETED"
    logs = p.client.logs(h.job_id, 0)
    assert "rejoined at step" in logs
    # restored near the peers' progress (snapshot), not from step 0
    assert payload.state is not None
    assert int(payload.state["step"]) == run.total_steps
    assert f"rejoined at step {step_before}" in logs or \
        f"rejoined at step {step_before - 1}" in logs, logs[-300:]
    # loss continuity: still below the untrained ~ln(V) starting point
    assert float(vol.read("last_loss")) < np.log(cfg.vocab_size)


def test_rejoin_real_compute_falls_back_to_checkpoint():
    """Without a volume snapshot, rejoin must restore the latest checkpoint
    and resume from the *checkpoint's* step — not silently jump-start to
    the peers' step with stale (or no) parameters."""
    cfg = get_config("paper-overhead-100m").reduced()
    run = RunConfig(learning_rate=2e-3, warmup_steps=5, total_steps=40)

    p = DLaaSPlatform(seed=7)
    p.run(10)
    h = p.submit(JobManifest(name="rejoin-ckpt", learners=1, total_steps=40,
                             step_time_s=0.5, checkpoint_interval_s=8,
                             real_compute=True,
                             extras={"recovery_mode": "rejoin"}))
    p.run(5)
    assert h.acked
    payload = make_payload(cfg, run)
    p.register_payload(h.job_id, payload)

    p.run(30)
    vol = p.volumes.get(f"vol-{h.job_id}")
    assert p.kill_pod(f"learner-{h.job_id}-0")
    payload.state = None
    vol.files.pop("param_snapshot", None)      # peers' snapshot unavailable

    assert p.run_until_terminal(h.job_id, timeout=900) == "COMPLETED"
    assert "rejoined at step" in p.client.logs(h.job_id, 0)
    assert int(payload.state["step"]) == run.total_steps


# ---------------------------------------------------------------------------
# no spurious stall across a chief checkpoint save
# ---------------------------------------------------------------------------
def test_no_peer_stall_across_chief_save(monkeypatch):
    """Make checkpoint uploads long relative to the heartbeat allowance
    (3×step_time + 2s): peers must honor the chief's save lease instead of
    reading the quiet window as a dead peer and stalling the gang."""
    monkeypatch.setattr(learner_mod, "SAVE_TIME", (5.0, 5.0))
    p = DLaaSPlatform(seed=3)
    p.run(10)
    h = p.submit(JobManifest(name="savewin", learners=3, total_steps=40,
                             step_time_s=0.5, checkpoint_interval_s=2))
    p.run(5)
    assert h.acked
    vol = p.volumes.get(f"vol-{h.job_id}")
    # let every learner start and take its first steps — staggered pod
    # startup legitimately reads as stale until the first heartbeats land
    for _ in range(200):
        p.run(1)
        prs = [vol.read(f"progress/{j}") for j in range(3)]
        if all(pr is not None and pr["step"] > 0 for pr in prs):
            break
    else:
        raise AssertionError("learners never started")

    stalls = []
    orig = vol.write

    def spy(path, data):
        if isinstance(data, dict) and data.get("stalled"):
            stalls.append((path, p.sim.now))
        orig(path, data)

    vol.write = spy
    assert p.run_until_terminal(h.job_id, timeout=900) == "COMPLETED"
    assert stalls == [], stalls[:5]


# ---------------------------------------------------------------------------
# top-k compression on sparse tensors
# ---------------------------------------------------------------------------
def test_topk_sparse_sends_at_most_k():
    """A tensor whose (1-ratio) magnitude quantile is 0 used to make the
    threshold 0 and send *every* entry (identity, zero residual)."""
    cfg = CompressionConfig(kind="topk", topk_ratio=0.05)
    t = jnp.zeros((1000,), jnp.float32).at[:10].set(
        jnp.arange(1, 11, dtype=jnp.float32))     # 99% zeros
    sent = _topk_leaf(t, cfg)
    k = max(1, round(t.size * cfg.topk_ratio))    # 50
    n_sent = int(jnp.count_nonzero(sent))
    assert n_sent <= k, n_sent
    assert n_sent == 10                            # zeros are never "sent"
    np.testing.assert_array_equal(np.asarray(sent[:10]), np.asarray(t[:10]))


def test_topk_dense_exactly_k_with_ties():
    cfg = CompressionConfig(kind="topk", topk_ratio=0.1)
    t = jnp.ones((100,), jnp.float32)              # all tied
    sent = _topk_leaf(t, cfg)
    assert int(jnp.count_nonzero(sent)) == 10      # ties broken, not >= k


def test_topk_error_feedback_carries_residual():
    """On a sparse gradient the residual must carry the unsent entries —
    the degenerate identity had err == 0 forever."""
    cfg = CompressionConfig(kind="topk", topk_ratio=0.05)   # k = 10
    g = {"w": jnp.zeros((200,), jnp.float32).at[::10].set(0.01)
              .at[0].set(5.0)}                              # 20 nonzeros
    err = init_error_buffers(g)
    sent, err = compress_grads(g, err, cfg)
    # cumulative transmitted + residual == cumulative gradient (exact)
    np.testing.assert_allclose(np.asarray(sent["w"] + err["w"]),
                               np.asarray(g["w"]), rtol=0, atol=1e-7)
    assert float(jnp.abs(err["w"]).sum()) > 0               # unsent carried
    assert int(jnp.count_nonzero(sent["w"])) <= 10
