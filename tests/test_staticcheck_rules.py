"""Per-rule fixtures for the staticcheck AST engine: every rule has a
true-positive fixture (fires), a suppressed fixture (marker drops it) and
a clean fixture (no finding) — plus engine-level suppression/baseline
semantics."""
import json
import textwrap

from repro.staticcheck.engine import (
    Baseline, Finding, all_rules, check_file, render_json, run_files)


def write(tmp_path, rel, src):
    """Write a fixture under a repo-shaped path (rule scopes are path
    substrings, so e.g. SC101 needs a file under ``repro/core/``)."""
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(src))
    return p


def findings_for(tmp_path, rel, src, rule_id=None):
    out = check_file(write(tmp_path, rel, src), all_rules())
    if rule_id is not None:
        out = [f for f in out if f.rule == rule_id]
    return out


# ---------------------------------------------------------------------------
# SC101 — SystemExit / sys.exit in pod-reachable code
# ---------------------------------------------------------------------------
def test_sc101_true_positive(tmp_path):
    src = """\
        import sys
        def pod():
            raise SystemExit(1)
        def other():
            sys.exit(2)
    """
    fs = findings_for(tmp_path, "repro/core/mod.py", src, "SC101")
    assert len(fs) == 2
    assert {f.line for f in fs} == {3, 5}


def test_sc101_suppressed(tmp_path):
    src = """\
        def pod():
            raise SystemExit(1)  # staticcheck: ignore[SC101]
    """
    assert not findings_for(tmp_path, "repro/core/mod.py", src, "SC101")


def test_sc101_clean_outside_scope(tmp_path):
    # launch CLIs are process boundaries: SystemExit is correct there
    src = """\
        def main():
            raise SystemExit(1)
    """
    assert not findings_for(tmp_path, "repro/launch/serve.py", src, "SC101")
    assert not findings_for(
        tmp_path, "repro/core/mod.py",
        "def pod():\n    raise ValueError('bad spec')\n", "SC101")


# ---------------------------------------------------------------------------
# SC102 — builtin hash() near persisted state
# ---------------------------------------------------------------------------
def test_sc102_true_positive(tmp_path):
    src = """\
        def key_for(prefix):
            return hash(tuple(prefix))
    """
    fs = findings_for(tmp_path, "repro/launch/mod.py", src, "SC102")
    assert len(fs) == 1 and "salted" in fs[0].message


def test_sc102_suppressed(tmp_path):
    src = """\
        def key_for(prefix):
            # staticcheck: ignore[SC102]
            return hash(tuple(prefix))
    """
    assert not findings_for(tmp_path, "repro/launch/mod.py", src, "SC102")


def test_sc102_clean(tmp_path):
    src = """\
        import hashlib
        def key_for(prefix):
            return hashlib.blake2b(bytes(prefix), digest_size=16).hexdigest()
    """
    assert not findings_for(tmp_path, "repro/launch/mod.py", src, "SC102")


# ---------------------------------------------------------------------------
# SC103 — ObjectStore get+put read-modify-write
# ---------------------------------------------------------------------------
def test_sc103_direct_rmw(tmp_path):
    src = """\
        def ship(store, key, line):
            store.put(key, store.get(key) + line)
    """
    fs = findings_for(tmp_path, "repro/core/mod.py", src, "SC103")
    assert len(fs) == 1 and "read-modify-write" in fs[0].message


def test_sc103_loop_rmw(tmp_path):
    src = """\
        def ship(store, key, lines):
            for line in lines:
                old = store.get(key)
                store.put(key, old + line)
    """
    fs = findings_for(tmp_path, "repro/core/mod.py", src, "SC103")
    assert len(fs) == 1


def test_sc103_suppressed_and_clean(tmp_path):
    sup = """\
        def ship(store, key, line):
            store.put(key, store.get(key) + line)  # staticcheck: ignore[SC103]
    """
    assert not findings_for(tmp_path, "repro/core/mod.py", sup, "SC103")
    clean = """\
        def ship(store, key, line):
            store.append(key, line)
        def disjoint(store, key, line):
            if store.get(key) is None:
                store.put("other", line)
    """
    assert not findings_for(tmp_path, "repro/core/mod.py", clean, "SC103")


# ---------------------------------------------------------------------------
# SC104 — module-global mutable counter in core/
# ---------------------------------------------------------------------------
def test_sc104_true_positive(tmp_path):
    src = """\
        _NEXT_ID = 0
        def new_id():
            global _NEXT_ID
            _NEXT_ID += 1
            return _NEXT_ID
    """
    fs = findings_for(tmp_path, "repro/core/mod.py", src, "SC104")
    assert len(fs) == 1 and "bump_counter" in fs[0].message


def test_sc104_suppressed(tmp_path):
    src = """\
        _NEXT_ID = 0
        def new_id():
            global _NEXT_ID
            _NEXT_ID += 1  # staticcheck: ignore[SC104]
            return _NEXT_ID
    """
    assert not findings_for(tmp_path, "repro/core/mod.py", src, "SC104")


def test_sc104_clean(tmp_path):
    # constant module ints without global-mutation are fine, and the rule
    # is scoped to core/ only
    src = "LIMIT = 8\ndef f():\n    return LIMIT\n"
    assert not findings_for(tmp_path, "repro/core/mod.py", src, "SC104")
    bad = """\
        _N = 0
        def f():
            global _N
            _N += 1
    """
    assert not findings_for(tmp_path, "repro/launch/mod.py", bad, "SC104")


# ---------------------------------------------------------------------------
# SC105 — wall clock in sim-driven code
# ---------------------------------------------------------------------------
def test_sc105_true_positive(tmp_path):
    src = """\
        import time, datetime
        def stamp():
            return time.time(), datetime.datetime.now()
    """
    fs = findings_for(tmp_path, "repro/launch/mod.py", src, "SC105")
    assert len(fs) == 2


def test_sc105_suppressed(tmp_path):
    src = """\
        import time
        def stamp():
            return time.time()  # staticcheck: ignore[SC105]
    """
    assert not findings_for(tmp_path, "repro/launch/mod.py", src, "SC105")


def test_sc105_clean_interval_clocks(tmp_path):
    src = """\
        import time
        def bench():
            t0 = time.perf_counter()
            return time.perf_counter() - t0, time.monotonic()
    """
    assert not findings_for(tmp_path, "repro/core/mod.py", src, "SC105")
    # out of scope: kernels may time however they like
    assert not findings_for(
        tmp_path, "repro/kernels/mod.py",
        "import time\ndef f():\n    return time.time()\n", "SC105")


# ---------------------------------------------------------------------------
# SC106 — broad excepts
# ---------------------------------------------------------------------------
def test_sc106_true_positive(tmp_path):
    src = """\
        def f():
            try:
                g()
            except:
                pass
        def h():
            try:
                g()
            except BaseException:
                pass
        def i():
            try:
                g()
            except Exception:
                pass
    """
    fs = findings_for(tmp_path, "repro/core/mod.py", src, "SC106")
    assert len(fs) == 3
    assert sum("SystemExit" in f.message for f in fs) == 2


def test_sc106_suppressed(tmp_path):
    src = """\
        def f():
            try:
                g()
            except Exception:  # staticcheck: ignore[SC106]
                pass
    """
    assert not findings_for(tmp_path, "repro/core/mod.py", src, "SC106")


def test_sc106_clean_reraise_or_use(tmp_path):
    src = """\
        def f(log):
            try:
                g()
            except ValueError:
                pass
            try:
                g()
            except Exception:
                cleanup()
                raise
            try:
                g()
            except Exception as e:
                log(f"failed: {e}")
    """
    assert not findings_for(tmp_path, "repro/core/mod.py", src, "SC106")


# ---------------------------------------------------------------------------
# Engine semantics
# ---------------------------------------------------------------------------
def test_sc100_unparseable(tmp_path):
    fs = findings_for(tmp_path, "repro/core/bad.py", "def f(:\n")
    assert [f.rule for f in fs] == ["SC100"]


def test_bare_ignore_suppresses_all(tmp_path):
    src = """\
        import time
        def f():
            return time.time()  # staticcheck: ignore
    """
    assert not findings_for(tmp_path, "repro/core/mod.py", src)


def test_suppression_on_line_above(tmp_path):
    src = """\
        import time
        def f():
            # staticcheck: ignore[SC105]
            return time.time()
    """
    assert not findings_for(tmp_path, "repro/core/mod.py", src, "SC105")


def test_suppression_is_per_rule(tmp_path):
    src = """\
        import time
        def f():
            return time.time()  # staticcheck: ignore[SC101]
    """
    assert findings_for(tmp_path, "repro/core/mod.py", src, "SC105")


def test_run_files_walks_tree(tmp_path):
    write(tmp_path, "repro/core/a.py", "import time\nt = time.time()\n")
    write(tmp_path, "repro/core/b.py", "x = 1\n")
    fs = run_files([str(tmp_path)])
    assert [f.rule for f in fs] == ["SC105"]


def test_baseline_multiset_and_ratchet(tmp_path):
    f = Finding("SC105", "repro/core/a.py", 3, "time.time() ...")
    bl = Baseline([f.fingerprint()])
    # one entry absorbs exactly one live finding; a second is NEW
    new, old = bl.apply([f, Finding("SC105", "repro/core/a.py", 9,
                                    "time.time() ...")])
    assert len(new) == 1 and len(old) == 1
    # entry no longer firing -> stale (must be deleted: burn-down ratchet)
    assert bl.stale([]) == [f.fingerprint()]
    assert bl.stale([f]) == []


def test_baseline_save_load_roundtrip(tmp_path):
    f = Finding("SC103", "repro/core/h.py", 10, "get+put")
    path = tmp_path / "baseline.json"
    Baseline.save(path, [f])
    doc = json.loads(path.read_text())
    assert doc["findings"] == [f.fingerprint()]
    bl = Baseline.load(path)
    assert bl.apply([f]) == ([], [f])
    assert Baseline.load(tmp_path / "missing.json").apply([f])[0] == [f]


def test_render_json_is_machine_readable():
    f = Finding("SC101", "repro/core/x.py", 2, "raise SystemExit")
    doc = json.loads(render_json([f]))
    assert doc == [{"rule": "SC101", "path": "repro/core/x.py", "line": 2,
                    "message": "raise SystemExit"}]
