"""jit'd model-facing wrappers around the Pallas kernels.

These accept the model's tensor layouts, handle padding to block multiples,
and select interpret mode automatically off-TPU (the brief's validation
path: kernel bodies execute in Python on CPU, compiled on real TPUs).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.paged_attention import (
    mla_paged_decode_attention as _mla_paged,
    paged_decode_attention as _paged,
)
from repro.kernels.rglru_scan import rglru_scan as _rglru
from repro.kernels.rwkv6_wkv import wkv6 as _wkv6


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "scale", "causal", "window", "logit_cap", "q_blk", "kv_blk"))
def flash_attention_bshd(
    q: jax.Array,          # (B, S, H, hd)
    k: jax.Array,          # (B, S, K, hd)
    v: jax.Array,          # (B, S, K, hd)
    *,
    scale: float,
    causal: bool = True,
    window: int = 0,
    logit_cap: float = 0.0,
    q_blk: int = 512,
    kv_blk: int = 512,
) -> jax.Array:
    B, S, H, hd = q.shape
    K = k.shape[2]
    group = H // K
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    out = _flash(qf, kf, vf, group=group, scale=scale, causal=causal,
                 window=window, logit_cap=logit_cap,
                 q_blk=min(q_blk, S), kv_blk=min(kv_blk, S),
                 interpret=_interpret())
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("scale", "logit_cap"))
def paged_decode_bhd(
    q: jax.Array,            # (B, 1, H, hd) — one new token per sequence
    k_pages: jax.Array,      # (P, K, ps, hd) shared physical pool
    v_pages: jax.Array,      # (P, K, ps, hd)
    page_table: jax.Array,   # (B, pps) int32; -1 = unallocated
    pos_q: jax.Array,        # (B,) int32; -1 = inactive slot
    *,
    scale: float,
    logit_cap: float = 0.0,
) -> jax.Array:
    """Model-layout wrapper for the paged flash-decode kernel: regroup the
    q heads per kv head, run the kernel (interpret mode off-TPU), ungroup."""
    B, _, H, hd = q.shape
    K = k_pages.shape[1]
    qg = q.reshape(B, K, H // K, hd)
    out = _paged(qg, k_pages, v_pages, page_table.astype(jnp.int32),
                 pos_q.astype(jnp.int32), scale=scale, logit_cap=logit_cap,
                 interpret=_interpret())
    return out.reshape(B, 1, H, hd)


@functools.partial(jax.jit, static_argnames=("scale",))
def mla_paged_decode_bhd(
    q_lat: jax.Array,        # (B, H, lora + rd) absorbed latent query
    ckv_pages: jax.Array,    # (P, ps, lora) shared latent pool
    krope_pages: jax.Array,  # (P, ps, rd) shared rope-key pool
    page_table: jax.Array,   # (B, pps) int32; -1 = unallocated
    pos_q: jax.Array,        # (B,) int32; -1 = inactive slot
    *,
    scale: float,
) -> jax.Array:
    """Model-layout wrapper for the MLA latent flash-decode kernel;
    returns the latent context (B, H, lora) — the caller expands it
    through W_vc (interpret mode off-TPU)."""
    return _mla_paged(q_lat, ckv_pages, krope_pages,
                      page_table.astype(jnp.int32), pos_q.astype(jnp.int32),
                      scale=scale, interpret=_interpret())


@jax.jit
def rglru_scan_bsr(log_a: jax.Array, b: jax.Array,
                   h0: Optional[jax.Array] = None) -> jax.Array:
    """(B,S,R) fp32 inputs; returns the h sequence (B,S,R) fp32."""
    B, S, R = log_a.shape
    if h0 is None:
        h0 = jnp.zeros((B, R), jnp.float32)
    t_blk = 16
    pad = (-S) % t_blk
    if pad:
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    out = _rglru(log_a.astype(jnp.float32), b.astype(jnp.float32),
                 h0.astype(jnp.float32), t_blk=t_blk,
                 interpret=_interpret())
    return out[:, :S]


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6_bshn(r: jax.Array, k: jax.Array, v: jax.Array, lw: jax.Array,
              u: jax.Array, s0: jax.Array, *, chunk: int = 32
              ) -> Tuple[jax.Array, jax.Array]:
    """Model layout: r/k/v/lw (B,S,H,N), u (H,N), s0 (B,H,N,N).
    Returns (o (B,S,H,N), s_final (B,H,N,N))."""
    B, S, H, N = r.shape
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, N)
    rf, kf, vf, lwf = fold(r), fold(k), fold(v), fold(lw.astype(jnp.float32))
    uf = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, 1, N)
    s0f = s0.reshape(B * H, N, N).astype(jnp.float32)
    pad = (-S) % chunk
    if pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        rf, kf, vf, lwf = z(rf), z(kf), z(vf), z(lwf)
    o, s_fin = _wkv6(rf, kf, vf, lwf, uf, s0f, chunk=chunk,
                     interpret=_interpret())
    o = o[:, :S].reshape(B, H, S, N).transpose(0, 2, 1, 3)
    return o, s_fin.reshape(B, H, N, N)
