"""jax-version seams shared by the Pallas TPU kernels."""
import jax.experimental.pallas.tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both.
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
