"""RWKV6 WKV for TPU (Pallas): chunked linear attention with data-dependent
per-channel decay; the (N, N) state lives in VMEM scratch across chunks.

    o_t = r_t · S_{t-1} + (r_t · (u ⊙ k_t)) v_t
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ,   w_t = exp(lw_t), lw ≤ 0

Grid = (B·H, n_chunks), chunks sequential (minormost).  Per chunk the
intra-chunk pairwise decays are computed in log space — every exp argument
is ≤ 0 so no rescaling is needed.  VMEM per step with L=32, N=64 (fp32):
r/k/v/lw 4·L·N + decay L·L·N + state N·N ≈ 0.3 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels.layout import KernelLayout, SpecDesc


def wkv_layout(BH: int, S: int, N: int, chunk: int) -> KernelLayout:
    """Grid layout of :func:`wkv6` — the single source of truth the
    pallas_call is built from and ``staticcheck`` abstractly checks."""
    seq_map = lambda bh, ci: (bh, ci, 0)
    head_map = lambda bh, ci: (bh, 0, 0)
    return KernelLayout(
        name="rwkv6_wkv",
        grid=(BH, S // chunk),
        in_specs=(
            SpecDesc("r", (BH, S, N), (1, chunk, N), seq_map),
            SpecDesc("k", (BH, S, N), (1, chunk, N), seq_map),
            SpecDesc("v", (BH, S, N), (1, chunk, N), seq_map),
            SpecDesc("lw", (BH, S, N), (1, chunk, N), seq_map),
            SpecDesc("u", (BH, 1, N), (1, 1, N), head_map),
            SpecDesc("s0", (BH, N, N), (1, N, N), head_map),
        ),
        out_specs=(
            SpecDesc("o", (BH, S, N), (1, chunk, N), seq_map),
            SpecDesc("s_out", (BH, N, N), (1, N, N), head_map),
        ),
        scratch=(((N, N), jnp.float32),),
        dimension_semantics=("parallel", "arbitrary"),
    )


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                o_ref, sout_ref, state, *, L: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state[...] = s0_ref[0]

    r = r_ref[0].astype(jnp.float32)               # (L, N)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0]                                 # (L, N) fp32, ≤ 0
    u = u_ref[0].astype(jnp.float32)               # (1, N)
    s = state[...]                                 # (N, N)

    clw = jnp.cumsum(lw, axis=0)                   # inclusive
    clw_ex = clw - lw                              # exclusive
    # inter-chunk: contribution of the carried state
    o_inter = jax.lax.dot_general(r * jnp.exp(clw_ex), s,
                                  (((1,), (0,)), ((), ())))     # (L, N)
    # intra-chunk pairwise (log-space decays, strictly lower-triangular)
    decay = jnp.exp(clw_ex[:, None, :] - clw[None, :, :])       # (L, L, N)
    a = jnp.sum(r[:, None, :] * k[None, :, :] * decay, axis=-1)  # (L, L)
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    a = jnp.where(tri, a, 0.0)
    bonus = jnp.sum(r * (u * k), axis=-1, keepdims=True)         # (L, 1)
    o_intra = jax.lax.dot_general(a, v, (((1,), (0,)), ((), ()))) + bonus * v
    o_ref[0] = (o_inter + o_intra).astype(o_ref.dtype)

    # state update: decay to end of chunk + decayed outer products
    k_dec = k * jnp.exp(clw[-1:] - clw)                          # (L, N)
    s_new = jnp.exp(clw[-1])[:, None] * s + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())))
    state[...] = s_new

    @pl.when(ci == n_chunks - 1)
    def _final():
        sout_ref[0] = s_new


def wkv6(
    r: jax.Array,          # (BH, S, N)
    k: jax.Array,          # (BH, S, N)
    v: jax.Array,          # (BH, S, N)
    lw: jax.Array,         # (BH, S, N) fp32 log-decay ≤ 0
    u: jax.Array,          # (BH, 1, N) bonus (per-head row, pre-expanded)
    s0: jax.Array,         # (BH, N, N) fp32 initial state
    *,
    chunk: int = 32,
    interpret: bool = False,
):
    BH, S, N = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk

    kernel = functools.partial(_wkv_kernel, L=chunk, n_chunks=n_chunks)
    layout = wkv_layout(BH, S, N, chunk)
    o, s_fin = pl.pallas_call(
        kernel,
        grid=layout.grid,
        in_specs=layout.block_specs(),
        out_specs=layout.out_block_specs(),
        out_shape=layout.out_shape_structs([r.dtype, jnp.float32]),
        scratch_shapes=layout.scratch_shapes(),
        compiler_params=_CompilerParams(
            dimension_semantics=layout.dimension_semantics),
        interpret=interpret,
    )(r, k, v, lw, u, s0)
    return o, s_fin
