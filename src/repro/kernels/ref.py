"""Pure-jnp oracles for the Pallas kernels (naive, obviously-correct)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, group: int, scale: float, causal: bool = True,
                  window: int = 0, logit_cap: float = 0.0) -> jax.Array:
    """q (BH,Sq,hd), k/v (BK,Sk,hd) — full masked softmax attention."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    kq = jnp.repeat(k, group, axis=0)            # expand kv heads to q heads
    vq = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   kq.astype(jnp.float32)) * scale
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    pq = jnp.arange(Sq)[:, None]
    pk = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= pk <= pq
    if window:
        mask &= pq - pk < window
    s = jnp.where(mask, s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, vq.astype(jnp.float32)).astype(q.dtype)


def rglru_ref(log_a, b, h0) -> jax.Array:
    """Step-by-step linear recurrence. log_a/b (B,S,R), h0 (B,R)."""
    def step(h, xs):
        la, bt = xs
        h = jnp.exp(la) * h + bt
        return h, h
    _, hs = jax.lax.scan(step, h0, (log_a.transpose(1, 0, 2),
                                    b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)


def wkv6_ref(r, k, v, lw, u, s0):
    """Step-by-step WKV6.  r/k/v/lw (BH,S,N), u (BH,1,N), s0 (BH,N,N)."""
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    uf = u.astype(jnp.float32)[:, 0]             # (BH, N)

    def step(s, xs):
        rt, kt, vt, lwt = xs                     # (BH, N) each
        at = kt[:, :, None] * vt[:, None, :]     # (BH, N, N)
        o = jnp.einsum("bc,bcv->bv", rt, s + uf[:, :, None] * at)
        s = jnp.exp(lwt)[:, :, None] * s + at
        return s, o

    s_fin, os = jax.lax.scan(
        step, s0.astype(jnp.float32),
        (rf.transpose(1, 0, 2), kf.transpose(1, 0, 2),
         vf.transpose(1, 0, 2), lw.transpose(1, 0, 2)))
    return os.transpose(1, 0, 2).astype(r.dtype), s_fin
