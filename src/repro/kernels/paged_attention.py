"""Paged-attention decode (flash-decode over the page table).

One new token per sequence attends a vLLM-style paged KV cache: a shared
physical pool ``(P, K, page_size, hd)`` plus per-sequence page tables
``(B, pages_per_seq)``.  The *reference* walk
(``models.attention.decode_attention_paged``) gathers the table-bounded
dense ``(B, pps·ps, K, hd)`` view every step — transient bandwidth scales
with the table length, not with what the sequence actually holds.  Both
implementations here are **O(live pages)**: they walk each sequence's
pages with a running online-softmax ``(m, l, acc)`` and never materialize
the gathered view.

* :func:`paged_decode_attention` — the Pallas TPU kernel.  Grid
  ``(batch, kv_head, page)`` with the page dim minormost/sequential so the
  running state lives in VMEM scratch; the page table and per-sequence
  positions are **scalar-prefetched** so the BlockSpec index map can DMA
  exactly the physical page each grid step needs.  Pages past the last
  live one (slot ``t`` holds position ``t``, so pages ``> pos_q // ps``
  are dead weight) re-map to the last live page — the block index repeats,
  Pallas issues no new copy, and the tail of a mostly-empty table costs
  nothing.  Runs in interpret mode off-TPU (CPU tests).
* :func:`paged_decode_jnp` — a ``lax.scan`` fallback with the same
  contract and the same O(pages) transient footprint, for serving without
  ``use_pallas`` (the scan carries one ``(B, K, ps, hd)`` page gather per
  step instead of the whole table).

When the query group G is small (GQA with few q heads per kv head), the
per-kv-head grid issues a starving ``(G, hd) × (hd, ps)`` matmul per page;
``grouped=True`` (the default) switches to a ``(batch, head_tile, page)``
grid where a *tile* of ``kt`` kv heads' query groups hit the page in ONE
MXU call — a block-diagonal masked ``(kt·G, hd) × (hd, kt·ps)`` score
matmul (kt× redundant compute, traded for MXU occupancy).  ``kt`` is the
largest divisor of K keeping ``kt·G`` within one MXU band (≤ 8 query
rows), so G > 4 now runs grouped too: large groups simply tile one kv
head at a time (kt = 1) with shared (m, l, acc) scratch per tile.
Contract and numerics match the per-kv-head kernel, the scan fallback,
and the ``decode_attention_paged`` oracle.

MLA's latent cache gets the same treatment (:func:`mla_paged_layout` /
:func:`mla_paged_decode_attention`): pages hold compressed latents +
rope keys, the walk is MQA-shaped — H absorbed query heads against ONE
shared latent kv head of width ``lora + rd`` — and the accumulator reads
the latent itself (``W_vc`` is applied outside the kernel).

Masking rules (shared by both, and by the reference):

* slot ``t`` of a sequence holds absolute position ``t`` — a key is live
  iff ``t <= pos_q`` *and* its page-table entry is allocated (``>= 0``);
* ``pos_q < 0`` marks an inactive continuous-batching slot: every key is
  masked and the output row is **zero** (the reference's plain softmax
  returns a garbage average there instead; callers ignore those rows);
* unallocated entries (``-1``) cost no bandwidth — the kernel's index
  map re-maps them to an already-fetched live page (no new DMA) and the
  fallback's ``take`` fills with zeros without reading the pool (the
  clamp-to-page-0 of the old reference paid page 0's bandwidth for
  every hole).

Layouts: q ``(B, K, G, hd)`` (G = query heads per kv head), pool
``(P, K, ps, hd)``, table ``(B, pps)`` int32, pos_q ``(B,)`` int32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels.layout import KernelLayout, SpecDesc

NEG_INF = -2.0e38


def _decode_kernel(pt_ref, pq_ref, q_ref, k_ref, v_ref, o_ref,
                   m_s, l_s, acc_s, *,
                   scale: float, logit_cap: float, ps: int, n_pages: int):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    pq = pq_ref[b]
    live = jnp.logical_and(pq >= 0,
                           jnp.logical_and(i * ps <= pq, pt_ref[b, i] >= 0))

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)                # (ps, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, ps)
        if logit_cap:
            s = logit_cap * jnp.tanh(s / logit_cap)
        t = i * ps + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = t <= pq
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        # mask p explicitly: a fully-dead row would otherwise see
        # exp(NEG_INF - NEG_INF) == 1 (NEG_INF is a finite sentinel)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + p.sum(axis=1, keepdims=True)
        acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
            p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())))
        m_s[...] = m_new

    @pl.when(i == n_pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[...] /
                       jnp.maximum(l_s[...], 1e-37)).astype(o_ref.dtype)


def _decode_kernel_grouped(pt_ref, pq_ref, q_ref, k_ref, v_ref, o_ref,
                           m_s, l_s, acc_s, *,
                           scale: float, logit_cap: float, ps: int,
                           n_pages: int):
    """Grouped variant: grid (batch, head_tile, page) — a tile of ``kt``
    kv heads' query groups (kt·G query heads) hits the page in ONE MXU
    call.  The (kt·G, hd) × (hd, kt·ps) score matmul computes every
    q-head × kv-head block *within the tile*; a block-diagonal mask
    (query head r belongs to kv head r // G, key column c to kv head
    c // ps) keeps only the matching ones.  The kt× redundant compute is
    a win when G is small: the per-page matmul of the per-kv-head kernel
    is a skinny (G, hd) × (hd, ps) that starves the MXU.  The tile size
    comes from the BlockSpec (q block (1, kt, G, hd)) — the kernel body
    is tile-size agnostic, so G > 4 runs the same code with kt = 1."""
    b = pl.program_id(0)
    i = pl.program_id(2)
    _, kt, G, hd = q_ref.shape

    @pl.when(i == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    pq = pq_ref[b]
    live = jnp.logical_and(pq >= 0,
                           jnp.logical_and(i * ps <= pq, pt_ref[b, i] >= 0))

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32).reshape(kt * G, hd) * scale
        k = k_ref[0].astype(jnp.float32).reshape(kt * ps, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (ktG, ktps)
        if logit_cap:
            s = logit_cap * jnp.tanh(s / logit_cap)
        row_head = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        col_head = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) // ps
        t = i * ps + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) % ps
        mask = jnp.logical_and(row_head == col_head, t <= pq)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        # mask p explicitly: a fully-dead row would otherwise see
        # exp(NEG_INF - NEG_INF) == 1 (NEG_INF is a finite sentinel)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + p.sum(axis=1, keepdims=True)
        # cross-head products are exact zeros (p is masked), so the one
        # (ktG, ktps) × (ktps, hd) value matmul sums only the right block
        acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32).reshape(kt * ps, hd),
            (((1,), (0,)), ((), ())))
        m_s[...] = m_new

    @pl.when(i == n_pages - 1)
    def _finalize():
        o_ref[0] = (acc_s[...] /
                    jnp.maximum(l_s[...], 1e-37)
                    ).reshape(kt, G, hd).astype(o_ref.dtype)


def _decode_kernel_mla(pt_ref, pq_ref, q_ref, ckv_ref, kr_ref, o_ref,
                       m_s, l_s, acc_s, *,
                       scale: float, ps: int, n_pages: int):
    """MLA latent flash-decode: grid (batch, page).  The latent cache is
    MQA-shaped — ONE shared latent kv head serves all H absorbed query
    heads — so each page costs one (H, lora+rd) × (lora+rd, ps) score
    matmul (keys are the concatenation of the compressed latent and the
    rotated rope key) and one (H, ps) × (ps, lora) accumulate against the
    latent itself (``W_vc`` expands outside the kernel)."""
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    pq = pq_ref[b]
    live = jnp.logical_and(pq >= 0,
                           jnp.logical_and(i * ps <= pq, pt_ref[b, i] >= 0))

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale           # (H, lora+rd)
        c = ckv_ref[0].astype(jnp.float32)                 # (ps, lora)
        r = kr_ref[0].astype(jnp.float32)                  # (ps, rd)
        k = jnp.concatenate([c, r], axis=1)                # (ps, lora+rd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (H, ps)
        t = i * ps + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = t <= pq
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        # mask p explicitly: a fully-dead row would otherwise see
        # exp(NEG_INF - NEG_INF) == 1 (NEG_INF is a finite sentinel)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + p.sum(axis=1, keepdims=True)
        acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
            p, c, (((1,), (0,)), ((), ())))                # (H, lora)
        m_s[...] = m_new

    @pl.when(i == n_pages - 1)
    def _finalize():
        o_ref[0] = (acc_s[...] /
                    jnp.maximum(l_s[...], 1e-37)).astype(o_ref.dtype)


def _page_block(b, i, pt_ref, pq_ref, ps: int):
    """Physical page for grid step (b, ·, i).  Dead tail pages (beyond the
    last live page) re-map to the last live page: the block index repeats
    across those steps, so the pipeline issues no new DMA for them.
    A -1 hole *inside* the live prefix (never produced by the allocator's
    contiguous-prefix tables, but legal input) borrows the last live
    page's entry — an already-fetched page, not physical page 0, so holes
    cost no extra bandwidth; compute is skipped either way.  Inactive
    rows (pos < 0, table all -1) clamp to page 0 with all compute
    skipped."""
    last_live = jnp.maximum(pq_ref[b], 0) // ps
    ii = jnp.minimum(i, last_live)
    entry = pt_ref[b, ii]
    entry = jnp.where(entry >= 0, entry, pt_ref[b, last_live])
    return jnp.maximum(entry, 0)


def group_tile(K: int, G: int) -> int:
    """kv heads per grouped-grid tile: the largest divisor of K keeping
    the tile's query rows (kt·G) within one MXU band (8 rows).  Small
    groups pack several kv heads per matmul; G >= 8 tiles one kv head at
    a time — still grouped (shared scratch, one matmul per page), just
    without cross-head packing."""
    kt = 1
    for d in range(1, K + 1):
        if K % d == 0 and d * G <= max(G, 8):
            kt = d
    return kt


def paged_layout(B: int, K: int, G: int, hd: int, ps: int, pps: int,
                 n_pool: int, *, grouped: bool) -> KernelLayout:
    """Grid layout of the flash-decode kernel (both variants).  The
    ``pallas_call`` below is built from this; ``staticcheck.kernel_check``
    abstractly evaluates the same index maps over adversarial page
    tables.  Page-table and position operands are scalar-prefetched and
    therefore not listed as blocked inputs."""
    if grouped:
        kt = group_tile(K, G)

        def kv_map_g(b, t, i, pt, pq):
            return (_page_block(b, i, pt, pq, ps), t, 0, 0)

        def q_map_g(b, t, i, pt, pq):
            return (b, t, 0, 0)

        return KernelLayout(
            name="paged_decode_grouped",
            grid=(B, K // kt, pps),
            num_scalar_prefetch=2,
            in_specs=(
                SpecDesc("q", (B, K, G, hd), (1, kt, G, hd), q_map_g),
                SpecDesc("k_pages", (n_pool, K, ps, hd), (1, kt, ps, hd),
                         kv_map_g),
                SpecDesc("v_pages", (n_pool, K, ps, hd), (1, kt, ps, hd),
                         kv_map_g),
            ),
            out_specs=(
                SpecDesc("o", (B, K, G, hd), (1, kt, G, hd), q_map_g),),
            scratch=(((kt * G, 1), jnp.float32),
                     ((kt * G, 1), jnp.float32),
                     ((kt * G, hd), jnp.float32)),
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )

    def kv_map(b, h, i, pt, pq):
        return (_page_block(b, i, pt, pq, ps), h, 0, 0)

    def q_map(b, h, i, pt, pq):
        return (b, h, 0, 0)

    return KernelLayout(
        name="paged_decode",
        grid=(B, K, pps),
        num_scalar_prefetch=2,
        in_specs=(
            SpecDesc("q", (B, K, G, hd), (1, 1, G, hd), q_map),
            SpecDesc("k_pages", (n_pool, K, ps, hd), (1, 1, ps, hd), kv_map),
            SpecDesc("v_pages", (n_pool, K, ps, hd), (1, 1, ps, hd), kv_map),
        ),
        out_specs=(SpecDesc("o", (B, K, G, hd), (1, 1, G, hd), q_map),),
        scratch=(((G, 1), jnp.float32),
                 ((G, 1), jnp.float32),
                 ((G, hd), jnp.float32)),
        dimension_semantics=("parallel", "parallel", "arbitrary"),
    )


def paged_decode_attention(
    q: jax.Array,            # (B, K, G, hd)
    k_pages: jax.Array,      # (P, K, ps, hd)
    v_pages: jax.Array,      # (P, K, ps, hd)
    page_table: jax.Array,   # (B, pps) int32; -1 = unallocated
    pos_q: jax.Array,        # (B,) int32; -1 = inactive slot
    *,
    scale: float,
    logit_cap: float = 0.0,
    interpret: bool = False,
    grouped: "bool | None" = None,
) -> jax.Array:
    B, K, G, hd = q.shape
    ps = k_pages.shape[2]
    pps = page_table.shape[1]

    # the grouped grid tiles head batches to MXU-friendly sizes for every
    # G (see group_tile), so it is the default; grouped=False keeps the
    # per-kv-head grid for A/B numerics checks
    if grouped is None:
        grouped = True
    layout = paged_layout(B, K, G, hd, ps, pps, k_pages.shape[0],
                          grouped=grouped)
    if grouped:
        kernel = functools.partial(
            _decode_kernel_grouped, scale=scale, logit_cap=logit_cap,
            ps=ps, n_pages=pps)
    else:
        kernel = functools.partial(
            _decode_kernel, scale=scale, logit_cap=logit_cap, ps=ps,
            n_pages=pps)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=layout.num_scalar_prefetch,
        grid=layout.grid,
        in_specs=layout.block_specs(),
        out_specs=layout.out_block_specs()[0],
        scratch_shapes=layout.scratch_shapes(),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=layout.out_shape_structs([q.dtype])[0],
        compiler_params=_CompilerParams(
            dimension_semantics=layout.dimension_semantics),
        interpret=interpret,
    )(page_table.astype(jnp.int32), pos_q.astype(jnp.int32), q,
      k_pages, v_pages)


def paged_decode_jnp(
    q: jax.Array,            # (B, K, G, hd)
    k_pages: jax.Array,      # (P, K, ps, hd)
    v_pages: jax.Array,      # (P, K, ps, hd)
    page_table: jax.Array,   # (B, pps) int32; -1 = unallocated
    pos_q: jax.Array,        # (B,) int32; -1 = inactive slot
    *,
    scale: float,
    logit_cap: float = 0.0,
) -> jax.Array:
    """Same contract as the kernel, pure jnp: ``lax.scan`` over logical
    pages carrying (m, l, acc) — transient memory is one (B, K, ps, hd)
    page gather per step, not the (B, pps·ps, K, hd) view."""
    B, K, G, hd = q.shape
    ps = k_pages.shape[2]
    pps = page_table.shape[1]
    qf = q.astype(jnp.float32) * scale
    pq = pos_q.astype(jnp.int32)

    def body(carry, i):
        m, l, acc = carry
        entry = jax.lax.dynamic_index_in_dim(page_table, i, axis=1,
                                             keepdims=False)     # (B,)
        # fill-mode gather: -1 is out of bounds -> zeros, page 0 untouched
        kb = jnp.take(k_pages, entry, axis=0, mode="fill",
                      fill_value=0).astype(jnp.float32)          # (B,K,ps,hd)
        vb = jnp.take(v_pages, entry, axis=0, mode="fill",
                      fill_value=0).astype(jnp.float32)
        s = jnp.einsum("bkgd,bktd->bkgt", qf, kb)                # (B,K,G,ps)
        if logit_cap:
            s = logit_cap * jnp.tanh(s / logit_cap)
        t = i * ps + jnp.arange(ps, dtype=jnp.int32)
        valid = (entry[:, None] >= 0) & (t[None, :] <= pq[:, None])  # (B,ps)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.where(valid[:, None, None, :],
                      jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgt,bktd->bkgd", p, vb)
        return (m_new, l, acc), None

    m0 = jnp.full((B, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G), jnp.float32)
    a0 = jnp.zeros((B, K, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  jnp.arange(pps, dtype=jnp.int32))
    return (acc / jnp.maximum(l, 1e-37)[..., None]).astype(q.dtype)


def mla_paged_layout(B: int, H: int, lora: int, rd: int, ps: int,
                     pps: int, n_pool: int) -> KernelLayout:
    """Grid layout of the MLA latent flash-decode kernel.  The latent
    pool has no kv-head axis (MQA-shaped), so the grid is just
    (batch, page); the page index maps reuse :func:`_page_block` and get
    the same adversarial-table walk in ``staticcheck.kernel_check``."""
    def kv_map(b, i, pt, pq):
        return (_page_block(b, i, pt, pq, ps), 0, 0)

    def q_map(b, i, pt, pq):
        return (b, 0, 0)

    return KernelLayout(
        name="mla_paged_decode",
        grid=(B, pps),
        num_scalar_prefetch=2,
        in_specs=(
            SpecDesc("q_lat", (B, H, lora + rd), (1, H, lora + rd), q_map),
            SpecDesc("ckv_pages", (n_pool, ps, lora), (1, ps, lora), kv_map),
            SpecDesc("krope_pages", (n_pool, ps, rd), (1, ps, rd), kv_map),
        ),
        out_specs=(SpecDesc("o", (B, H, lora), (1, H, lora), q_map),),
        scratch=(((H, 1), jnp.float32),
                 ((H, 1), jnp.float32),
                 ((H, lora), jnp.float32)),
        dimension_semantics=("parallel", "arbitrary"),
    )


def mla_paged_decode_attention(
    q_lat: jax.Array,        # (B, H, lora + rd) absorbed query
    ckv_pages: jax.Array,    # (P, ps, lora)
    krope_pages: jax.Array,  # (P, ps, rd)
    page_table: jax.Array,   # (B, pps) int32; -1 = unallocated
    pos_q: jax.Array,        # (B,) int32; -1 = inactive slot
    *,
    scale: float,
    interpret: bool = False,
) -> jax.Array:
    """Latent-space flash-decode over MLA pages; returns the latent
    context ``(B, H, lora)`` (caller applies ``W_vc`` and the output
    projection).  Same masking contract as :func:`paged_decode_attention`:
    holes cost no DMA, dead tails repeat the last live page, inactive
    rows come back zero."""
    B, H, qd = q_lat.shape
    ps, lora = ckv_pages.shape[1], ckv_pages.shape[2]
    rd = krope_pages.shape[2]
    assert qd == lora + rd, (qd, lora, rd)
    pps = page_table.shape[1]

    layout = mla_paged_layout(B, H, lora, rd, ps, pps, ckv_pages.shape[0])
    kernel = functools.partial(_decode_kernel_mla, scale=scale, ps=ps,
                               n_pages=pps)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=layout.num_scalar_prefetch,
        grid=layout.grid,
        in_specs=layout.block_specs(),
        out_specs=layout.out_block_specs()[0],
        scratch_shapes=layout.scratch_shapes(),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=layout.out_shape_structs([q_lat.dtype])[0],
        compiler_params=_CompilerParams(
            dimension_semantics=layout.dimension_semantics),
        interpret=interpret,
    )(page_table.astype(jnp.int32), pos_q.astype(jnp.int32), q_lat,
      ckv_pages, krope_pages)


def mla_paged_decode_jnp(
    q_lat: jax.Array,        # (B, H, lora + rd) absorbed query
    ckv_pages: jax.Array,    # (P, ps, lora)
    krope_pages: jax.Array,  # (P, ps, rd)
    page_table: jax.Array,   # (B, pps) int32; -1 = unallocated
    pos_q: jax.Array,        # (B,) int32; -1 = inactive slot
    *,
    scale: float,
) -> jax.Array:
    """Same contract as :func:`mla_paged_decode_attention`, pure jnp:
    ``lax.scan`` over logical pages carrying (m, l, acc) — transient
    memory is one (B, ps, lora + rd) page gather per step."""
    B, H, _ = q_lat.shape
    ps, lora = ckv_pages.shape[1], ckv_pages.shape[2]
    pps = page_table.shape[1]
    qf = q_lat.astype(jnp.float32) * scale
    pq = pos_q.astype(jnp.int32)

    def body(carry, i):
        m, l, acc = carry
        entry = jax.lax.dynamic_index_in_dim(page_table, i, axis=1,
                                             keepdims=False)     # (B,)
        cb = jnp.take(ckv_pages, entry, axis=0, mode="fill",
                      fill_value=0).astype(jnp.float32)          # (B,ps,lora)
        rb = jnp.take(krope_pages, entry, axis=0, mode="fill",
                      fill_value=0).astype(jnp.float32)          # (B,ps,rd)
        kb = jnp.concatenate([cb, rb], axis=-1)                  # (B,ps,l+r)
        s = jnp.einsum("bhe,bte->bht", qf, kb)                   # (B,H,ps)
        t = i * ps + jnp.arange(ps, dtype=jnp.int32)
        valid = (entry[:, None] >= 0) & (t[None, :] <= pq[:, None])  # (B,ps)
        s = jnp.where(valid[:, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.where(valid[:, None, :],
                      jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bht,btl->bhl", p, cb)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H), jnp.float32)
    a0 = jnp.zeros((B, H, lora), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  jnp.arange(pps, dtype=jnp.int32))
    return (acc / jnp.maximum(l, 1e-37)[..., None]).astype(q_lat.dtype)
