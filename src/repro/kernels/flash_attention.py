"""Flash attention for TPU (Pallas): blocked online-softmax, VMEM-resident
accumulators, causal/sliding-window masking, GQA, logit softcap.

Grid = (batch·q_heads, n_q_blocks, n_kv_blocks); the kv dim is minormost so
on TPU it iterates sequentially per (bh, qi) and the running (m, l, acc)
live in VMEM scratch across kv steps.  Fully-masked kv blocks (beyond the
causal frontier or before the sliding window) are skipped with pl.when —
the MXU sees only live blocks, giving O(S·W) work for windowed layers.

Block shapes are MXU-aligned (q_blk, kv_blk multiples of 128; head_dim is
the lane dim).  VMEM working set per grid step:
    q (q_blk·hd) + k,v (kv_blk·hd) + scores (q_blk·kv_blk) + acc (q_blk·hd)
e.g. 512×128 blocks at f32 ≈ 1.3 MB — comfortably under the ~16 MB VMEM.

Layouts: q (B·H, Sq, hd); k, v (B·K, Sk, hd); kv head = q head // G.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels.layout import KernelLayout, SpecDesc

NEG_INF = -2.0e38


def flash_layout(BH: int, Sq: int, Sk: int, hd: int, q_blk: int,
                 kv_blk: int, group: int) -> KernelLayout:
    """Grid layout of :func:`flash_attention` — the single source of truth
    the pallas_call is built from and ``staticcheck`` abstractly checks."""
    q_map = lambda bh, qi, ki: (bh, qi, 0)
    kv_map = lambda bh, qi, ki, group=group: (bh // group, ki, 0)
    return KernelLayout(
        name="flash_attention",
        grid=(BH, Sq // q_blk, Sk // kv_blk),
        in_specs=(
            SpecDesc("q", (BH, Sq, hd), (1, q_blk, hd), q_map),
            SpecDesc("k", (BH // group, Sk, hd), (1, kv_blk, hd), kv_map),
            SpecDesc("v", (BH // group, Sk, hd), (1, kv_blk, hd), kv_map),
        ),
        out_specs=(
            SpecDesc("o", (BH, Sq, hd), (1, q_blk, hd), q_map),
        ),
        scratch=(
            ((q_blk, 1), jnp.float32),
            ((q_blk, 1), jnp.float32),
            ((q_blk, hd), jnp.float32),
        ),
        dimension_semantics=("parallel", "parallel", "arbitrary"),
    )


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                  scale: float, causal: bool, window: int, logit_cap: float,
                  q_blk: int, kv_blk: int, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q0 = qi * q_blk
    t0 = ki * kv_blk
    # live test for this (q, kv) block pair
    live = True
    if causal:
        live = t0 <= q0 + q_blk - 1
    if window:
        live = jnp.logical_and(live, t0 + kv_blk - 1 >= q0 - window + 1) \
            if causal else (t0 + kv_blk - 1 >= q0 - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (q_blk, hd)
        k = k_ref[0].astype(jnp.float32)                  # (kv_blk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (q_blk,kv_blk)
        if logit_cap:
            s = logit_cap * jnp.tanh(s / logit_cap)
        pq = q0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        pk = t0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones(s.shape, jnp.bool_)
        if causal:
            mask &= pk <= pq
        if window:
            mask &= pq - pk < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + p.sum(axis=1, keepdims=True)
        acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())))
        m_s[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_s[...] /
                    jnp.maximum(l_s[...], 1e-37)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,           # (BH, Sq, hd)
    k: jax.Array,           # (BK, Sk, hd)
    v: jax.Array,           # (BK, Sk, hd)
    *,
    group: int,             # q heads per kv head (GQA)
    scale: float,
    causal: bool = True,
    window: int = 0,
    logit_cap: float = 0.0,
    q_blk: int = 512,
    kv_blk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    q_blk = min(q_blk, Sq)
    kv_blk = min(kv_blk, Sk)
    assert Sq % q_blk == 0 and Sk % kv_blk == 0, (Sq, q_blk, Sk, kv_blk)
    n_kv = Sk // kv_blk

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        logit_cap=logit_cap, q_blk=q_blk, kv_blk=kv_blk, n_kv=n_kv)

    layout = flash_layout(BH, Sq, Sk, hd, q_blk, kv_blk, group)
    return pl.pallas_call(
        kernel,
        grid=layout.grid,
        in_specs=layout.block_specs(),
        out_specs=layout.out_block_specs()[0],
        out_shape=layout.out_shape_structs([q.dtype])[0],
        scratch_shapes=layout.scratch_shapes(),
        compiler_params=_CompilerParams(
            dimension_semantics=layout.dimension_semantics),
        interpret=interpret,
    )(q, k, v)
