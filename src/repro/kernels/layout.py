"""Declarative Pallas grid layouts — one source of truth per kernel.

Each kernel module exposes a ``*_layout(...)`` function returning a
:class:`KernelLayout`: the grid, every operand's (shape, block,
index_map), the outputs, the scratch allocations, and the dimension
semantics.  The kernel's ``pallas_call`` is built *from* the layout, and
``repro.staticcheck.kernel_check`` abstractly evaluates the very same
index maps over every grid point — so the static checker can prove
in-bounds blocks, exactly-once output coverage, page-hole remapping, and
scratch-dtype coherence for exactly the code that runs, with no
possibility of checker/kernel drift.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, List, Tuple


@dataclass(frozen=True)
class SpecDesc:
    """One operand: full array shape, block shape, block index map."""

    name: str
    shape: Tuple[int, ...]
    block: Tuple[int, ...]
    index_map: Callable[..., Tuple[int, ...]]


@dataclass(frozen=True)
class KernelLayout:
    """Complete grid description of one ``pallas_call``."""

    name: str
    grid: Tuple[int, ...]
    in_specs: Tuple[SpecDesc, ...]
    out_specs: Tuple[SpecDesc, ...]
    scratch: Tuple[Tuple[Tuple[int, ...], Any], ...]  # (shape, dtype)
    dimension_semantics: Tuple[str, ...]
    num_scalar_prefetch: int = 0

    # -- pallas_call construction ------------------------------------------
    def block_specs(self) -> List[Any]:
        from jax.experimental import pallas as pl
        return [pl.BlockSpec(s.block, s.index_map) for s in self.in_specs]

    def out_block_specs(self) -> List[Any]:
        from jax.experimental import pallas as pl
        return [pl.BlockSpec(s.block, s.index_map) for s in self.out_specs]

    def scratch_shapes(self) -> List[Any]:
        import jax.experimental.pallas.tpu as pltpu
        return [pltpu.VMEM(shape, dtype) for shape, dtype in self.scratch]

    def out_shape_structs(self, dtypes) -> List[Any]:
        import jax
        assert len(dtypes) == len(self.out_specs), (dtypes, self.out_specs)
        return [jax.ShapeDtypeStruct(s.shape, dt)
                for s, dt in zip(self.out_specs, dtypes)]
