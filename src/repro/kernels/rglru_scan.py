"""RG-LRU linear recurrence for TPU (Pallas): h_t = a_t ⊙ h_{t-1} + b_t.

Grid = (B, n_r_blocks, n_t_blocks); time is minormost so the carry vector
(1, r_blk) persists in VMEM scratch across time blocks.  Each time block is
a *statically unrolled* chain of ``t_blk`` vector FMAs on the VPU — the
recurrence is elementwise per channel, so there is no MXU work; the kernel
exists to keep the carry resident in VMEM and stream a_t/b_t once from HBM
(the jnp associative-scan path reads/writes O(S·R·log S) intermediates).

Inputs are fp32: ``log_a`` (≤ 0) and ``b``; decay applied as exp(log_a).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels.layout import KernelLayout, SpecDesc


def rglru_layout(B: int, S: int, R: int, t_blk: int,
                 r_blk: int) -> KernelLayout:
    """Grid layout of :func:`rglru_scan` — the single source of truth the
    pallas_call is built from and ``staticcheck`` abstractly checks."""
    seq_map = lambda bi, ri, ti: (bi, ti, ri)
    h0_map = lambda bi, ri, ti: (bi, ri)
    return KernelLayout(
        name="rglru_scan",
        grid=(B, R // r_blk, S // t_blk),
        in_specs=(
            SpecDesc("log_a", (B, S, R), (1, t_blk, r_blk), seq_map),
            SpecDesc("b", (B, S, R), (1, t_blk, r_blk), seq_map),
            SpecDesc("h0", (B, R), (1, r_blk), h0_map),
        ),
        out_specs=(
            SpecDesc("o", (B, S, R), (1, t_blk, r_blk), seq_map),
        ),
        scratch=(((1, r_blk), jnp.float32),),
        dimension_semantics=("parallel", "parallel", "arbitrary"),
    )


def _rglru_kernel(la_ref, b_ref, h0_ref, o_ref, carry, *, t_blk: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        carry[...] = h0_ref[...]

    h = carry[0]                                   # (r_blk,)
    la = la_ref[0]                                 # (t_blk, r_blk)
    b = b_ref[0]
    rows = []
    for t in range(t_blk):                         # static unroll
        h = jnp.exp(la[t]) * h + b[t]
        rows.append(h)
    o_ref[0] = jnp.stack(rows)
    carry[0] = h


def rglru_scan(
    log_a: jax.Array,       # (B, S, R) fp32, ≤ 0
    b: jax.Array,           # (B, S, R) fp32
    h0: jax.Array,          # (B, R)    fp32 initial state
    *,
    t_blk: int = 16,
    r_blk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, S, R = log_a.shape
    t_blk = min(t_blk, S)
    r_blk = min(r_blk, R)
    assert S % t_blk == 0 and R % r_blk == 0, (S, t_blk, R, r_blk)

    kernel = functools.partial(_rglru_kernel, t_blk=t_blk)
    layout = rglru_layout(B, S, R, t_blk, r_blk)
    return pl.pallas_call(
        kernel,
        grid=layout.grid,
        in_specs=layout.block_specs(),
        out_specs=layout.out_block_specs()[0],
        out_shape=layout.out_shape_structs([jnp.float32])[0],
        scratch_shapes=layout.scratch_shapes(),
        compiler_params=_CompilerParams(
            dimension_semantics=layout.dimension_semantics),
        interpret=interpret,
    )(log_a, b, h0)
