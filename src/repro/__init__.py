"""repro — reproduction of "Dependability in a Multi-tenant
Multi-framework Deep Learning as-a-Service Platform" grown into a
JAX/Pallas training-and-serving substrate.

Subpackages: ``core`` (platform sim), ``dist`` (sharded execution),
``models`` / ``train`` / ``optim`` / ``kernels`` (learner compute),
``launch`` (dry-run, perf, serve), ``configs``, ``data``, ``testing``.
"""
