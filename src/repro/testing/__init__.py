"""Test-support utilities that ship with the library (the CI container
is hermetic — anything the suite needs beyond jax/numpy/pytest must live
here, stubbed or gated, never pip-installed at test time)."""
