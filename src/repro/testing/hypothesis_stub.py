"""Minimal, deterministic stand-in for the ``hypothesis`` library.

The test suite uses a small slice of hypothesis (``@given`` with keyword
strategies, ``@settings(max_examples=…, deadline=None)``, and the
``integers`` / ``floats`` / ``sampled_from`` / ``lists`` / ``tuples``
strategies).  The CI container does not ship hypothesis and the repo
policy forbids installing packages, so ``install()`` registers this
module as ``hypothesis`` when the real one is absent (conftest.py).

Differences from real hypothesis, by design:

* fully deterministic — examples are drawn from a PRNG seeded by the
  test's qualified name, so failures reproduce exactly;
* no shrinking — the failing example is printed as-is;
* the first examples are boundary-biased (min/max for integer ranges)
  to keep the edge-case coverage the property tests rely on.
"""
from __future__ import annotations

import functools
import random
import sys
import types
import zlib
from typing import Any, Callable, Sequence

DEFAULT_MAX_EXAMPLES = 20


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------
class SearchStrategy:
    """A strategy = a draw function + optional boundary examples."""

    def __init__(self, draw: Callable[[random.Random], Any],
                 boundary: Sequence[Any] = ()):
        self._draw = draw
        self.boundary = tuple(boundary)

    def example(self, rng: random.Random, i: int = -1) -> Any:
        if 0 <= i < len(self.boundary):
            return self.boundary[i]
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value),
                          boundary=(min_value, max_value))


def floats(min_value: float, max_value: float, **_: Any) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value),
                          boundary=(min_value, max_value))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5, boundary=(False, True))


def just(value: Any) -> SearchStrategy:
    return SearchStrategy(lambda rng: value, boundary=(value,))


def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda rng: rng.choice(elements),
                          boundary=elements[:1])


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(s.example(rng) for s in strategies))


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int = 10, **_: Any) -> SearchStrategy:
    def draw(rng: random.Random):
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]
    return SearchStrategy(draw, boundary=([elements.example(random.Random(0))]
                                          * min_size,))


# ---------------------------------------------------------------------------
# @settings / @given
# ---------------------------------------------------------------------------
class settings:
    """Records max_examples on the decorated test; other knobs accepted
    and ignored (deadline, suppress_health_check, …)."""

    def __init__(self, max_examples: int = DEFAULT_MAX_EXAMPLES, **_: Any):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_max_examples = self.max_examples
        return fn


def given(*args: Any, **strategies_kw: SearchStrategy):
    assert not args, "hypothesis stub supports keyword strategies only"

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*a, **kw):
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", DEFAULT_MAX_EXAMPLES))
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                drawn = {k: s.example(rng, i) for k, s in strategies_kw.items()}
                try:
                    fn(*a, **kw, **drawn)
                except Exception:
                    print(f"[hypothesis-stub] falsifying example "
                          f"({fn.__qualname__}, #{i}): {drawn!r}",
                          file=sys.stderr)
                    raise
        # pytest must see the wrapper's (empty) signature, not the inner
        # test's — otherwise the drawn params look like missing fixtures.
        del wrapper.__wrapped__
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper
    return deco


def assume(condition: bool) -> None:
    """Real hypothesis retries; the stub just skips via an assertion-free
    early exit — property bodies here never use assume on the hot path."""
    if not condition:
        raise _Unsatisfied()


class _Unsatisfied(Exception):
    pass


class HealthCheck:
    all = staticmethod(lambda: [])
    too_slow = data_too_large = filter_too_much = None


# ---------------------------------------------------------------------------
def install() -> None:
    """Register this module as ``hypothesis`` if the real one is absent."""
    if "hypothesis" in sys.modules:
        return
    try:
        import hypothesis  # noqa: F401  (real library present — use it)
        return
    except ImportError:
        pass
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = HealthCheck
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "just", "sampled_from",
                 "tuples", "lists"):
        setattr(strat, name, globals()[name])
    strat.SearchStrategy = SearchStrategy
    mod.strategies = strat
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat
