"""ShapeDtypeStruct input specs + sharding trees for every (arch × shape).

``input_specs`` mirrors what the data pipeline / serving frontend would
feed: weak-type-correct stand-ins, no device allocation.  Modality
frontends are stubs per the brief — audio/vision entries get precomputed
frame/patch embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import ShardingRules, DEFAULT_RULES, make_named_sharding
from repro.models import params as MP
from repro.models.model import abstract_cache

Tree = Dict[str, Any]


def src_len_for(cfg: ModelConfig, seq_len: int) -> int:
    """Encoder-side length for enc-dec archs (audio frames stub)."""
    return max(seq_len // 4, 16) if cfg.is_encoder_decoder else 0


def text_len_for(cfg: ModelConfig, seq_len: int) -> int:
    """Token count fed to the decoder; vision archs reserve frontend slots
    so the total decoder sequence is exactly ``seq_len``."""
    if cfg.frontend == "vision" and cfg.frontend_tokens:
        return seq_len - cfg.frontend_tokens
    return seq_len


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, kind: str) -> Tree:
    """ShapeDtypeStructs for the step's data inputs."""
    B, S = shape.global_batch, shape.seq_len
    tl = text_len_for(cfg, S)
    i32 = jnp.int32
    if kind == "train":
        specs: Tree = {
            "tokens": jax.ShapeDtypeStruct((B, tl), i32),
            "labels": jax.ShapeDtypeStruct((B, tl), i32),
        }
    elif kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, tl), i32)}
    else:  # decode
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.is_encoder_decoder and kind != "decode":
        specs["src_embeds"] = jax.ShapeDtypeStruct(
            (B, src_len_for(cfg, S), cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "vision" and kind != "decode":
        specs["frontend_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    return specs


def batch_shardings(batch_spec: Tree, mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES) -> Tree:
    def sh(s: jax.ShapeDtypeStruct):
        axes = ("batch",) + (None,) * (len(s.shape) - 1)
        return make_named_sharding(axes, s.shape, mesh, rules)
    return jax.tree.map(sh, batch_spec)


def param_specs(cfg: ModelConfig, serve: bool = False) -> Tree:
    """``serve=True``: matrices are stored bf16 (no optimizer → no master
    copy; halves both HBM residency and FSDP-gather wire bytes)."""
    specs = MP.shape_dtype_tree(MP.abstract_params(cfg))
    if serve:
        specs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
            if len(s.shape) >= 2 and s.dtype == jnp.float32 else s, specs)
    return specs


def param_shardings(cfg: ModelConfig, mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES) -> Tree:
    from repro.dist.sharding import tree_shardings
    return tree_shardings(MP.abstract_params(cfg), mesh, rules)


def state_specs(cfg: ModelConfig, run=None) -> Tree:
    """Train-state ShapeDtypeStructs (m/v mirror the params)."""
    from repro.configs.base import RunConfig
    run = run or RunConfig()
    ps = param_specs(cfg)
    master = lambda s: jax.ShapeDtypeStruct(
        s.shape, jnp.dtype(run.master_dtype) if len(s.shape) >= 2 else s.dtype)
    od = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(run.opt_dtype))
    ps_m = jax.tree.map(master, ps)
    return {
        "params": ps_m,
        "opt": {"m": jax.tree.map(od, ps), "v": jax.tree.map(od, ps),
                "count": jax.ShapeDtypeStruct((), jnp.int32)},
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def state_shardings(cfg: ModelConfig, mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES) -> Tree:
    psh = param_shardings(cfg, mesh, rules)
    rep = NamedSharding(mesh, P())
    return {
        "params": psh,
        "opt": {"m": psh, "v": psh, "count": rep},
        "step": rep,
    }


def cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> Tree:
    B, S = shape.global_batch, shape.seq_len
    ab = abstract_cache(cfg, B, S, src_len=src_len_for(cfg, S))
    return MP.shape_dtype_tree(ab)


def cache_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES) -> Tree:
    from repro.dist.sharding import tree_shardings
    B, S = shape.global_batch, shape.seq_len
    ab = abstract_cache(cfg, B, S, src_len=src_len_for(cfg, S))
    return tree_shardings(ab, mesh, rules)
