"""ShapeDtypeStruct input specs + sharding trees for every (arch × shape).

``input_specs`` mirrors what the data pipeline / serving frontend would
feed: weak-type-correct stand-ins, no device allocation.  Modality
frontends are stubs per the brief — audio/vision entries get precomputed
frame/patch embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import (
    DEFAULT_RULES,
    ShardingRules,
    check_cache_locality,
    make_named_sharding,
    tree_shardings,
)
from repro.models import params as MP
from repro.models.model import abstract_cache, num_pages

Tree = Dict[str, Any]


def src_len_for(cfg: ModelConfig, seq_len: int) -> int:
    """Encoder-side length for enc-dec archs (audio frames stub)."""
    return max(seq_len // 4, 16) if cfg.is_encoder_decoder else 0


def text_len_for(cfg: ModelConfig, seq_len: int) -> int:
    """Token count fed to the decoder; vision archs reserve frontend slots
    so the total decoder sequence is exactly ``seq_len``."""
    if cfg.frontend == "vision" and cfg.frontend_tokens:
        return seq_len - cfg.frontend_tokens
    return seq_len


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, kind: str) -> Tree:
    """ShapeDtypeStructs for the step's data inputs."""
    B, S = shape.global_batch, shape.seq_len
    tl = text_len_for(cfg, S)
    i32 = jnp.int32
    if kind == "train":
        specs: Tree = {
            "tokens": jax.ShapeDtypeStruct((B, tl), i32),
            "labels": jax.ShapeDtypeStruct((B, tl), i32),
        }
    elif kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, tl), i32)}
    else:  # decode
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.is_encoder_decoder and kind != "decode":
        specs["src_embeds"] = jax.ShapeDtypeStruct(
            (B, src_len_for(cfg, S), cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "vision" and kind != "decode":
        specs["frontend_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    return specs


def batch_shardings(batch_spec: Tree, mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES) -> Tree:
    def sh(s: jax.ShapeDtypeStruct):
        axes = ("batch",) + (None,) * (len(s.shape) - 1)
        return make_named_sharding(axes, s.shape, mesh, rules)
    return jax.tree.map(sh, batch_spec)


def param_specs(cfg: ModelConfig, serve: bool = False) -> Tree:
    """``serve=True``: matrices are stored bf16 (no optimizer → no master
    copy; halves both HBM residency and FSDP-gather wire bytes)."""
    specs = MP.shape_dtype_tree(MP.abstract_params(cfg))
    if serve:
        specs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
            if len(s.shape) >= 2 and s.dtype == jnp.float32 else s, specs)
    return specs


def param_shardings(cfg: ModelConfig, mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES) -> Tree:
    return MP.param_shardings(cfg, mesh, rules)


def state_specs(cfg: ModelConfig, run=None) -> Tree:
    """Train-state ShapeDtypeStructs (m/v — and, with gradient compression
    on, the fp32 error-feedback residuals — mirror the params)."""
    from repro.configs.base import RunConfig
    run = run or RunConfig()
    ps = param_specs(cfg)
    master = lambda s: jax.ShapeDtypeStruct(
        s.shape, jnp.dtype(run.master_dtype) if len(s.shape) >= 2 else s.dtype)
    od = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(run.opt_dtype))
    ps_m = jax.tree.map(master, ps)
    out = {
        "params": ps_m,
        "opt": {"m": jax.tree.map(od, ps), "v": jax.tree.map(od, ps),
                "count": jax.ShapeDtypeStruct((), jnp.int32)},
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if run.grad_compression != "none":
        out["err"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), ps)
    return out


def state_shardings(cfg: ModelConfig, mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES, run=None) -> Tree:
    from repro.configs.base import RunConfig
    run = run or RunConfig()
    psh = param_shardings(cfg, mesh, rules)
    rep = NamedSharding(mesh, P())
    out = {
        "params": psh,
        "opt": {"m": psh, "v": psh, "count": rep},
        "step": rep,
    }
    if run.grad_compression != "none":
        out["err"] = psh
    return out


def decode_page_budget(cfg: ModelConfig, shape: ShapeConfig,
                       run=None) -> Optional[int]:
    """Pool size in pages for a paged decode cell: worst case scaled by the
    run's expected occupancy.  Continuous batching keeps sequences at mixed
    fill levels, so the scheduler admits the cell by this *allocated*-page
    budget instead of reserving ``S_max`` per sequence.  None for dense."""
    if cfg.cache_layout != "paged":
        return None
    B, S = shape.global_batch, shape.seq_len
    occ = getattr(run, "page_occupancy", 1.0) if run is not None else 1.0
    worst = B * num_pages(S, cfg.page_size)
    return max(B, int(-(-worst * occ // 1)))


def decode_attn_bytes(cfg: ModelConfig, shape: ShapeConfig, run=None,
                      path: str = "kernel") -> int:
    """Modeled HBM bytes one decode step spends reading K/V, per *global*
    attention layer summed over the stack — the serving hot path's
    bandwidth bound.  Three walks of the same cache:

    * ``dense``     — the dense layout: B·S_max tokens per layer.
    * ``reference`` — the paged gather walk (``decode_attention_paged``):
      bounded by the page-*table* length, B·pps·ps tokens, regardless of
      how many pages are live.
    * ``kernel``    — the flash-decode kernel / scan fallback: only
      *resident* pages are touched (``run.page_occupancy`` of the table),
      and at least the one page holding the current position.
    * ``kernel_unique`` — the kernel walk priced by UNIQUE physical
      pages: ``run.prefix_share_frac`` of each sequence's resident pages
      are prefix pages aliased across the whole batch (the engine's
      hash-addressed prefix cache), physically read once per step instead
      of B times.  Equal to ``kernel`` at share 0.
    * ``dense_expanded`` — MLA only: the hypothetical head-expanded
      cache (per-head nope+rope keys and values, B·S_max tokens) a naive
      MQA/MHA materialization would read.  The latent/expanded ratio is
      MLA's entire decode-bandwidth case; for non-MLA it equals
      ``dense``.

    Per-token bytes follow the layout the decode step actually walks:
    GQA reads K and V heads (``2·K·hd``); MLA decode scores and
    accumulates in latent space, so every path but ``dense_expanded``
    charges ``kv_lora_rank + qk_rope_head_dim`` per token — the
    compressed latents ARE the cache, there is no expansion to re-read.

    The ratio reference/kernel ≈ 1/occupancy is the modeled win the
    ``serve_decode`` benchmark lane sweeps; kernel/kernel_unique is the
    dedup win ``prefix_cache`` sweeps; dense_expanded/kernel is the MLA
    lane's headline.
    """
    from repro.configs.base import GLOBAL_ATTN
    from repro.models.model import num_pages
    if path not in ("dense", "reference", "kernel", "kernel_unique",
                    "dense_expanded"):
        raise ValueError(path)
    B, S = shape.global_batch, shape.seq_len
    n_global = sum(1 for k in cfg.layer_kinds() if k == GLOBAL_ATTN)
    K, hd = cfg.num_kv_heads, cfg.head_dim
    isize = jnp.dtype(cfg.dtype).itemsize
    if cfg.use_mla:
        tok_bytes = (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * isize
        expanded_bytes = cfg.num_heads * (
            cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
            + cfg.v_head_dim) * isize
    else:
        tok_bytes = 2 * K * hd * isize                     # K and V
        expanded_bytes = tok_bytes
    ps = cfg.page_size
    pps = num_pages(S, ps)
    if path in ("dense", "dense_expanded"):
        tokens = B * S
    elif path == "reference":
        tokens = B * pps * ps
    else:
        occ = getattr(run, "page_occupancy", 1.0) if run is not None else 1.0
        resident = max(int(-(-pps * occ // 1)), 1)
        if path == "kernel_unique":
            tokens = unique_decode_pages(B, resident, run) * ps
        else:
            tokens = B * resident * ps
    per_tok = expanded_bytes if path == "dense_expanded" else tok_bytes
    return tokens * per_tok * n_global


def unique_decode_pages(batch: int, resident_per_seq: int, run=None) -> int:
    """Unique physical pages a decode step touches when
    ``run.prefix_share_frac`` of every sequence's resident pages are one
    batch-wide aliased prefix: the shared span is counted once, each
    sequence's private remainder B times."""
    f = getattr(run, "prefix_share_frac", 0.0) if run is not None else 0.0
    shared = min(int(resident_per_seq * f), resident_per_seq)
    return batch * (resident_per_seq - shared) + shared


def decode_arithmetic_intensity(cfg: ModelConfig, shape: ShapeConfig,
                                run=None, path: str = "kernel") -> float:
    """FLOPs per HBM byte of the decode attention walk (one step).  The
    useful work is fixed — 4·B·resident_tokens·H·hd MACs — so intensity
    degrades exactly by the wasted gather bytes; the kernel's intensity is
    occupancy-independent (it touches what it computes on)."""
    from repro.configs.base import GLOBAL_ATTN
    from repro.models.model import num_pages
    B, S = shape.global_batch, shape.seq_len
    n_global = sum(1 for k in cfg.layer_kinds() if k == GLOBAL_ATTN)
    if not n_global:
        return 0.0
    occ = getattr(run, "page_occupancy", 1.0) if run is not None else 1.0
    pps = num_pages(S, cfg.page_size)
    resident = max(int(-(-pps * occ // 1)), 1) * cfg.page_size
    if cfg.use_mla:
        # latent-space MACs: scores over lora+rd, context over lora
        per_tok = 2 * cfg.num_heads * (2 * cfg.kv_lora_rank
                                       + cfg.qk_rope_head_dim)
    else:
        per_tok = 4 * cfg.num_heads * cfg.head_dim
    flops = B * resident * per_tok * n_global
    return flops / max(decode_attn_bytes(cfg, shape, run, path), 1)


def _cache_ab(cfg: ModelConfig, shape: ShapeConfig, run=None) -> Tree:
    B, S = shape.global_batch, shape.seq_len
    return abstract_cache(cfg, B, S, src_len=src_len_for(cfg, S),
                          page_budget=decode_page_budget(cfg, shape, run))


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, run=None) -> Tree:
    return MP.shape_dtype_tree(_cache_ab(cfg, shape, run))


def cache_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES, run=None) -> Tree:
    ab = _cache_ab(cfg, shape, run)
    # decode gather/scatter must stay shard-local; raises on a bad override
    check_cache_locality(ab, mesh, rules)
    return tree_shardings(ab, mesh, rules)


# ---------------------------------------------------------------------------
# Analytic placement: per-device residency without compiling anything.
# ---------------------------------------------------------------------------
def sharded_bytes(spec_tree: Tree, shard_tree: Tree) -> int:
    """Exact per-device bytes of a (ShapeDtypeStruct, NamedSharding) tree
    pair — ``NamedSharding.shard_shape`` applies the same partitioning XLA
    will, so this matches the compiled argument residency."""
    import numpy as np
    specs = jax.tree.leaves(spec_tree)
    shards = jax.tree.leaves(shard_tree)
    assert len(specs) == len(shards), (len(specs), len(shards))
    total = 0
    for s, h in zip(specs, shards):
        shape = h.shard_shape(s.shape)
        total += int(np.prod(shape, dtype=np.int64)) * jnp.dtype(s.dtype).itemsize
    return total


def placement_report(cfg: ModelConfig, shape: ShapeConfig, run, mesh: Mesh,
                     rules: ShardingRules = DEFAULT_RULES) -> Dict[str, float]:
    """Per-device GB by residency class for one (arch × shape × mesh) cell.

    This is the number the scheduler wants *before* paying a compile: does
    the cell fit HBM, and how is it split between state, cache, and batch?
    """
    out: Dict[str, float] = {}
    kind = shape.kind
    bs = batch_specs(cfg, shape, kind)
    out["batch_gb"] = sharded_bytes(bs, batch_shardings(bs, mesh, rules)) / 1e9
    if kind == "train":
        out["state_gb"] = sharded_bytes(
            state_specs(cfg, run), state_shardings(cfg, mesh, rules, run)) / 1e9
    else:
        out["params_gb"] = sharded_bytes(
            param_specs(cfg, serve=True), param_shardings(cfg, mesh, rules)) / 1e9
        out["cache_gb"] = sharded_bytes(
            cache_specs(cfg, shape, run),
            cache_shardings(cfg, shape, mesh, rules, run)) / 1e9
    out["resident_gb"] = round(sum(out.values()), 3)
    if kind != "train" and cfg.cache_layout == "paged":
        # the admission-control number: pages the scheduler must find free
        out["cache_pages"] = float(decode_page_budget(cfg, shape, run))
    if kind == "decode" and cfg.cache_layout == "paged":
        # per-step decode bandwidth pricing: the scheduler/roofline should
        # charge the kernel's resident-page walk, not the dense-view bound
        # (for MLA that walk reads latent pages — priced as such)
        import numpy as np
        n_dev = int(np.prod(list(mesh.shape.values())))   # AbstractMesh-safe
        out["decode_attn_gb_step"] = decode_attn_bytes(
            cfg, shape, run, "kernel") / n_dev / 1e9
        out["decode_attn_gb_step_ref"] = decode_attn_bytes(
            cfg, shape, run, "reference") / n_dev / 1e9
        if cfg.use_mla:
            # what the step would read had the latents been expanded to
            # per-head K/V — the scheduler's case for the latent layout
            out["decode_attn_gb_step_dense_equiv"] = decode_attn_bytes(
                cfg, shape, run, "dense_expanded") / n_dev / 1e9
        if getattr(run, "prefix_share_frac", 0.0) > 0.0:
            # dedup-aware residency/bandwidth: aliased prefix pages are
            # physically one page — price what is actually resident/read,
            # not the per-sequence double count
            from repro.models.model import num_pages as _np
            occ = getattr(run, "page_occupancy", 1.0)
            r = max(int(-(-_np(shape.seq_len, cfg.page_size) * occ // 1)), 1)
            out["decode_attn_gb_step_unique"] = decode_attn_bytes(
                cfg, shape, run, "kernel_unique") / n_dev / 1e9
            out["cache_pages_unique"] = float(
                unique_decode_pages(shape.global_batch, r, run))
    return {k: round(v, 3) for k, v in out.items()}
