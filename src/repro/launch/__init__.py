"""Launcher layer: dry-run compilation, perf hillclimb, serve loop.

NOTE: ``repro.launch.dryrun`` / ``perf`` / ``analysis`` set ``XLA_FLAGS``
(512 fake host devices) at import — import them only in a fresh process.
"""
