import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline cost extraction with scan-trip-count correction.

XLA's HloCostAnalysis gives a while-loop body constant weight regardless of
trip count (verified experimentally — see EXPERIMENTS.md §Dry-run), so raw
cost_analysis() of the scanned layer stack is wrong.  Fix: compile the same
cell with the layer scan UNROLLED at n_groups = 2 and 3 (microbatches pinned
to 1 so the grad-accum loop disappears; that moves FLOPs between loops but
not their total) and fit linearly:

    cost(G) = cost(2) + (cost(3) - cost(2)) · (G - 2)

Verified linear to <2% (the g=1 point is excluded: XLA simplifies
single-layer programs more aggressively).  This captures everything in the
body — remat recompute, per-layer collectives, attention block skipping —
at exact HLO fidelity.  Collective bytes extrapolate per op kind the same
way.  Known residual: the RWKV intra-chunk scan stays rolled (its einsums
are <1% of layer FLOPs; noted in EXPERIMENTS.md).

Writes artifacts/analysis/<arch>__<shape>__16x16.json (single-pod: the
roofline table mesh) and, with --multi-pod, the 2x16x16 variant.
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "analysis"

_COLL_KEYS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
              "collective-permute")


def _variant_cfg(cfg, g: int):
    """Same family, n_groups = g (prefix/tail preserved)."""
    P = len(cfg.block_pattern)
    body = cfg.num_layers - cfg.first_k_dense
    tail = body % P
    n_layers = cfg.first_k_dense + g * P + tail
    kw = {"num_layers": n_layers}
    if cfg.is_encoder_decoder:
        full_groups = body // P
        kw["num_encoder_layers"] = max(
            1, cfg.num_encoder_layers * g // full_groups)
    return dataclasses.replace(cfg, **kw)


def _measure(arch, shape_name, multi_pod, cfg, run):
    from repro.launch.dryrun import build_lowered, parse_collectives
    lowered, meta = build_lowered(arch, shape_name, multi_pod,
                                  cfg_override=cfg, run_override=run,
                                  scan_unroll=True)
    if lowered is None:
        return None
    compiled = lowered.compile()
    from repro.launch.dryrun import cost_dict
    cost = cost_dict(compiled)
    rec = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
        "collectives": parse_collectives(compiled.as_text()),
    }
    return rec


def _extrapolate(c2, c3, G: int):
    """cost(G) from the (g=2, g=3) unrolled fit points."""
    out = {}
    for k in ("flops", "bytes", "transcendentals"):
        slope = c3[k] - c2[k]
        out[k] = c2[k] + slope * (G - 2)
    colls = {}
    keys = set(c2["collectives"]) | set(c3["collectives"])
    for op in keys:
        a = c2["collectives"].get(op, {"count": 0, "bytes": 0, "wire_bytes": 0})
        b = c3["collectives"].get(op, {"count": 0, "bytes": 0, "wire_bytes": 0})
        colls[op] = {
            key: a[key] + (b[key] - a[key]) * (G - 2)
            for key in ("count", "bytes", "wire_bytes")}
    out["collectives"] = colls
    return out


def run_analysis(arch: str, shape_name: str, multi_pod: bool) -> dict:
    from repro.configs import SHAPES, get_config, get_run_config, shape_applicable
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        return {"ok": True, "skipped": why, **meta}

    run = get_run_config(arch, shape_name)
    run1 = dataclasses.replace(run, num_microbatches=1)
    P = len(cfg.block_pattern)
    G = (cfg.num_layers - cfg.first_k_dense) // P

    t0 = time.perf_counter()
    c2 = _measure(arch, shape_name, multi_pod, _variant_cfg(cfg, 2), run1)
    c3 = _measure(arch, shape_name, multi_pod, _variant_cfg(cfg, 3), run1)
    full = _extrapolate(c2, c3, G)
    return {
        "ok": True, **meta,
        "n_groups": G,
        "seconds": round(time.perf_counter() - t0, 1),
        "g2": c2, "g3": c3,
        "extrapolated": full,
    }


def cell_path(arch, shape_name, multi_pod) -> Path:
    mesh = "2x16x16" if multi_pod else "16x16"
    return ARTIFACTS / f"{arch}__{shape_name}__{mesh}.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)
    ARTIFACTS.mkdir(parents=True, exist_ok=True)

    if args.all:
        from repro.launch.dryrun import all_cells
        fails = 0
        for arch, shape_name in all_cells():
            out = cell_path(arch, shape_name, args.multi_pod)
            if out.exists() and not args.force:
                continue
            cmd = [sys.executable, "-m", "repro.launch.analysis",
                   "--arch", arch, "--shape", shape_name]
            if args.multi_pod:
                cmd.append("--multi-pod")
            print(f"[analysis] {arch} × {shape_name} ...", flush=True)
            if subprocess.run(cmd, timeout=3600).returncode:
                fails += 1
        return 1 if fails else 0

    assert args.arch and args.shape
    out = cell_path(args.arch, args.shape, args.multi_pod)
    if out.exists() and not args.force:
        print(f"[analysis] cached: {out}")
        return 0
    try:
        rec = run_analysis(args.arch, args.shape, args.multi_pod)
    except Exception as e:
        import traceback
        rec = {"ok": False, "arch": args.arch, "shape": args.shape,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-3000:]}
    out.write_text(json.dumps(rec, indent=2))
    print(json.dumps({k: rec.get(k) for k in ("ok", "arch", "shape",
                                              "skipped", "error")}))
    return 0 if rec.get("ok") else 1


if __name__ == "__main__":
    sys.exit(main())
