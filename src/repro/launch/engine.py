"""Resumable continuous-batching serving engine + the real-payload seam.

``launch/executor.py:run_continuous`` used to be a ~170-line monolith whose
entire state (page pool, slot table, host page table, queue cursor) was
function-locals — unrecoverable, unpreemptible, unreachable from a platform
job.  :class:`ServingEngine` is that loop turned into an explicit state
machine:

* **admit** — one admission round: FIFO requests from the queue into free
  decode slots, gated by a per-shard *worst-case page reservation* scaled
  by ``ServeSpec.overcommit`` (1.0 = the old conservative admission;
  > 1.0 = optimistic admission with preemption).  Pages are allocated
  lazily (prompt pages at admission, one page at a time as decode grows),
  so overcommitted admission can actually run out — see evict.  With
  **prefix caching** on (the default for all-global paged decoders), each
  prompt's full pages are chain-hashed against the shard's prefix index:
  hits are attached read-only with a refcount bump — no prefill compute,
  no new residency — a first-divergent-token overlap gets its page
  copy-on-write duplicated, and the round's single ragged prefill covers
  only the uncached tails (at per-row start offsets).  A request whose
  prefix is being prefilled by an earlier request in the same round
  defers one round and attaches instead of recomputing, so N requests
  sharing a P-token prefix pay ~one prefill and one set of resident
  prefix pages.
* **step** — one batched decode step over every active slot; grows each
  sequence's page list on demand first.  On page exhaustion the engine
  **evicts the youngest sequence in the starving shard** back to the front
  of the queue (requeue-on-eviction): its pages are freed, its partial
  generation is discarded, and re-admission re-prefills from the prompt.
  Greedy decode is deterministic, so the re-generated response is
  identical — no request is ever lost or answered differently.  The
  oldest sequence in a shard is never evicted, so it always completes:
  admission is reservation-bounded and the queue drains FIFO — no
  deadlock, no livelock.
* **finish** — frees pages, logs the completed response (exactly-once by
  request id), releases the reservation.
* **snapshot / restore** — the whole engine state (pool free lists, slot
  records, host page table, queue, responses, the append-only
  :attr:`journal` of admissions/evictions/completions, KV-cache arrays
  pulled to host) as one plain-Python structure.  ``restore`` on a fresh
  engine reproduces the exact device state, so a killed-and-restarted
  server continues **byte-identically** with the uninterrupted run.  A
  platform pod persists snapshots to the job volume, journals request
  *claims* there separately, and replays the claim suffix after
  ``restore`` to recover requests claimed after the last snapshot (see
  ``core/server.py``).

:class:`RealServePayload` / :class:`RealDryRunPayload` are the builders the
``FrameworkAdapter.payload`` hook returns so platform serve jobs run this
engine (and dryrun jobs real compile cells) inside their workload pods,
under the unchanged Guardian/LCM dependability machinery.
"""
from __future__ import annotations

import hashlib
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.jobspec import JobSpec, ServeSpec

#: Parent hash of a prompt's first page in the chained prefix hash.
PREFIX_ROOT = "root"


def page_chain_hashes(tokens, page_size: int) -> List[Tuple[str, str]]:
    """``(parent_hash, chain_hash)`` for every FULL page of a prompt.

    The chain hash of page ``i`` commits to the entire prefix through
    page ``i`` (it hashes the parent's chain hash plus the page's token
    ids), so two prompts share page ``i`` iff they agree on ALL tokens
    up to and including it — a hash hit is a safe alias, not a guess.
    blake2b, not Python's builtin ``hash``: the index must round-trip
    snapshots byte-identically across process incarnations, and builtin
    hashes are salted per process."""
    toks = np.asarray(tokens, np.int64)
    out: List[Tuple[str, str]] = []
    parent = PREFIX_ROOT
    for i in range(len(toks) // page_size):
        chunk = toks[i * page_size:(i + 1) * page_size]
        h = hashlib.blake2b(parent.encode() + chunk.tobytes(),
                            digest_size=16).hexdigest()
        out.append((parent, h))
        parent = h
    return out


class PagePool:
    """Host-side physical-page allocator for the paged KV cache.

    Manages page ids ``0 .. n_pages-1``.  ``n_shards > 1`` partitions the
    id space into contiguous per-shard free lists.  The pool's pages dim
    shards contiguously over the data axis (``cache_pages`` rule), so
    allocating a sequence's pages from its own data shard's range keeps
    every decode gather/scatter data-shard-local — the runtime half of the
    locality contract whose spec half is
    ``dist.sharding.check_cache_locality``.

    Pages are **refcounted** so prefix caching can alias one physical page
    into many sequences' tables: ``alloc`` hands pages out at refcount 1,
    ``attach`` bumps a cached page (pulling it back off the free list if
    it was cached-but-free), ``free`` decrements — a page returns to its
    shard's free list only when nobody references it.  Hash-addressed
    prefix metadata (chain hash, parent hash, token content) lives in
    ``page_meta`` with a per-shard ``prefix_index`` mapping
    ``parent_hash -> {chain_hash: page}``.  A freed page KEEPS its
    metadata (cached-but-free, vLLM-style: the KV bytes are intact until
    the allocator reuses the physical page, at which point ``alloc``
    deregisters it) — so a finished sequence's prefix stays hittable for
    followers at zero residency cost.
    """

    def __init__(self, n_pages: int, n_shards: int = 1):
        assert n_shards >= 1 and n_pages % n_shards == 0, (n_pages, n_shards)
        self.n_pages = n_pages
        self.n_shards = n_shards
        per = n_pages // n_shards
        self.free_lists: List[List[int]] = [
            list(range(s * per, (s + 1) * per)) for s in range(n_shards)]
        self.high_water = 0
        self.refcount: List[int] = [0] * n_pages
        # page -> {"parent": str, "hash": str, "tokens": [int]}
        self.page_meta: Dict[int, dict] = {}
        # per shard: parent_hash -> {chain_hash: page}
        self.prefix_index: List[Dict[str, Dict[str, int]]] = [
            {} for _ in range(n_shards)]

    @property
    def in_use(self) -> int:
        """Unique resident pages (each aliased page counts once)."""
        return self.n_pages - sum(len(f) for f in self.free_lists)

    def shard_of(self, p: int) -> int:
        per = self.n_pages // self.n_shards
        return min(p // per, self.n_shards - 1)

    def alloc(self, n: int, shard: int = 0) -> Optional[List[int]]:
        fl = self.free_lists[shard]
        if n > len(fl):
            return None
        pages, self.free_lists[shard] = fl[:n], fl[n:]
        for p in pages:
            assert self.refcount[p] == 0, (p, self.refcount[p])
            self.refcount[p] = 1
            self._deregister(p)          # physical reuse ends its cache life
        self.high_water = max(self.high_water, self.in_use)
        return pages

    def free(self, pages: List[int]) -> None:
        """Drop one reference per page; pages nobody references anymore
        return to their home shard's free list (metadata retained —
        cached-but-free until reallocated)."""
        for p in pages:
            assert self.refcount[p] > 0, f"free of unreferenced page {p}"
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self.free_lists[self.shard_of(p)].append(p)

    def attach(self, p: int) -> None:
        """Add a reference to a cached page (prefix hit).  A
        cached-but-free page leaves the free list again — its KV bytes
        were never touched, so no prefill is needed."""
        if self.refcount[p] == 0:
            self.free_lists[self.shard_of(p)].remove(p)
        self.refcount[p] += 1
        self.high_water = max(self.high_water, self.in_use)

    def lookup(self, shard: int, parent: str, chain: str) -> Optional[int]:
        return self.prefix_index[shard].get(parent, {}).get(chain)

    def candidates(self, shard: int, parent: str) -> Dict[str, int]:
        """All cached continuations of ``parent`` (CoW donor search)."""
        return self.prefix_index[shard].get(parent, {})

    def publish(self, page: int, parent: str, chain: str, tokens) -> bool:
        """Register a full, immutable page in the prefix index.  First
        publisher wins: an already-indexed chain (or a page already
        carrying metadata) is left alone."""
        idx = self.prefix_index[self.shard_of(page)]
        kids = idx.setdefault(parent, {})
        if chain in kids or page in self.page_meta:
            if not kids:
                del idx[parent]
            return False
        kids[chain] = page
        self.page_meta[page] = {"parent": parent, "hash": chain,
                                "tokens": [int(t) for t in tokens]}
        return True

    def _deregister(self, p: int) -> None:
        meta = self.page_meta.pop(p, None)
        if meta is None:
            return
        idx = self.prefix_index[self.shard_of(p)]
        kids = idx.get(meta["parent"])
        if kids is not None and kids.get(meta["hash"]) == p:
            del kids[meta["hash"]]
            if not kids:
                del idx[meta["parent"]]


def _set_page_tables(cache, host_table: np.ndarray):
    """Broadcast the (B, pps) host page table into every per-layer
    ``page_table`` leaf (layers index their own pools identically)."""
    import jax
    import jax.numpy as jnp

    table = jnp.asarray(host_table, jnp.int32)

    leaves, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for path, leaf in leaves:
        if getattr(path[-1], "key", None) == "page_table":
            out.append(jnp.broadcast_to(table, leaf.shape).astype(jnp.int32))
        else:
            out.append(leaf)
    return jax.tree.unflatten(treedef, out)


def _copy_pool_pages(cache, pairs: List[Tuple[int, int]]):
    """Device-side ``src -> dst`` page copies in every layer's K/V pool —
    the copy half of copy-on-write: a sequence diverging mid-page from a
    cached prefix gets the partially-shared page duplicated into its own
    private page, then the chunk prefill overwrites the divergent tail
    slots.  Scanned-group pool leaves carry a leading layers dim, so the
    pages axis is 1 there and 0 on unrolled leaves (mirrors
    ``models.model._slot_axis``)."""
    import jax
    import jax.numpy as jnp

    srcs = jnp.asarray([s for s, _ in pairs], jnp.int32)
    dsts = jnp.asarray([d for _, d in pairs], jnp.int32)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for path, leaf in leaves:
        if getattr(path[-1], "key", None) in ("k_pages", "v_pages",
                                              "ckv_pages", "krope_pages"):
            ax = 1 if any(getattr(p, "key", None) == "groups"
                          for p in path) else 0
            vals = jnp.take(leaf, srcs, axis=ax)
            leaf = leaf.at[dsts].set(vals) if ax == 0 \
                else leaf.at[:, dsts].set(vals)
        out.append(leaf)
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Requests and per-slot records
# ---------------------------------------------------------------------------
@dataclass
class Request:
    """One serving request: a prompt and a greedy generation budget."""

    req: int                       # stable id (claim index on the platform)
    tokens: np.ndarray             # (L,) prompt token ids
    gen_len: int                   # tokens to generate (incl. prefill token)


@dataclass
class SeqRecord:
    """Everything the engine knows about one active decode slot."""

    request: Request
    pages: List[int]               # physical pages held, table order
    shard: int
    need_worst: int                # reserved pages (worst case minus shared)
    remaining: int                 # tokens still to generate
    out_tokens: List[int] = field(default_factory=list)
    admit_seq: int = 0             # admission order; larger = younger
    n_shared: int = 0              # leading pages attached from the index
    cached_tokens: int = 0         # prompt tokens served from the cache


class ServingEngine:
    """Continuous batching over the paged cache as a resumable state
    machine.  See the module docstring for the state-machine contract."""

    def __init__(self, cfg, ctx, params, sv: ServeSpec):
        import jax.numpy as jnp  # noqa: F401  (fail fast without jax)

        from repro.configs.base import GLOBAL_ATTN
        from repro.models.model import init_cache, num_pages
        from repro.train.steps import make_serve_steps

        # ValueError, not SystemExit: inside a platform pod these must fail
        # THIS pod/job (sim catches Exception), never the whole simulator;
        # run_continuous maps them to SystemExit for the CLI
        if cfg.cache_layout != "paged":
            raise ValueError("--continuous requires --layout paged")
        # ragged (one batched prefill per admission round) covers every
        # decoder-only stack: paged globals + ring locals mask their
        # writes, paged MLA latents scatter per row, recurrent/RWKV
        # carries are length-masked.  Enc-dec keeps the per-slot path —
        # the cross K/V of rows not in the round would be overwritten.
        ragged_ok = not cfg.is_encoder_decoder
        ragged = ragged_ok if sv.ragged_prefill is None else sv.ragged_prefill
        if ragged and not ragged_ok:
            raise ValueError(
                "--ragged-prefill needs a decoder-only stack; the encoder "
                "output is per-round, so enc-dec prefills per slot")
        # hash-addressed prefix caching: needs the chunked-prefill seam,
        # which covers all-global paged decoders only (ring locals would
        # have to replay the evicted prefix; vision frontends shift pos 0)
        self.prefix_cache = bool(sv.prefix_cache) and ragged \
            and set(cfg.layer_kinds()) == {GLOBAL_ATTN} \
            and cfg.frontend != "vision"

        B, P, G = sv.batch, sv.prompt_len, sv.gen
        self.cfg, self.ctx, self.params, self.sv = cfg, ctx, params, sv
        self.ragged = ragged
        self.B = B
        self.ps = cfg.page_size
        self.max_len = P + G
        self.pps = num_pages(self.max_len, self.ps)
        budget = sv.page_budget or B * self.pps
        if budget < self.pps:
            raise ValueError(f"--page-budget {budget} cannot hold one "
                             f"request ({self.pps} pages)")
        self.overcommit = sv.overcommit or 1.0
        if self.overcommit < 1.0:
            raise ValueError(f"--overcommit {self.overcommit} must be >= 1")

        self.prefill, self.decode = make_serve_steps(cfg, ctx)
        from repro.launch.specs import src_len_for
        self.src_len = src_len_for(cfg, self.max_len)
        self.cache = init_cache(cfg, B, self.max_len, self.src_len,
                                layout="paged", page_budget=budget,
                                paged_tables="empty")

        # page→data-shard locality (see PagePool); one shard when the budget
        # doesn't split evenly or a shard couldn't hold a full request
        n_shards = dict(zip(ctx.mesh.axis_names, ctx.mesh.axis_sizes)).get(
            "data", 1) if ctx.mesh is not None else 1
        if budget % n_shards or B % n_shards \
                or budget // n_shards < self.pps:
            n_shards = 1
        self.pool = PagePool(budget, n_shards)
        self.per_shard = budget // n_shards
        self.reserved = [0] * n_shards          # worst-case pages admitted
        self.host_table = np.full((B, self.pps), -1, np.int32)

        self.slots: List[Optional[SeqRecord]] = [None] * B
        self.toks = np.zeros((B, 1), np.int64)
        self.pos = np.full((B,), -1, np.int64)
        self.queue: Deque[Request] = deque()
        self.responses: Dict[int, List[int]] = {}
        self.journal: List[dict] = []

        # stats
        self.decode_steps = 0
        self.generated = 0
        self.stalled_admissions = 0
        self.evictions = 0
        self._admit_seq = 0
        self.prefill_tokens = 0      # prompt tokens actually computed
        self.cached_tokens = 0       # prompt tokens served from the cache
        self.prefix_hits = 0         # admissions reusing >= 1 cached page
        self.prefix_misses = 0
        self.cow_copies = 0          # copy-on-write page duplications

    def _src_embeds(self, req_id: int):
        """Deterministic stub frontend embeddings for one enc-dec request,
        keyed on the request id alone — an evict-replay or a restored
        incarnation re-synthesizes the identical encoder input, keeping
        the cross K/V (and so the whole continuation) byte-identical."""
        import jax
        import jax.numpy as jnp

        return 0.02 * jax.random.normal(
            jax.random.key(req_id), (1, self.src_len, self.cfg.d_model),
            jnp.float32)

    # -- queue -------------------------------------------------------------
    def submit(self, request: Request) -> None:
        """Enqueue a request (FIFO).  Rejects requests whose worst-case
        page need exceeds a shard's capacity — admitting one would
        deadlock the pool."""
        from repro.models.model import num_pages
        need = num_pages(len(request.tokens) + request.gen_len, self.ps)
        if need > self.per_shard:
            raise ValueError(
                f"request {request.req} needs {need} pages worst-case; "
                f"a shard holds {self.per_shard}")
        self.queue.append(request)

    def free_slot_count(self) -> int:
        return sum(1 for s in self.slots if s is None)

    @property
    def idle(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)

    def active_records(self) -> List[SeqRecord]:
        return [s for s in self.slots if s is not None]

    def _shard_of(self, b: int) -> int:
        return b * self.pool.n_shards // self.B

    def unique_resident_pages(self) -> int:
        """Physical pages referenced by anyone (aliases count once)."""
        return self.pool.in_use

    def resident_prefix_pages(self) -> int:
        """Unique physical pages serving some active sequence's cached
        prompt span — the residency N prefix-sharing requests split."""
        return len({p for rec in self.slots if rec is not None
                    for p in rec.pages[:rec.n_shared]})

    # -- prefix matching ---------------------------------------------------
    def _match_prefix(self, req: Request, shard: int, pending) -> tuple:
        """Match a prompt against the shard's prefix index.

        Returns ``(shared, cow, C, hashes, defer)``: the leading cached
        pages to attach read-only, an optional ``(src_page, overlap)``
        copy-on-write donor for the first divergent page, the number of
        prompt tokens served from the cache (``C = full-page span +
        overlap``), the prompt's per-page chain hashes, and whether to
        defer admission because an unmatched hash is being published by
        THIS round's prefill (first-come-first-prefilled: the follower
        waits one round and attaches instead of recomputing).

        At least one prompt token is always left uncached (cap at
        ``(L-1)//ps`` pages / ``L-1`` tokens): the next-token logits need
        the last prompt token's hidden state, so a fully-cached prompt
        must still compute its final token."""
        L = len(req.tokens)
        if not self.prefix_cache:
            return [], None, 0, [], False
        hashes = page_chain_hashes(req.tokens, self.ps)
        shared: List[int] = []
        for i in range((L - 1) // self.ps):
            parent, chain = hashes[i]
            page = self.pool.lookup(shard, parent, chain)
            if page is None:
                if chain in pending:
                    return [], None, 0, hashes, True
                break
            shared.append(page)
        m = len(shared)
        cow = None
        parent = hashes[m - 1][1] if m else PREFIX_ROOT
        limit = min(self.ps, L - 1 - m * self.ps)
        if limit > 0:
            chunk = np.asarray(req.tokens[m * self.ps:
                                          m * self.ps + limit], np.int64)
            best_page, best_ov = None, 0
            # deterministic donor choice: sorted by chain hash
            for chain in sorted(self.pool.candidates(shard, parent)):
                page = self.pool.candidates(shard, parent)[chain]
                ptoks = np.asarray(
                    self.pool.page_meta[page]["tokens"][:limit], np.int64)
                n = min(len(chunk), len(ptoks))
                ne = chunk[:n] != ptoks[:n]
                ov = int(np.argmax(ne)) if ne.any() else n
                if ov > best_ov:
                    best_page, best_ov = page, ov
            if best_ov > 0:
                cow = (best_page, best_ov)
        C = m * self.ps + (cow[1] if cow else 0)
        return shared, cow, C, hashes, False

    # -- admission ---------------------------------------------------------
    def admit(self) -> List[int]:
        """One admission round: FIFO queue head into free slots while the
        shard reservation (scaled by ``overcommit``) and the prompt's
        physical pages are available.  Runs ONE batched ragged prefill for
        the whole round on attention-only stacks (per-slot view prefill
        otherwise).  Returns the admitted request ids."""
        import jax.numpy as jnp

        from repro.models.model import (
            cache_slot_merge, cache_slot_view, num_pages)

        admitted: List[tuple] = []               # (slot, request)
        plans: Dict[int, tuple] = {}             # slot -> (C, hashes, m)
        cow_pairs: List[Tuple[int, int]] = []    # (src, dst) page copies
        pending: set = set()                     # hashes this round publishes
        for b in range(self.B):
            if self.slots[b] is not None or not self.queue:
                continue
            req = self.queue[0]
            shard = self._shard_of(b)
            L = len(req.tokens)
            need_worst = num_pages(L + req.gen_len, self.ps)
            cap = int(self.overcommit * self.per_shard)
            prompt_pages = num_pages(L, self.ps)
            shared, cow, C, hashes, defer = self._match_prefix(
                req, shard, pending)
            if defer:
                # its prefix is being prefilled RIGHT NOW by an earlier
                # request in this round — next round it is a cache hit
                self.stalled_admissions += 1
                break                            # FIFO: no out-of-order admit
            m = len(shared)
            # shared pages are refcount-held, not stolen-from, so only the
            # private remainder needs a worst-case reservation — dedup
            # shows up directly as admission capacity
            reserve = need_worst - m
            if self.reserved[shard] + reserve > cap:
                self.stalled_admissions += 1
                break
            # attach BEFORE alloc: a cached-but-free shared page must
            # leave the free list before the allocator could hand it out
            # as somebody's private page
            for p in shared:
                self.pool.attach(p)
            pages = self.pool.alloc(prompt_pages - m, shard)
            if pages is None:
                self.pool.free(shared)           # roll the attaches back
                self.stalled_admissions += 1
                break
            self.queue.popleft()
            self.reserved[shard] += reserve
            pages = shared + pages
            self.host_table[b, :prompt_pages] = pages
            self.host_table[b, prompt_pages:] = -1
            self._admit_seq += 1
            self.slots[b] = SeqRecord(
                request=req, pages=pages, shard=shard,
                need_worst=reserve, remaining=req.gen_len,
                admit_seq=self._admit_seq, n_shared=m, cached_tokens=C)
            if cow is not None:
                # duplicate the partially-shared page into this sequence's
                # first private page; the chunk overwrites the divergent
                # suffix slots before anything reads them
                cow_pairs.append((cow[0], pages[m]))
                self.cow_copies += 1
            if self.prefix_cache:
                if C > 0:
                    self.prefix_hits += 1
                else:
                    self.prefix_misses += 1
                pending.update(ch for _, ch in hashes[m:L // self.ps])
            plans[b] = (C, hashes, m)
            admitted.append((b, req))

        if not admitted:
            return []
        self.cache = _set_page_tables(self.cache, self.host_table)

        if self.ragged:
            # one batched ragged prefill for the whole round over the
            # UNCACHED prompt tails only: pad to the round's max tail,
            # bucketed to a page multiple (bounds recompiles)
            round_max = max(len(r.tokens) - plans[b][0] for b, r in admitted)
            S0 = -(-round_max // self.ps) * self.ps
            toks_in = np.zeros((self.B, S0), admitted[0][1].tokens.dtype)
            lens = np.zeros((self.B,), np.int32)
            starts = np.zeros((self.B,), np.int32)
            for b, r in admitted:
                C = plans[b][0]
                toks_in[b, :len(r.tokens) - C] = r.tokens[C:]
                lens[b] = len(r.tokens) - C
                starts[b] = C
            if cow_pairs:
                self.cache = _copy_pool_pages(self.cache, cow_pairs)
            if self.prefix_cache:
                # chunked path even at starts == 0: one numeric family for
                # every prefill, so evict-replay stays byte-identical
                logits, self.cache = self.prefill(
                    self.params, {"tokens": jnp.asarray(toks_in)},
                    self.cache, jnp.asarray(lens), jnp.asarray(starts))
            else:
                logits, self.cache = self.prefill(
                    self.params, {"tokens": jnp.asarray(toks_in)},
                    self.cache, jnp.asarray(lens))
            nxt_tok = np.asarray(jnp.argmax(logits[:, -1], axis=-1))

        out: List[int] = []
        for b, r in admitted:
            if not self.ragged:
                view = cache_slot_view(self.cache, self.B, b)
                batch = {"tokens": jnp.asarray(r.tokens[None])}
                if self.cfg.is_encoder_decoder:
                    batch["src_embeds"] = self._src_embeds(r.req)
                logits, view = self.prefill(self.params, batch, view)
                self.cache = cache_slot_merge(self.cache, view, self.B, b)
                tok = int(jnp.argmax(logits[0, -1]))
            else:
                tok = int(nxt_tok[b])
            rec = self.slots[b]
            C, hashes, m = plans[b]
            if self.prefix_cache:
                # the round's freshly prefilled full pages become cache
                # content (including a full CoW page — its bytes are now
                # exactly the chain's)
                for i in range(m, len(r.tokens) // self.ps):
                    parent, chain = hashes[i]
                    self.pool.publish(
                        rec.pages[i], parent, chain,
                        r.tokens[i * self.ps:(i + 1) * self.ps])
            self.prefill_tokens += len(r.tokens) - C
            self.cached_tokens += C
            rec.out_tokens.append(tok)
            rec.remaining -= 1
            self.toks[b, 0] = tok
            self.pos[b] = len(r.tokens)
            self.generated += 1
            self.journal.append({"ev": "admit", "req": r.req, "slot": b,
                                 "cached": C})
            out.append(r.req)
            if rec.remaining <= 0:
                self.finish(b)                   # gen_len == 1: prefill was it
        return out

    # -- eviction (preemption / requeue path) --------------------------------
    def evict(self, b: int) -> int:
        """Preempt slot ``b`` back to the FRONT of the queue: free its
        pages, release its reservation, discard its partial generation
        (re-admission re-prefills the prompt; greedy decode regenerates
        the identical response).  Crash recovery and preemption share this
        one path.  Returns the evicted request id."""
        rec = self.slots[b]
        assert rec is not None, f"evict of empty slot {b}"
        self.pool.free(rec.pages)
        self.reserved[rec.shard] -= rec.need_worst
        self.host_table[b, :] = -1
        self.cache = _set_page_tables(self.cache, self.host_table)
        self.slots[b] = None
        self.pos[b] = -1
        self.toks[b, 0] = 0
        self.queue.appendleft(rec.request)
        self.evictions += 1
        self.journal.append({"ev": "evict", "req": rec.request.req,
                             "slot": b})
        return rec.request.req

    def _youngest_in_shard(self, shard: int) -> Optional[int]:
        best, best_seq = None, -1
        for b, rec in enumerate(self.slots):
            if rec is not None and rec.shard == shard \
                    and rec.admit_seq > best_seq:
                best, best_seq = b, rec.admit_seq
        return best

    def _ensure_pages(self) -> None:
        """Grow every active sequence's page list to cover its next decode
        write.  On exhaustion, evict the youngest sequence in the starving
        shard (possibly the needy one itself) until the allocation
        succeeds — the shard's oldest sequence is never evicted, so it
        always completes (no deadlock)."""
        dirty = False
        for b in range(self.B):
            rec = self.slots[b]
            if rec is None:
                continue
            needed = int(self.pos[b]) // self.ps + 1
            while rec is not None and len(rec.pages) < needed:
                got = self.pool.alloc(1, rec.shard)
                if got is not None:
                    self.host_table[b, len(rec.pages)] = got[0]
                    rec.pages.extend(got)
                    dirty = True
                    continue
                victim = self._youngest_in_shard(rec.shard)
                assert victim is not None, \
                    "page exhaustion with no active sequence to evict"
                self.evict(victim)
                dirty = False  # evict() already pushed the table
                if victim == b:
                    rec = None                   # the needy one was youngest
        if dirty:
            self.cache = _set_page_tables(self.cache, self.host_table)

    # -- decode ------------------------------------------------------------
    def step(self) -> List[int]:
        """One batched decode step over every active slot (inactive rows
        carry pos = -1 and are masked inside the kernel).  Returns the
        request ids finished by this step."""
        import jax.numpy as jnp

        if all(s is None for s in self.slots):
            return []
        self._ensure_pages()
        if all(s is None for s in self.slots):
            return []                            # everything got evicted
        logits, self.cache = self.decode(
            self.params, {"tokens": jnp.asarray(self.toks)}, self.cache,
            jnp.asarray(self.pos, jnp.int32))
        self.decode_steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        finished: List[int] = []
        for b in range(self.B):
            rec = self.slots[b]
            if rec is None:
                continue
            tok = int(nxt[b])
            self.toks[b, 0] = tok
            self.pos[b] += 1
            rec.out_tokens.append(tok)
            self.generated += 1
            rec.remaining -= 1
            if rec.remaining <= 0:
                finished.append(rec.request.req)
                self.finish(b)
        return finished

    def finish(self, b: int) -> None:
        """Complete slot ``b``: free pages, release the reservation, log
        the response (exactly-once by request id — a deterministic
        re-execution after restore rewrites identical bytes)."""
        rec = self.slots[b]
        assert rec is not None, f"finish of empty slot {b}"
        self.pool.free(rec.pages)
        self.reserved[rec.shard] -= rec.need_worst
        self.host_table[b, :] = -1
        self.cache = _set_page_tables(self.cache, self.host_table)
        prev = self.responses.get(rec.request.req)
        assert prev is None or prev == rec.out_tokens, \
            (rec.request.req, prev, rec.out_tokens)
        self.responses[rec.request.req] = list(rec.out_tokens)
        self.journal.append({"ev": "finish", "req": rec.request.req,
                             "tokens": list(rec.out_tokens)})
        self.slots[b] = None
        self.pos[b] = -1
        self.toks[b, 0] = 0

    # -- snapshot / restore --------------------------------------------------
    def snapshot(self) -> dict:
        """The complete engine state as plain host data.  ``restore`` of
        this structure on a fresh engine (same cfg/params) reproduces the
        device state exactly — continuation is byte-identical."""
        import jax

        def rec_doc(rec: Optional[SeqRecord]):
            if rec is None:
                return None
            return {"req": rec.request.req,
                    "tokens": np.asarray(rec.request.tokens).copy(),
                    "gen_len": rec.request.gen_len,
                    "pages": list(rec.pages), "shard": rec.shard,
                    "need_worst": rec.need_worst,
                    "remaining": rec.remaining,
                    "out_tokens": list(rec.out_tokens),
                    "admit_seq": rec.admit_seq,
                    "n_shared": rec.n_shared,
                    "cached_tokens": rec.cached_tokens}

        return {
            "queue": [(r.req, np.asarray(r.tokens).copy(), r.gen_len)
                      for r in self.queue],
            "slots": [rec_doc(s) for s in self.slots],
            "host_table": self.host_table.copy(),
            "free_lists": [list(f) for f in self.pool.free_lists],
            "high_water": self.pool.high_water,
            "refcount": list(self.pool.refcount),
            "page_meta": {int(p): {"parent": m["parent"], "hash": m["hash"],
                                   "tokens": list(m["tokens"])}
                          for p, m in self.pool.page_meta.items()},
            "prefix_index": [{par: dict(kids) for par, kids in idx.items()}
                             for idx in self.pool.prefix_index],
            "reserved": list(self.reserved),
            "toks": self.toks.copy(),
            "pos": self.pos.copy(),
            "responses": {r: list(t) for r, t in self.responses.items()},
            "journal": [dict(e) for e in self.journal],
            "stats": {"decode_steps": self.decode_steps,
                      "generated": self.generated,
                      "stalled_admissions": self.stalled_admissions,
                      "evictions": self.evictions,
                      "admit_seq": self._admit_seq,
                      "prefill_tokens": self.prefill_tokens,
                      "cached_tokens": self.cached_tokens,
                      "prefix_hits": self.prefix_hits,
                      "prefix_misses": self.prefix_misses,
                      "cow_copies": self.cow_copies},
            "journal_len": len(self.journal),
            "cache": jax.device_get(self.cache),
        }

    def restore(self, snap: dict) -> None:
        """Load a :meth:`snapshot` into this (freshly built) engine."""
        import jax
        import jax.numpy as jnp

        self.queue = deque(Request(req=r, tokens=np.asarray(t),
                                   gen_len=g)
                           for r, t, g in snap["queue"])
        self.slots = []
        for doc in snap["slots"]:
            if doc is None:
                self.slots.append(None)
                continue
            self.slots.append(SeqRecord(
                request=Request(req=doc["req"],
                                tokens=np.asarray(doc["tokens"]),
                                gen_len=doc["gen_len"]),
                pages=list(doc["pages"]), shard=doc["shard"],
                need_worst=doc["need_worst"], remaining=doc["remaining"],
                out_tokens=list(doc["out_tokens"]),
                admit_seq=doc["admit_seq"],
                n_shared=doc.get("n_shared", 0),
                cached_tokens=doc.get("cached_tokens", 0)))
        self.host_table = np.asarray(snap["host_table"]).copy()
        self.pool.free_lists = [list(f) for f in snap["free_lists"]]
        self.pool.high_water = snap["high_water"]
        self.pool.refcount = list(snap["refcount"])
        self.pool.page_meta = {
            int(p): {"parent": m["parent"], "hash": m["hash"],
                     "tokens": [int(t) for t in m["tokens"]]}
            for p, m in snap["page_meta"].items()}
        self.pool.prefix_index = [
            {par: dict(kids) for par, kids in idx.items()}
            for idx in snap["prefix_index"]]
        self.reserved = list(snap["reserved"])
        self.toks = np.asarray(snap["toks"]).copy()
        self.pos = np.asarray(snap["pos"]).copy()
        self.responses = {r: list(t) for r, t in snap["responses"].items()}
        self.journal = [dict(e) for e in snap["journal"]]
        st = snap["stats"]
        self.decode_steps = st["decode_steps"]
        self.generated = st["generated"]
        self.stalled_admissions = st["stalled_admissions"]
        self.evictions = st["evictions"]
        self._admit_seq = st["admit_seq"]
        self.prefill_tokens = st.get("prefill_tokens", 0)
        self.cached_tokens = st.get("cached_tokens", 0)
        self.prefix_hits = st.get("prefix_hits", 0)
        self.prefix_misses = st.get("prefix_misses", 0)
        self.cow_copies = st.get("cow_copies", 0)
        self.cache = jax.tree.map(jnp.asarray, snap["cache"])

    # -- drive to completion --------------------------------------------------
    def run(self) -> None:
        """Drain the queue: alternate admission rounds and decode steps
        until nothing is queued or active (the old run_continuous loop)."""
        while not self.idle:
            self.admit()
            if all(s is None for s in self.slots):
                if not self.queue:
                    break                        # drained at prefill
                continue                         # re-admit (gen_len == 1 round)
            self.step()


# ---------------------------------------------------------------------------
# Workload synthesis (shared by the CLI and every platform replica)
# ---------------------------------------------------------------------------
def synthesize_requests(cfg, sv: ServeSpec, seed: int,
                        ragged: bool) -> List[Request]:
    """The deterministic request workload for a ServeSpec: every replica of
    a platform gang derives the identical list, so a claim index fully
    identifies a request (claim-then-serve exactly-once)."""
    import jax

    rng = np.random.default_rng(seed)
    n_req, P, G = sv.requests, sv.prompt_len, sv.gen
    prompts = np.array(jax.random.randint(
        jax.random.key(1), (n_req, P), 0, cfg.vocab_size))
    # shared-prefix workload (system prompt / few-shot template traffic):
    # every request opens with request 0's leading span
    C = int(round(P * getattr(sv, "shared_prefix_frac", 0.0)))
    if C > 0:
        prompts[:, :C] = prompts[0, :C]
    gen_lens = rng.integers(max(G // 2, 1), G + 1, size=n_req)
    # ragged workload: per-request prompt lengths in [P/2, P]; the lockstep
    # fallback serves every prompt at full length P.  Shared-prefix runs
    # keep full-length prompts so the share ratio is exact.
    prompt_lens = rng.integers(max(P // 2, 1), P + 1, size=n_req) \
        if ragged and C == 0 else np.full(n_req, P, np.int64)
    return [Request(req=r, tokens=prompts[r, :int(prompt_lens[r])].copy(),
                    gen_len=int(gen_lens[r])) for r in range(n_req)]


# ---------------------------------------------------------------------------
# Real payloads for platform workload pods (FrameworkAdapter.payload hook)
# ---------------------------------------------------------------------------
class RealServePayload:
    """Builds the real serving engine for one platform serve job.  Each pod
    incarnation calls :meth:`build` fresh — parameters are re-initialized
    from the job seed (pure function), so a restarted container holds the
    exact model the dead one did, and ``ServingEngine.restore`` + journal
    replay recover the serving state."""

    def __init__(self, spec: JobSpec):
        self.spec = spec

    def build(self):
        """Returns ``(engine, requests)`` for this job's ServeSpec."""
        import dataclasses

        import jax
        import jax.numpy as jnp

        from repro.configs import get_config
        from repro.launch.executor import _make_mesh
        from repro.models.layers import Ctx
        from repro.models.params import init_params

        spec, sv = self.spec, self.spec.serve
        cfg = get_config(spec.framework)
        if sv.reduced:
            cfg = cfg.reduced()
        overrides = {"cache_layout": sv.cache_layout or "paged"}
        if sv.page_size:
            overrides["page_size"] = sv.page_size
        cfg = dataclasses.replace(cfg, **overrides)
        ctx = Ctx(mesh=_make_mesh(sv.mesh),
                  dtype=jnp.float32 if sv.reduced else jnp.bfloat16,
                  use_pallas=sv.use_pallas)
        params = init_params(cfg, jax.random.key(spec.seed))
        engine = ServingEngine(cfg, ctx, params, sv)
        requests = synthesize_requests(cfg, sv, spec.seed, engine.ragged)
        return engine, requests


class RealDryRunPayload:
    """Real compile cells for a platform dryrun job.  ``run_cell`` lowers
    and compiles the cell for real (``launch.dryrun.run_cell``); tests may
    inject a cheaper cell runner via ``platform.register_payload``."""

    def __init__(self, spec: JobSpec, run_cell=None):
        self.spec = spec
        self._run_cell = run_cell

    def run_cell(self, cell) -> dict:
        if self._run_cell is not None:
            return self._run_cell(cell)
        from repro.launch import dryrun
        return dryrun.run_cell(cell.arch, cell.shape, cell.multi_pod)
