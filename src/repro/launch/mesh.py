"""Back-compat shim: mesh construction moved into the distribution
subsystem (``repro.dist.mesh``) so learners, the dry-run, and tests build
meshes from one place."""
from repro.dist.mesh import (  # noqa: F401
    axis_sizes,
    make_device_mesh,
    make_host_mesh,
    make_production_mesh,
)
