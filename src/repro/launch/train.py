"""Training CLI: a thin parse-to-spec layer over the shared executor.

Flags build a ``JobSpec(kind="train")``; ``repro.launch.executor`` runs it.
The same spec can be submitted to the platform instead
(``DLaaSPlatform.submit``) to run under the full dependability machinery.

CPU-runnable with --reduced (the same code path the production mesh uses;
on a real TPU slice drop --reduced and pass --mesh prod/multipod).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --reduced --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse

from repro.core.jobspec import JobSpec, TrainSpec
from repro.launch.executor import execute


def parse_spec(argv=None) -> JobSpec:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-overhead-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--mesh", default="host", choices=["host", "prod", "multipod"])
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    return JobSpec(
        name=f"train-{args.arch}",
        kind="train",
        framework=args.arch,
        seed=args.seed,
        train=TrainSpec(
            total_steps=args.steps,
            global_batch=args.batch,
            seq_len=args.seq,
            learning_rate=args.lr,
            num_microbatches=args.microbatches,
            remat_policy=args.remat,
            mesh=args.mesh,
            use_pallas=args.use_pallas,
            reduced=args.reduced,
            log_every=args.log_every,
        ))


def main(argv=None) -> int:
    return execute(parse_spec(argv))


if __name__ == "__main__":
    raise SystemExit(main())
