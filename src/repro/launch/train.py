"""Training driver: real JAX training of any registry architecture.

CPU-runnable with --reduced (the same code path the production mesh uses;
on a real TPU slice drop --reduced and pass --mesh prod/multipod).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --reduced --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, get_config
from repro.data.pipeline import SyntheticLMData
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.layers import Ctx
from repro.train.steps import init_train_state, make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-overhead-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--mesh", default="host", choices=["host", "prod", "multipod"])
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = {"host": make_host_mesh,
            "prod": make_production_mesh,
            "multipod": lambda: make_production_mesh(multi_pod=True)}[args.mesh]()
    ctx = Ctx(mesh=mesh, dtype=jnp.float32 if args.reduced else jnp.bfloat16,
              use_pallas=args.use_pallas)
    run = RunConfig(num_microbatches=args.microbatches,
                    remat_policy=args.remat, learning_rate=args.lr,
                    warmup_steps=max(args.steps // 20, 1),
                    total_steps=args.steps)
    state = init_train_state(cfg, jax.random.key(args.seed), run)
    data = SyntheticLMData(cfg.vocab_size, args.seq, args.batch, args.seed)
    step = jax.jit(make_train_step(cfg, ctx, run), donate_argnums=(0,))

    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"mesh={mesh.devices.shape} devices={mesh.devices.size}")
    t0 = time.time()
    for i in range(args.steps):
        state, m = step(state, data.batch_at(i))
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"  step {i:5d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}  "
                  f"lr {float(m['lr']):.2e}")
    dt = time.time() - t0
    tok = args.steps * args.batch * args.seq
    print(f"[train] {args.steps} steps in {dt:.1f}s "
          f"({tok/dt:.0f} tok/s incl. compile)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
