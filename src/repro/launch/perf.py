import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb harness: measure one cell under modified knobs.

    PYTHONPATH=src python -m repro.launch.perf --arch X --shape Y \
        [--override resid_seq=model] [--override seq=model] \
        [--microbatches N] [--constrain-scan-weights] [--tag note]

Prints the three roofline terms + temp memory, and appends a JSON line to
artifacts/perf_log.jsonl so every hypothesis→measure iteration is recorded.
"""
import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

ART = Path(__file__).resolve().parents[3] / "artifacts"

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def measure(arch, shape, overrides, mb=None, csw=False, multi_pod=False):
    import jax.numpy as jnp
    from repro.configs import get_config, get_run_config
    from repro.dist.sharding import DEFAULT_RULES
    from repro.launch.dryrun import build_lowered, cost_dict, parse_collectives
    from repro.launch.analysis import _variant_cfg, _extrapolate
    from repro.models.layers import Ctx
    from repro.launch.mesh import make_production_mesh

    run = get_run_config(arch, shape)
    if mb is not None:
        run = dataclasses.replace(run, num_microbatches=mb)
    if overrides:
        run = dataclasses.replace(
            run, sharding_overrides=tuple((k, tuple(v.split("+")) if v else ())
                                          for k, v in overrides.items()))

    def _build(cfg_override=None, run_override=None, unroll=False):
        rules = DEFAULT_RULES
        r = run_override or run
        if r.sharding_overrides:
            rules = rules.override(**{k: v for k, v in r.sharding_overrides})
        return build_lowered(
            arch, shape, multi_pod, rules=rules, cfg_override=cfg_override,
            run_override=r, scan_unroll=unroll,
            constrain_scan_weights=csw)

    # memory from the FULL config compile
    t0 = time.perf_counter()
    lowered, meta = _build()
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    temp = int(getattr(mem, "temp_size_in_bytes", 0))
    arg = int(getattr(mem, "argument_size_in_bytes", 0))

    # roofline terms from unrolled g=2/3 variants
    cfg = get_config(arch)
    from repro.configs import SHAPES
    G = (cfg.num_layers - cfg.first_k_dense) // len(cfg.block_pattern)
    run1 = dataclasses.replace(run, num_microbatches=1)
    cs = {}
    for g in (2, 3):
        lw, _ = _build(cfg_override=_variant_cfg(cfg, g), run_override=run1,
                       unroll=True)
        c = lw.compile()
        cost = cost_dict(c)
        cs[g] = {"flops": float(cost.get("flops", 0)),
                 "bytes": float(cost.get("bytes accessed", 0)),
                 "transcendentals": float(cost.get("transcendentals", 0)),
                 "collectives": parse_collectives(c.as_text())}
    ex = _extrapolate(cs[2], cs[3], G)
    wire = sum(v["wire_bytes"] for v in ex["collectives"].values())
    rec = {
        "arch": arch, "shape": shape, "overrides": overrides, "mb": mb,
        "constrain_scan_weights": csw,
        "analytic": meta.get("analytic"),
        "temp_GB": round(temp / 1e9, 2), "args_GB": round(arg / 1e9, 2),
        "t_compute_s": round(ex["flops"] / PEAK_FLOPS, 4),
        "t_memory_s": round(ex["bytes"] / HBM_BW, 4),
        "t_collective_s": round(wire / ICI_BW, 4),
        "collectives_GB": {k: round(v["wire_bytes"] / 1e9, 2)
                           for k, v in ex["collectives"].items()},
        "flops_dev": ex["flops"],
        "seconds": round(time.perf_counter() - t0, 1),
    }
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--override", action="append", default=[],
                    help="logical=mesh1+mesh2 (empty rhs = replicate)")
    ap.add_argument("--microbatches", type=int)
    ap.add_argument("--constrain-scan-weights", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    overrides = {}
    for o in args.override:
        k, _, v = o.partition("=")
        overrides[k] = v

    rec = measure(args.arch, args.shape, overrides, args.microbatches,
                  args.constrain_scan_weights, args.multi_pod)
    rec["tag"] = args.tag
    print(json.dumps(rec, indent=2))
    with open(ART / "perf_log.jsonl", "a") as f:
        f.write(json.dumps(rec) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
