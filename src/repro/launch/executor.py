"""One executor for every job kind — local execution of a ``JobSpec``.

The three launch CLIs (``train``, ``serve``, ``dryrun``) are thin
parse-to-spec layers over this module: each builds a validated
``JobSpec`` and hands it to :func:`execute`, which dispatches on
``spec.kind``.  The exact same spec can instead be submitted to the
platform (``DLaaSPlatform.submit``) where the Guardian runs it under the
full dependability machinery — one resource model, two run paths.

Serving internals (the :class:`PagePool` allocator, lockstep and
continuous-batching loops) live here; ``repro.launch.serve`` re-exports
``PagePool`` for compatibility.
"""
from __future__ import annotations

import dataclasses
import subprocess
import sys
import time
from typing import List, Optional

import numpy as np

from repro.core.jobspec import (
    FrameworkRegistry, JobSpec, ServeSpec, resolve_cells)


def execute(spec: JobSpec) -> int:
    """Validate and run a JobSpec locally; returns a process exit code."""
    err = spec.validate(FrameworkRegistry.default())
    if err:
        raise SystemExit(f"invalid JobSpec: {err}")
    if spec.kind == "train":
        return _run_train(spec)
    if spec.kind == "serve":
        return _run_serve(spec)
    return _run_dryrun(spec)


def _make_mesh(name: str):
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    return {"host": make_host_mesh,
            "prod": make_production_mesh,
            "multipod": lambda: make_production_mesh(multi_pod=True)}[name]()


# ---------------------------------------------------------------------------
# kind = train
# ---------------------------------------------------------------------------
def _run_train(spec: JobSpec) -> int:
    import jax
    import jax.numpy as jnp

    from repro.configs import RunConfig, get_config
    from repro.data.pipeline import SyntheticLMData
    from repro.models.layers import Ctx
    from repro.train.steps import init_train_state, make_train_step

    t = spec.train
    cfg = get_config(spec.framework)
    if t.reduced:
        cfg = cfg.reduced()
    mesh = _make_mesh(t.mesh)
    ctx = Ctx(mesh=mesh, dtype=jnp.float32 if t.reduced else jnp.bfloat16,
              use_pallas=t.use_pallas)
    run = RunConfig(num_microbatches=t.num_microbatches,
                    remat_policy=t.remat_policy,
                    learning_rate=t.learning_rate,
                    warmup_steps=max(t.total_steps // 20, 1),
                    total_steps=t.total_steps)
    state = init_train_state(cfg, jax.random.key(spec.seed), run)
    data = SyntheticLMData(cfg.vocab_size, t.seq_len, t.global_batch,
                           spec.seed)
    step = jax.jit(make_train_step(cfg, ctx, run), donate_argnums=(0,))

    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"mesh={mesh.devices.shape} devices={mesh.devices.size}")
    t0 = time.time()
    for i in range(t.total_steps):
        state, m = step(state, data.batch_at(i))
        if i % t.log_every == 0 or i == t.total_steps - 1:
            print(f"  step {i:5d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}  "
                  f"lr {float(m['lr']):.2e}")
    dt = time.time() - t0
    tok = t.total_steps * t.global_batch * t.seq_len
    print(f"[train] {t.total_steps} steps in {dt:.1f}s "
          f"({tok/dt:.0f} tok/s incl. compile)")
    return 0


# ---------------------------------------------------------------------------
# kind = serve
# ---------------------------------------------------------------------------
class PagePool:
    """Host-side physical-page allocator for the paged KV cache.

    Manages page ids ``0 .. n_pages-1``.  Conservative admission: the
    serving loop reserves a request's full worst-case page count up front,
    so decode can never run out mid-flight (no preemption needed).

    ``n_shards > 1`` partitions the id space into contiguous per-shard free
    lists.  The pool's pages dim shards contiguously over the data axis
    (``cache_pages`` rule), so allocating a sequence's pages from its own
    data shard's range keeps every decode gather/scatter data-shard-local —
    the runtime half of the locality contract whose spec half is
    ``dist.sharding.check_cache_locality``.
    """

    def __init__(self, n_pages: int, n_shards: int = 1):
        assert n_shards >= 1 and n_pages % n_shards == 0, (n_pages, n_shards)
        self.n_pages = n_pages
        self.n_shards = n_shards
        per = n_pages // n_shards
        self.free_lists: List[List[int]] = [
            list(range(s * per, (s + 1) * per)) for s in range(n_shards)]
        self.high_water = 0

    @property
    def in_use(self) -> int:
        return self.n_pages - sum(len(f) for f in self.free_lists)

    def alloc(self, n: int, shard: int = 0) -> Optional[List[int]]:
        fl = self.free_lists[shard]
        if n > len(fl):
            return None
        pages, self.free_lists[shard] = fl[:n], fl[n:]
        self.high_water = max(self.high_water, self.in_use)
        return pages

    def free(self, pages: List[int]) -> None:
        per = self.n_pages // self.n_shards
        for p in pages:
            self.free_lists[min(p // per, self.n_shards - 1)].append(p)


def _set_page_tables(cache, host_table: np.ndarray):
    """Broadcast the (B, pps) host page table into every per-layer
    ``page_table`` leaf (layers index their own pools identically)."""
    import jax
    import jax.numpy as jnp

    table = jnp.asarray(host_table, jnp.int32)

    leaves, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for path, leaf in leaves:
        if getattr(path[-1], "key", None) == "page_table":
            out.append(jnp.broadcast_to(table, leaf.shape).astype(jnp.int32))
        else:
            out.append(leaf)
    return jax.tree.unflatten(treedef, out)


def run_lockstep(cfg, ctx, params, sv: ServeSpec) -> int:
    """Batched prefill + lockstep greedy decode (dense or paged layout)."""
    import jax
    import jax.numpy as jnp

    from repro.models.model import init_cache
    from repro.train.steps import make_serve_steps

    B, P, G = sv.batch, sv.prompt_len, sv.gen
    max_len = P + G
    prompts = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    src_len = 0
    if cfg.is_encoder_decoder:
        src_len = max(P // 4, 16)
        batch["src_embeds"] = 0.02 * jax.random.normal(
            jax.random.key(2), (B, src_len, cfg.d_model))

    prefill, decode = make_serve_steps(cfg, ctx)
    cache = init_cache(cfg, B, max_len, src_len=src_len)

    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for t in range(P, P + G - 1):
        logits, cache = decode(params, {"tokens": tok}, cache, jnp.int32(t))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"[serve] arch={cfg.name} layout={cfg.cache_layout} "
          f"batch={B} prompt={P} gen={G}")
    print(f"  prefill: {t_prefill*1e3:.1f} ms "
          f"({B*P/t_prefill:.0f} tok/s incl. compile)")
    print(f"  decode:  {t_decode*1e3:.1f} ms "
          f"({B*(G-1)/max(t_decode,1e-9):.0f} tok/s incl. compile)")
    print(f"  sample continuations: {gen[:2, :10].tolist()}")
    return 0


def run_continuous(cfg, ctx, params, sv: ServeSpec, seed: int = 0) -> int:
    """Continuous batching over the paged cache: a queue of requests with
    varying generation lengths is admitted per-request whenever the page
    allocator can reserve the request's worst-case pages; finished requests
    free their pages immediately, letting the next one in.

    Attention-only architectures take the *ragged* prefill path: every
    request admitted in a round is prefilled in ONE batched call padded to
    the round's max prompt length (bucketed to a page multiple to bound
    recompiles), with per-row ``lengths`` masking the cache writes — no
    per-request slot-view prefill, and prompts are no longer padded to the
    queue-wide maximum.  Recurrent / RWKV stacks keep the per-request
    slot-view prefill (their carries would scan the padding)."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import GLOBAL_ATTN, LOCAL_ATTN
    from repro.models.model import (
        cache_slot_merge, cache_slot_view, init_cache, num_pages)
    from repro.train.steps import make_serve_steps

    if cfg.cache_layout != "paged":
        raise SystemExit("--continuous requires --layout paged")
    if cfg.use_mla or cfg.is_encoder_decoder:
        raise SystemExit("--continuous needs per-sequence decode positions; "
                         "MLA / enc-dec caches are lockstep-only")
    attn_only = set(cfg.layer_kinds()) <= {GLOBAL_ATTN, LOCAL_ATTN}
    ragged = attn_only if sv.ragged_prefill is None else sv.ragged_prefill
    if ragged and not attn_only:
        raise SystemExit("--ragged-prefill needs an attention-only decoder; "
                         "recurrent/RWKV state would scan the padding")

    B, P, G = sv.batch, sv.prompt_len, sv.gen
    max_len = P + G
    ps = cfg.page_size
    pps = num_pages(max_len, ps)
    budget = sv.page_budget or B * pps
    if budget < pps:
        raise SystemExit(f"--page-budget {budget} cannot hold one request "
                         f"({pps} pages)")

    rng = np.random.default_rng(seed)
    n_req = sv.requests
    prompts = np.asarray(jax.random.randint(
        jax.random.key(1), (n_req, P), 0, cfg.vocab_size))
    gen_lens = rng.integers(max(G // 2, 1), G + 1, size=n_req)
    # ragged workload: per-request prompt lengths in [P/2, P]; the lockstep
    # fallback serves every prompt at full length P
    prompt_lens = rng.integers(max(P // 2, 1), P + 1, size=n_req) if ragged \
        else np.full(n_req, P, np.int64)

    prefill, decode = make_serve_steps(cfg, ctx)
    cache = init_cache(cfg, B, max_len, layout="paged", page_budget=budget,
                       paged_tables="empty")
    # page→data-shard locality: slot b's batch row lives on one data shard,
    # so allocate its pages from that shard's contiguous range.  Falls back
    # to one shard when the budget doesn't split evenly or a shard couldn't
    # hold even a single request (which would deadlock admission).
    n_shards = dict(zip(ctx.mesh.axis_names, ctx.mesh.axis_sizes)).get(
        "data", 1) if ctx.mesh is not None else 1
    if budget % n_shards or B % n_shards or budget // n_shards < pps:
        n_shards = 1
    pool = PagePool(budget, n_shards)
    host_table = np.full((B, pps), -1, np.int32)

    slots: List[Optional[dict]] = [None] * B
    toks = np.zeros((B, 1), np.int64)
    pos = np.full((B,), -1, np.int64)
    next_req = 0
    done: List[int] = []
    stalled_admissions = 0
    t0 = time.time()
    decode_steps = 0
    generated = 0

    def finish(b: int) -> None:
        nonlocal cache
        s = slots[b]
        pool.free(s["pages"])
        host_table[b, :] = -1
        cache = _set_page_tables(cache, host_table)
        done.append(s["req"])
        slots[b] = None
        pos[b] = -1
        toks[b, 0] = 0

    while len(done) < n_req:
        # ---- admission: one request per free slot, if pages are available
        admitted: List[tuple] = []           # (slot, request) this round
        for b in range(B):
            if slots[b] is not None or next_req >= n_req:
                continue
            r = next_req
            need = num_pages(int(prompt_lens[r]) + int(gen_lens[r]), ps)
            pages = pool.alloc(need, shard=b * n_shards // B)
            if pages is None:
                stalled_admissions += 1
                break                        # FIFO: don't admit out of order
            next_req += 1
            host_table[b, :need] = pages
            host_table[b, need:] = -1
            admitted.append((b, r, pages))
        if admitted:
            cache = _set_page_tables(cache, host_table)
        if admitted and ragged:
            # one batched ragged prefill for the whole round: pad to the
            # round max, bucketed to a page multiple (bounds recompiles)
            round_max = max(int(prompt_lens[r]) for _, r, _ in admitted)
            S0 = -(-round_max // ps) * ps
            toks_in = np.zeros((B, S0), prompts.dtype)
            lens = np.zeros((B,), np.int32)
            for b, r, _ in admitted:
                L = int(prompt_lens[r])
                toks_in[b, :L] = prompts[r, :L]
                lens[b] = L
            logits, cache = prefill(params, {"tokens": jnp.asarray(toks_in)},
                                    cache, jnp.asarray(lens))
            nxt_tok = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for b, r, pages in admitted:
            if not ragged:
                view = cache_slot_view(cache, B, b)
                logits, view = prefill(
                    params, {"tokens": jnp.asarray(prompts[r][None])}, view)
                cache = cache_slot_merge(cache, view, B, b)
                toks[b, 0] = int(jnp.argmax(logits[0, -1]))
            else:
                toks[b, 0] = int(nxt_tok[b])
            pos[b] = int(prompt_lens[r])
            slots[b] = {"req": r, "remaining": int(gen_lens[r]) - 1,
                        "pages": pages}
            generated += 1
            if slots[b]["remaining"] <= 0:
                finish(b)                    # gen_len == 1: prefill was it

        if all(s is None for s in slots):
            if next_req >= n_req:
                break                        # queue drained
            continue                         # everything finished at prefill

        # ---- one decode step over every active slot (inactive rows: -1)
        logits, cache = decode(params, {"tokens": jnp.asarray(toks)}, cache,
                               jnp.asarray(pos, jnp.int32))
        decode_steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for b in range(B):
            s = slots[b]
            if s is None:
                continue
            toks[b, 0] = int(nxt[b])
            pos[b] += 1
            generated += 1
            s["remaining"] -= 1
            if s["remaining"] <= 0:
                finish(b)

    jax.block_until_ready(cache)
    dt = time.time() - t0
    print(f"[serve/continuous] arch={cfg.name} requests={n_req} slots={B} "
          f"prompt<= {P} gen<= {G} page_size={ps} "
          f"prefill={'ragged' if ragged else 'per-slot'} "
          f"decode={'pallas' if ctx.use_pallas else 'jnp-scan'}")
    print(f"  pool: {budget} pages, high-water {pool.high_water}, "
          f"admission stalls {stalled_admissions}")
    print(f"  completed {len(done)}/{n_req} in {decode_steps} decode steps, "
          f"{dt*1e3:.1f} ms ({generated/max(dt,1e-9):.0f} tok/s incl. "
          f"compile)")
    assert len(done) == n_req, (len(done), n_req)
    return 0


def _run_serve(spec: JobSpec) -> int:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models.layers import Ctx
    from repro.models.params import init_params

    sv = spec.serve
    cfg = get_config(spec.framework)
    if sv.reduced:
        cfg = cfg.reduced()
    overrides = {}
    if sv.cache_layout:
        overrides["cache_layout"] = sv.cache_layout
    if sv.continuous and "cache_layout" not in overrides:
        overrides["cache_layout"] = "paged"
    if sv.page_size:
        overrides["page_size"] = sv.page_size
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    mesh = _make_mesh(sv.mesh)
    ctx = Ctx(mesh=mesh, dtype=jnp.float32 if sv.reduced else jnp.bfloat16,
              use_pallas=sv.use_pallas)
    params = init_params(cfg, jax.random.key(spec.seed))

    if sv.continuous:
        return run_continuous(cfg, ctx, params, sv, seed=spec.seed)
    return run_lockstep(cfg, ctx, params, sv)


# ---------------------------------------------------------------------------
# kind = dryrun
# ---------------------------------------------------------------------------
def _run_dryrun(spec: JobSpec) -> int:
    """Run the sweep cells, one subprocess each (isolation: every cell gets
    a fresh XLA with the 512 fake-host-device flag).  Cached cells are
    skipped unless the spec says ``force`` — the sweep is resumable."""
    from repro.launch import dryrun as dr_mod

    dr = spec.dryrun
    dr_mod.ARTIFACTS.mkdir(parents=True, exist_ok=True)
    failures = 0
    for cell in resolve_cells(dr):
        out = dr_mod.cell_path(cell.arch, cell.shape, cell.multi_pod)
        if out.exists() and not dr.force:
            print(f"[dryrun] cached: {out}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--cell-worker",
               "--arch", cell.arch, "--shape", cell.shape]
        if cell.multi_pod:
            cmd.append("--multi-pod")
        if dr.force:
            cmd.append("--force")
        print(f"[dryrun] {cell.arch} × {cell.shape} × {cell.mesh_name} ...",
              flush=True)
        r = subprocess.run(cmd, timeout=dr.timeout_s)
        if r.returncode:
            failures += 1
    return 1 if failures else 0
