"""One executor for every job kind — local execution of a ``JobSpec``.

The three launch CLIs (``train``, ``serve``, ``dryrun``) are thin
parse-to-spec layers over this module: each builds a validated
``JobSpec`` and hands it to :func:`execute`, which dispatches on
``spec.kind``.  The exact same spec can instead be submitted to the
platform (``DLaaSPlatform.submit``) where the Guardian runs it under the
full dependability machinery — one resource model, two run paths.

Continuous-batching serving lives in :class:`repro.launch.engine.
ServingEngine` (resumable admit/step/finish/snapshot/restore state
machine); :func:`run_continuous` here is the thin CLI driver over it.
``PagePool`` moved to ``repro.launch.engine`` and is re-exported here (and
from ``repro.launch.serve``) for compatibility.
"""
from __future__ import annotations

import dataclasses
import subprocess
import sys
import time

from repro.core.jobspec import (
    FrameworkRegistry, JobSpec, ServeSpec, resolve_cells)
from repro.launch.engine import (  # noqa: F401  (PagePool: compat re-export)
    PagePool, ServingEngine, synthesize_requests)


def execute(spec: JobSpec) -> int:
    """Validate and run a JobSpec locally; returns a process exit code."""
    err = spec.validate(FrameworkRegistry.default())
    if err:
        raise SystemExit(f"invalid JobSpec: {err}")
    if spec.kind == "train":
        return _run_train(spec)
    if spec.kind == "serve":
        return _run_serve(spec)
    return _run_dryrun(spec)


def _make_mesh(name: str):
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    return {"host": make_host_mesh,
            "prod": make_production_mesh,
            "multipod": lambda: make_production_mesh(multi_pod=True)}[name]()


# ---------------------------------------------------------------------------
# kind = train
# ---------------------------------------------------------------------------
def _run_train(spec: JobSpec) -> int:
    import jax
    import jax.numpy as jnp

    from repro.configs import RunConfig, get_config
    from repro.data.pipeline import SyntheticLMData
    from repro.models.layers import Ctx
    from repro.train.steps import init_train_state, make_train_step

    t = spec.train
    cfg = get_config(spec.framework)
    if t.reduced:
        cfg = cfg.reduced()
    mesh = _make_mesh(t.mesh)
    ctx = Ctx(mesh=mesh, dtype=jnp.float32 if t.reduced else jnp.bfloat16,
              use_pallas=t.use_pallas)
    run = RunConfig(num_microbatches=t.num_microbatches,
                    remat_policy=t.remat_policy,
                    learning_rate=t.learning_rate,
                    warmup_steps=max(t.total_steps // 20, 1),
                    total_steps=t.total_steps)
    state = init_train_state(cfg, jax.random.key(spec.seed), run)
    data = SyntheticLMData(cfg.vocab_size, t.seq_len, t.global_batch,
                           spec.seed)
    step = jax.jit(make_train_step(cfg, ctx, run), donate_argnums=(0,))

    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"mesh={mesh.devices.shape} devices={mesh.devices.size}")
    t0 = time.perf_counter()
    for i in range(t.total_steps):
        state, m = step(state, data.batch_at(i))
        if i % t.log_every == 0 or i == t.total_steps - 1:
            print(f"  step {i:5d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}  "
                  f"lr {float(m['lr']):.2e}")
    dt = time.perf_counter() - t0
    tok = t.total_steps * t.global_batch * t.seq_len
    print(f"[train] {t.total_steps} steps in {dt:.1f}s "
          f"({tok/dt:.0f} tok/s incl. compile)")
    return 0


# ---------------------------------------------------------------------------
# kind = serve
# ---------------------------------------------------------------------------
def run_lockstep(cfg, ctx, params, sv: ServeSpec) -> int:
    """Batched prefill + lockstep greedy decode (dense or paged layout)."""
    import jax
    import jax.numpy as jnp

    from repro.models.model import init_cache
    from repro.train.steps import make_serve_steps

    B, P, G = sv.batch, sv.prompt_len, sv.gen
    max_len = P + G
    prompts = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    src_len = 0
    if cfg.is_encoder_decoder:
        src_len = max(P // 4, 16)
        batch["src_embeds"] = 0.02 * jax.random.normal(
            jax.random.key(2), (B, src_len, cfg.d_model))

    prefill, decode = make_serve_steps(cfg, ctx)
    cache = init_cache(cfg, B, max_len, src_len=src_len)

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t_prefill = time.perf_counter() - t0

    out = [tok]
    t0 = time.perf_counter()
    for t in range(P, P + G - 1):
        logits, cache = decode(params, {"tokens": tok}, cache, jnp.int32(t))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"[serve] arch={cfg.name} layout={cfg.cache_layout} "
          f"batch={B} prompt={P} gen={G}")
    print(f"  prefill: {t_prefill*1e3:.1f} ms "
          f"({B*P/t_prefill:.0f} tok/s incl. compile)")
    print(f"  decode:  {t_decode*1e3:.1f} ms "
          f"({B*(G-1)/max(t_decode,1e-9):.0f} tok/s incl. compile)")
    print(f"  sample continuations: {gen[:2, :10].tolist()}")
    return 0


def run_continuous(cfg, ctx, params, sv: ServeSpec, seed: int = 0) -> int:
    """Continuous batching over the paged cache: the CLI driver over
    :class:`repro.launch.engine.ServingEngine`.  Synthesizes the request
    workload, drains the engine, prints the summary — all batching,
    admission (conservative or optimistic via ``sv.overcommit``),
    eviction/requeue and paging semantics live in the engine."""
    import jax

    try:
        engine = ServingEngine(cfg, ctx, params, sv)
    except ValueError as e:          # CLI contract: bad flags exit nonzero
        raise SystemExit(str(e)) from e
    n_req = sv.requests
    t0 = time.perf_counter()
    for request in synthesize_requests(cfg, sv, seed, engine.ragged):
        engine.submit(request)
    engine.run()

    jax.block_until_ready(engine.cache)
    dt = time.perf_counter() - t0
    print(f"[serve/continuous] arch={cfg.name} requests={n_req} "
          f"slots={engine.B} prompt<= {sv.prompt_len} gen<= {sv.gen} "
          f"page_size={engine.ps} "
          f"prefill={'ragged' if engine.ragged else 'per-slot'} "
          f"decode={'pallas' if ctx.use_pallas else 'jnp-scan'}")
    print(f"  pool: {engine.pool.n_pages} pages, high-water "
          f"{engine.pool.high_water}, admission stalls "
          f"{engine.stalled_admissions}, evictions {engine.evictions} "
          f"(overcommit {engine.overcommit:g})")
    if engine.prefix_cache:
        total = engine.prefill_tokens + engine.cached_tokens
        print(f"  prefix cache: {engine.prefix_hits} hits / "
              f"{engine.prefix_misses} misses, {engine.cached_tokens}/"
              f"{total} prompt tokens served from cache, "
              f"{engine.cow_copies} CoW copies")
    print(f"  completed {len(engine.responses)}/{n_req} in "
          f"{engine.decode_steps} decode steps, "
          f"{dt*1e3:.1f} ms ({engine.generated/max(dt,1e-9):.0f} tok/s "
          f"incl. compile)")
    assert len(engine.responses) == n_req, (len(engine.responses), n_req)
    return 0


def _run_serve(spec: JobSpec) -> int:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models.layers import Ctx
    from repro.models.params import init_params

    sv = spec.serve
    cfg = get_config(spec.framework)
    if sv.reduced:
        cfg = cfg.reduced()
    overrides = {}
    if sv.cache_layout:
        overrides["cache_layout"] = sv.cache_layout
    if sv.continuous and "cache_layout" not in overrides:
        overrides["cache_layout"] = "paged"
    if sv.page_size:
        overrides["page_size"] = sv.page_size
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    mesh = _make_mesh(sv.mesh)
    ctx = Ctx(mesh=mesh, dtype=jnp.float32 if sv.reduced else jnp.bfloat16,
              use_pallas=sv.use_pallas)
    params = init_params(cfg, jax.random.key(spec.seed))

    if sv.continuous:
        return run_continuous(cfg, ctx, params, sv, seed=spec.seed)
    return run_lockstep(cfg, ctx, params, sv)


# ---------------------------------------------------------------------------
# kind = dryrun
# ---------------------------------------------------------------------------
def _run_dryrun(spec: JobSpec) -> int:
    """Run the sweep cells, one subprocess each (isolation: every cell gets
    a fresh XLA with the 512 fake-host-device flag).  Cached cells are
    skipped unless the spec says ``force`` — the sweep is resumable."""
    from repro.launch import dryrun as dr_mod

    dr = spec.dryrun
    dr_mod.ARTIFACTS.mkdir(parents=True, exist_ok=True)
    failures = 0
    for cell in resolve_cells(dr):
        out = dr_mod.cell_path(cell.arch, cell.shape, cell.multi_pod)
        if out.exists() and not dr.force:
            print(f"[dryrun] cached: {out}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--cell-worker",
               "--arch", cell.arch, "--shape", cell.shape]
        if cell.multi_pod:
            cmd.append("--multi-pod")
        if dr.force:
            cmd.append("--force")
        print(f"[dryrun] {cell.arch} × {cell.shape} × {cell.mesh_name} ...",
              flush=True)
        r = subprocess.run(cmd, timeout=dr.timeout_s)
        if r.returncode:
            failures += 1
    return 1 if failures else 0
