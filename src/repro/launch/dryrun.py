import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: XLA SPMD must partition every step function over the production
meshes, the per-device memory must fit the 16 GB HBM of a TPU v5e, and the
compiled HLO yields the FLOP/byte/collective terms for §Roofline.

Each cell writes ``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` and is
skipped when that file exists (the sweep is resumable; use --force to
recompute).  The CLI is a parse-to-spec layer: flags become a
``JobSpec(kind="dryrun")`` that the shared executor runs, one subprocess
per cell for isolation (the hidden ``--cell-worker`` entry).
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def cost_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` compat: jax < 0.5 returns a list with
    one dict per computation, newer jax returns the dict directly."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def parse_collectives(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in the optimized HLO.

    Returns {op_kind: {"count": n, "bytes": total_output_bytes,
                       "wire_bytes": est. bytes moved per device}}.
    """
    shape_re = re.compile(r"(\w+)\[([0-9,]*)\]")
    group_re = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
    group_expl_re = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
    out: dict = {k: {"count": 0, "bytes": 0, "wire_bytes": 0.0}
                 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(.*?)\s+(all-reduce|all-gather|reduce-scatter|"
                      r"all-to-all|collective-permute)(-start)?\(", line)
        if not m:
            continue
        shapes_part, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in shape_re.findall(shapes_part):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        g = group_re.search(line)
        if g:
            gsize = int(g.group(2))
        else:
            g2 = group_expl_re.search(line)
            gsize = len(g2.group(1).split(",")) if g2 else 2
        # ring-algorithm wire bytes per participating device
        if op == "all-reduce":
            wire = 2 * nbytes * (gsize - 1) / max(gsize, 1)
        elif op == "all-gather":
            wire = nbytes * (gsize - 1) / max(gsize, 1)
        elif op == "reduce-scatter":
            wire = nbytes * (gsize - 1)          # nbytes is the shard output
        elif op == "all-to-all":
            wire = nbytes * (gsize - 1) / max(gsize, 1)
        else:                                     # collective-permute
            wire = nbytes
        out[op]["count"] += 1
        out[op]["bytes"] += nbytes
        out[op]["wire_bytes"] += wire
    return {k: v for k, v in out.items() if v["count"]}


def build_lowered(arch: str, shape_name: str, multi_pod: bool,
                  rules=None, cfg_override=None, run_override=None,
                  scan_unroll: bool = False,
                  constrain_scan_weights: bool = False):
    """Lower the right step function for one cell.  Heavy imports are local
    so `--all` subprocess dispatch stays cheap."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import SHAPES, get_config, get_run_config, shape_applicable
    from repro.dist.sharding import DEFAULT_RULES
    from repro.launch.mesh import make_production_mesh
    from repro.launch import specs as S
    from repro.models.layers import Ctx
    from repro.train.steps import (
        make_decode_step, make_prefill_step, make_train_step)

    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, {"skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    run = run_override if run_override is not None \
        else get_run_config(arch, shape_name)
    if rules is None:
        rules = DEFAULT_RULES
        if run.sharding_overrides:
            rules = rules.override(
                **{k: v for k, v in run.sharding_overrides})
    ctx = Ctx(mesh=mesh, rules=rules, dtype=jnp.bfloat16,
              scan_unroll=scan_unroll,
              constrain_scan_weights=constrain_scan_weights)
    kind = shape.kind

    bs = S.batch_specs(cfg, shape, kind)
    bsh = S.batch_shardings(bs, mesh, rules)
    rep = NamedSharding(mesh, P())

    if kind == "train":
        step = make_train_step(cfg, ctx, run)
        ssp = S.state_specs(cfg, run)
        ssh = S.state_shardings(cfg, mesh, rules, run)
        fn = jax.jit(step, in_shardings=(ssh, bsh),
                     out_shardings=(ssh, None), donate_argnums=(0,))
        lowered = fn.lower(ssp, bs)
    elif kind == "prefill":
        step = make_prefill_step(cfg, ctx)
        psp = S.param_specs(cfg, serve=True)
        psh = S.param_shardings(cfg, mesh, rules)
        csp = S.cache_specs(cfg, shape, run)
        csh = S.cache_shardings(cfg, shape, mesh, rules, run)
        fn = jax.jit(step, in_shardings=(psh, bsh, csh),
                     out_shardings=(None, csh), donate_argnums=(2,))
        lowered = fn.lower(psp, bs, csp)
    else:  # decode
        step = make_decode_step(cfg, ctx)
        psp = S.param_specs(cfg, serve=True)
        psh = S.param_shardings(cfg, mesh, rules)
        csp = S.cache_specs(cfg, shape, run)
        csh = S.cache_shardings(cfg, shape, mesh, rules, run)
        fn = jax.jit(step, in_shardings=(psh, bsh, csh, rep),
                     out_shardings=(None, csh), donate_argnums=(2,))
        lowered = fn.lower(psp, bs, csp,
                           jax.ShapeDtypeStruct((), jnp.int32))

    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "kind": kind, "n_devices": mesh.devices.size,
            "seq_len": shape.seq_len, "global_batch": shape.global_batch,
            "num_microbatches": run.num_microbatches,
            "remat_policy": run.remat_policy,
            # pre-compile placement estimate from the sharding trees —
            # cross-check against compiled argument_size_in_bytes
            "analytic": S.placement_report(cfg, shape, run, mesh, rules)}
    return lowered, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    from repro.configs import get_config
    from repro.models.model import count_params

    t0 = time.perf_counter()
    lowered, meta = build_lowered(arch, shape_name, multi_pod)
    if lowered is None:
        return {"ok": True, **meta}
    t_lower = time.perf_counter() - t0

    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_rec = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if hasattr(mem, attr):
            mem_rec[attr] = int(getattr(mem, attr))

    cost = cost_dict(compiled)
    cost_rec = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed", "transcendentals",
                 "utilization operand 0 {}", "bytes accessed output {}")}
    colls = parse_collectives(compiled.as_text())

    cfg = get_config(arch)
    meta.update(
        ok=True,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=mem_rec,
        cost=cost_rec,
        collectives=colls,
        n_params=count_params(cfg),
        n_params_active=count_params(cfg, active_only=True),
    )
    return meta


def cell_path(arch: str, shape_name: str, multi_pod: bool) -> Path:
    mesh = "2x16x16" if multi_pod else "16x16"
    return ARTIFACTS / f"{arch}__{shape_name}__{mesh}.json"


def all_cells():
    from repro.configs import SHAPES, get_config, list_configs, shape_applicable
    for arch in list_configs():
        if arch == "paper-overhead-100m":
            continue
        for shape_name in SHAPES:
            yield arch, shape_name


def _run_cell_worker(args) -> int:
    """In-process single-cell execution (the subprocess entry the shared
    executor dispatches to; isolation keeps each cell's XLA fresh)."""
    assert args.arch and args.shape, "--arch and --shape required"
    out = cell_path(args.arch, args.shape, args.multi_pod)
    if out.exists() and not args.force:
        print(f"[dryrun] cached: {out}")
        return 0
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod)
    except Exception as e:
        rec = {"ok": False, "arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multi_pod else "16x16",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        out.write_text(json.dumps(rec, indent=2))
        print(json.dumps({k: rec[k] for k in ("ok", "arch", "shape", "error")},
                         indent=2))
        return 1
    out.write_text(json.dumps(rec, indent=2))
    brief = {k: rec.get(k) for k in
             ("ok", "arch", "shape", "mesh", "compile_s", "memory", "skipped")}
    print(json.dumps(brief, indent=2))
    return 0


def parse_spec(argv=None):
    """Parse CLI flags into a ``JobSpec(kind="dryrun")`` (plus the raw
    args, for the hidden --cell-worker plumbing)."""
    from repro.core.jobspec import DryRunSpec, JobSpec, Resources, SweepCell

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every cell (both meshes) in subprocesses")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--cell-worker", action="store_true",
                    help=argparse.SUPPRESS)     # executor's subprocess entry
    args = ap.parse_args(argv)

    if args.all:
        cells, sweep_all = (), True
    else:
        assert args.arch and args.shape, "--arch and --shape required"
        cells = (SweepCell(args.arch, args.shape, args.multi_pod),)
        sweep_all = False
    spec = JobSpec(
        name="dryrun-all" if args.all else f"dryrun-{args.arch}",
        kind="dryrun",
        framework=args.arch or "paper-overhead-100m",
        resources=Resources(replicas=1, gpus_per_replica=0),
        dryrun=DryRunSpec(cells=cells, sweep_all=sweep_all,
                          force=args.force, timeout_s=args.timeout))
    return spec, args


def main(argv=None) -> int:
    spec, args = parse_spec(argv)
    if args.cell_worker:
        ARTIFACTS.mkdir(parents=True, exist_ok=True)
        return _run_cell_worker(args)
    from repro.launch.executor import execute
    return execute(spec)


if __name__ == "__main__":
    sys.exit(main())
