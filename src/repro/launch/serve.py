"""Serving CLI: a thin parse-to-spec layer over the shared executor.

Flags build a ``JobSpec(kind="serve")``; ``repro.launch.executor`` runs it
(lockstep or continuous batching; see ``executor.run_lockstep`` /
``run_continuous``).  The same spec can instead be submitted to the
platform for gang-scheduled, quota'd, metered serving.

Two cache layouts (``--layout``):

* ``dense`` — the fallback: one (B, K, S_max, hd) buffer per layer,
  lockstep batch (every sequence at the same position).
* ``paged`` — vLLM-style: global-attention layers share a physical page
  pool with per-sequence page tables (``models/model.py``).  Lockstep mode
  uses identity page tables over a worst-case pool; ``--continuous`` runs
  real continuous batching — per-request admission when the page allocator
  has room, per-sequence decode positions, pages freed on completion.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \\
        --batch 4 --prompt-len 64 --gen 32
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \\
        --layout paged --continuous --requests 8
"""
from __future__ import annotations

import argparse

from repro.core.jobspec import JobSpec, ServeSpec
from repro.launch.executor import (  # noqa: F401  (PagePool: compat re-export)
    PagePool, execute, run_continuous, run_lockstep)


def parse_spec(argv=None) -> JobSpec:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="host", choices=["host", "prod", "multipod"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--layout", default=None, choices=["dense", "paged"],
                    help="KV-cache layout (default: the config's)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="tokens per KV page (0 = config default)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over the paged cache")
    ap.add_argument("--requests", type=int, default=8,
                    help="request-queue length for --continuous")
    ap.add_argument("--page-budget", type=int, default=0,
                    help="physical pages in the pool (0 = worst case); "
                         "smaller budgets throttle admission")
    ap.add_argument("--overcommit", type=float, default=1.0,
                    help="optimistic admission factor: reserve worst-case "
                         "pages up to overcommit × budget; page exhaustion "
                         "evicts the youngest sequence back to the queue "
                         "(1.0 = conservative, never evicts)")
    ap.add_argument("--use-pallas", action="store_true",
                    help="paged flash-decode Pallas kernel for decode "
                         "(interpret mode off-TPU)")
    ap.add_argument("--ragged-prefill", dest="ragged_prefill",
                    action="store_const", const=True, default=None,
                    help="force batched ragged prefill (default: auto for "
                         "attention-only archs)")
    ap.add_argument("--no-ragged-prefill", dest="ragged_prefill",
                    action="store_const", const=False,
                    help="force per-slot lockstep prefill")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false", default=True,
                    help="disable hash-addressed prefix caching / "
                         "copy-on-write page sharing")
    ap.add_argument("--shared-prefix", type=float, default=0.0,
                    help="synthetic workload: fraction of prompt-len every "
                         "request shares as a common leading prefix")
    args = ap.parse_args(argv)

    return JobSpec(
        name=f"serve-{args.arch}",
        kind="serve",
        framework=args.arch,
        seed=args.seed,
        serve=ServeSpec(
            batch=args.batch,
            prompt_len=args.prompt_len,
            gen=args.gen,
            mesh=args.mesh,
            reduced=args.reduced,
            cache_layout=args.layout,
            page_size=args.page_size,
            continuous=args.continuous,
            requests=args.requests,
            page_budget=args.page_budget,
            overcommit=args.overcommit,
            use_pallas=args.use_pallas,
            ragged_prefill=args.ragged_prefill,
            prefix_cache=args.prefix_cache,
            shared_prefix_frac=args.shared_prefix,
        ))


def main(argv=None) -> int:
    return execute(parse_spec(argv))


if __name__ == "__main__":
    raise SystemExit(main())
