"""Serving driver: batched prefill + greedy decode for any registry arch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.layers import Ctx
from repro.models.model import init_cache
from repro.models.params import init_params
from repro.train.steps import make_decode_step, make_prefill_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="host", choices=["host", "prod", "multipod"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = {"host": make_host_mesh,
            "prod": make_production_mesh,
            "multipod": lambda: make_production_mesh(multi_pod=True)}[args.mesh]()
    ctx = Ctx(mesh=mesh, dtype=jnp.float32 if args.reduced else jnp.bfloat16)
    params = init_params(cfg, jax.random.key(args.seed))

    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G
    prompts = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    src_len = 0
    if cfg.is_encoder_decoder:
        src_len = max(P // 4, 16)
        batch["src_embeds"] = 0.02 * jax.random.normal(
            jax.random.key(2), (B, src_len, cfg.d_model))

    prefill = jax.jit(make_prefill_step(cfg, ctx))
    decode = jax.jit(make_decode_step(cfg, ctx), donate_argnums=(2,))

    cache = init_cache(cfg, B, max_len, src_len=src_len)
    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for t in range(P, P + G - 1):
        logits, cache = decode(params, {"tokens": tok}, cache, jnp.int32(t))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"[serve] arch={cfg.name} batch={B} prompt={P} gen={G}")
    print(f"  prefill: {t_prefill*1e3:.1f} ms "
          f"({B*P/t_prefill:.0f} tok/s incl. compile)")
    print(f"  decode:  {t_decode*1e3:.1f} ms "
          f"({B*(G-1)/max(t_decode,1e-9):.0f} tok/s incl. compile)")
    print(f"  sample continuations: {gen[:2, :10].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
