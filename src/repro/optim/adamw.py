"""AdamW + warmup-cosine schedule, pure JAX.

Optimizer state is a pytree parallel to the params (m, v per leaf) so it
inherits the params' shardings leaf-for-leaf — that IS the ZeRO partitioning:
params are FSDP/TP-sharded, so m and v are too.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.learning_rate * step / max(cfg.warmup_steps, 1)
    decay_steps = max(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.learning_rate * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_init(params, opt_dtype=jnp.float32) -> Dict[str, Any]:
    zeros = lambda t: jax.tree.map(lambda p: jnp.zeros_like(p, opt_dtype), t)
    return {"m": zeros(params), "v": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(
    cfg: AdamWConfig,
    grads,
    params,
    state: Dict[str, Any],
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
    lr = cosine_schedule(cfg, count)

    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, p, m, v):
        g = g.astype(jnp.float32) * scale
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mh = mf / b1c
        vh = vf / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, p, m, v) for g, p, m, v in zip(flat_g, flat_p, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
