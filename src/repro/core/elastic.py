"""Elastic data parallelism: shrink/grow the learner group.

When a node dies and no spare capacity exists, a synchronous DP job is
stuck (the paper's stateful-set restart assumes a schedulable replacement).
``ElasticPolicy`` decides a new world size; the re-mesh math
(``remesh_plan``) maps the old data-parallel shards onto the survivors so
per-learner batch shares stay balanced.  Growth on healed capacity is the
mirror operation.  The platform applies a plan by rewriting the learner
StatefulSet size and letting learners re-read their shard assignment from
the volume (tested in tests/test_platform_dependability.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional


@dataclass(frozen=True)
class RemeshPlan:
    old_world: int
    new_world: int
    # shard_of[new_learner] = list of old data shards it takes over
    shard_map: Dict[int, List[int]]
    global_batch: int
    per_learner_batch: Dict[int, int]


class ElasticPolicy:
    def __init__(self, min_world: int = 1, allow_grow: bool = True):
        self.min_world = min_world
        self.allow_grow = allow_grow

    def decide(self, desired_world: int, schedulable_world: int) -> Optional[int]:
        """Return the new world size, or None if the job must wait."""
        w = min(desired_world, schedulable_world)
        if w < self.min_world:
            return None
        if w == desired_world:
            return desired_world
        return w

    def remesh_plan(self, old_world: int, new_world: int,
                    global_batch: int) -> RemeshPlan:
        assert new_world >= 1
        shard_map: Dict[int, List[int]] = {i: [] for i in range(new_world)}
        for old in range(old_world):
            shard_map[old % new_world].append(old)
        base, rem = divmod(global_batch, new_world)
        per = {i: base + (1 if i < rem else 0) for i in range(new_world)}
        return RemeshPlan(old_world, new_world, shard_map, global_batch, per)
