"""DLaaSPlatform: the assembled system (paper Fig. 1).

Layers:
* platform layer — cluster (K8S analog), 3-replica Raft statestore (ETCD),
  metadata store (Mongo), object store (COS), volume manager (NFS);
* core services — API (2-replica Deployment), LCM (Deployment);
* per-job — Guardian (K8S Job), helper pod, learner StatefulSet.

Fault injection mirrors the paper's evaluation: ``kubectl_delete_pod`` for
Fig-4 component kills, ``crash_node`` for machine failures, plus statestore
replica crashes and metadata-store outages.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.core.api import ApiClient, SubmitHandle, make_api_proc
from repro.core.cluster import Cluster, ContainerSpec, Deployment, PodSpec
from repro.core.failures import FaultInjector, FaultPlan
from repro.core.jobspec import FrameworkRegistry, JobSpec
from repro.core.lcm import make_lcm_proc
from repro.core.manifest import JobManifest
from repro.core.metadata import MetadataStore, Unavailable
from repro.core.objectstore import ObjectStore
from repro.core.scheduler import Scheduler
from repro.core.sim import Sim
from repro.core.statestore import StateStore
from repro.core.tenancy import NetworkPolicy, TenancyManager
from repro.core.volumes import VolumeManager

# Fig-4 startup ranges for core-service pods
API_STARTUP = (3.0, 5.0)
LCM_STARTUP = (4.0, 6.0)


class DLaaSPlatform:
    def __init__(self, seed: int = 0, n_nodes: int = 16,
                 gpus_per_node: int = 8, api_replicas: int = 2,
                 lcm_replicas: int = 1):
        self.sim = Sim(seed=seed)
        self.cluster = Cluster(self.sim, n_nodes=n_nodes,
                               gpus_per_node=gpus_per_node)
        self.tenancy = TenancyManager()
        self.scheduler = Scheduler(self.tenancy)
        self.cluster.scheduler = self.scheduler
        self.statestore = StateStore(self.sim, n_replicas=3)
        self.metadata = MetadataStore()
        self.objectstore = ObjectStore()
        self.volumes = VolumeManager()
        self.netpolicy = NetworkPolicy()
        # framework-adapter registry: one adapter per architecture by
        # default; register() more to plug in new frameworks (Job API v2)
        self.frameworks = FrameworkRegistry.default()
        # chaos injection as a first-class API: scripted, typed, replayable
        # fault plans (see core/failures.py and the chaos benchmark lane)
        self.faults = FaultInjector(self)

        # mutable registries
        self.api_queue: List[SubmitHandle] = []
        self.guardians: Dict[str, Any] = {}
        self.statefulsets: Dict[str, Any] = {}
        self.deployments: Dict[str, Any] = {}
        self.netpolicies: Dict[str, Dict] = {}
        self.gang_sizes: Dict[str, int] = {}
        self.payloads: Dict[str, Any] = {}      # job_id -> RealPayload

        # core services
        self.api_deployment = Deployment(
            self.cluster, "dlaas-api",
            lambda i: PodSpec(name=f"api-{i}",
                              containers=[ContainerSpec(
                                  "api", make_api_proc(self))],
                              startup_range=API_STARTUP,
                              labels={"role": "api"}),
            replicas=api_replicas, service="dlaas-api")
        self.lcm_deployment = Deployment(
            self.cluster, "dlaas-lcm",
            lambda i: PodSpec(name=f"lcm-{i}",
                              containers=[ContainerSpec(
                                  "lcm", make_lcm_proc(self))],
                              startup_range=LCM_STARTUP,
                              labels={"role": "lcm"}),
            replicas=lcm_replicas, service="dlaas-lcm")
        self.client = ApiClient(self)

    # ------------------------------------------------------------------
    def run(self, seconds: float) -> None:
        self.sim.run_for(seconds)

    def run_until_terminal(self, job_id: str, timeout: float = 3600.0,
                           tick: float = 5.0) -> str:
        deadline = self.sim.now + timeout
        while self.sim.now < deadline:
            self.run(tick)
            try:
                doc = self.metadata.get("jobs", job_id)
            except Unavailable:
                continue            # store outage window: poll again
            if doc and doc["state"] in ("COMPLETED", "FAILED", "HALTED"):
                return doc["state"]
        return "TIMEOUT"

    # -- convenience passthroughs ------------------------------------------
    def submit(self, spec: "JobSpec | JobManifest",
               request_id: Optional[str] = None) -> SubmitHandle:
        return self.client.submit(spec, request_id=request_id)

    def register_payload(self, job_id: str, payload) -> None:
        self.payloads[job_id] = payload

    # -- fault injection -------------------------------------------------------
    def inject(self, plan: FaultPlan) -> None:
        """Arm a scripted chaos plan (typed faults at absolute sim times)."""
        self.faults.arm(plan)

    def kill_pod(self, name: str) -> bool:
        return self.cluster.kubectl_delete_pod(name)

    def crash_node_of(self, pod_name: str) -> Optional[str]:
        for pod in self.cluster.pods.values():
            if pod.spec.name == pod_name and pod.status == "RUNNING":
                node = pod.node.name
                self.cluster.crash_node(node)
                return node
        return None

    # -- observability ------------------------------------------------------------
    def recovery_time(self, pod_name: str, after_t: float) -> Optional[float]:
        """Virtual seconds from ``after_t`` until a pod with this name is
        RUNNING again (Fig-4 measurement).

        Scans live pods plus the cluster's bounded tombstone history, so
        an incarnation that recovered and then terminated again before the
        measurement is read still counts its first recovery.  A non-None
        ``started_at`` means the pod reached RUNNING — the same criterion
        for live and tombstoned pods, so there is no blind window between
        a pod going terminal and its GC tombstone being written."""
        candidates = [
            (pod.spec.name, pod.started_at)
            for pod in self.cluster.pods.values()
        ] + [
            (rec.name, rec.started_at)
            for rec in self.cluster.pod_history
        ]
        return min(
            (started_at - after_t for name, started_at in candidates
             if name == pod_name and started_at is not None
             and started_at >= after_t),
            default=None)
