"""Job manifest v1 — DEPRECATED in favor of ``repro.core.jobspec.JobSpec``.

``framework`` names one of the registry architectures: the platform treats
architectures the way DLaaS treats frameworks (opaque learner payloads).

This flat, training-only manifest predates the multi-kind Job API v2.  It
is kept as a compatibility shim: the gateway accepts it and converts via
:meth:`JobManifest.to_jobspec` (equivalence is pinned by tests), and the
LCM still reconciles legacy job documents that carry ``manifest`` instead
of ``spec``.  New code should construct a ``JobSpec`` directly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional


@dataclass(frozen=True)
class JobManifest:
    name: str
    tenant: str = "default"
    framework: str = "paper-overhead-100m"    # architecture id
    learners: int = 1
    gpus_per_learner: int = 1
    # training params
    total_steps: int = 100
    step_time_s: float = 0.5                  # virtual step time (sim learners)
    checkpoint_interval_s: float = 30.0       # user-configured (paper §III-g)
    max_restarts: int = 3
    elastic: bool = False                     # allow DP shrink on learner loss
    priority: int = 0
    # data / results
    data_source: str = "cos://datasets/synthetic"
    dataset_gb: float = 1.0
    result_location: str = "cos://results"
    # learner payload knobs (real learners)
    real_compute: bool = False                # run actual JAX steps
    seed: int = 0
    extras: Dict[str, str] = field(default_factory=dict)

    def validate(self) -> Optional[str]:
        if self.learners < 1:
            return "learners must be >= 1"
        if self.gpus_per_learner < 0:
            return "gpus_per_learner must be >= 0"
        if self.checkpoint_interval_s <= 0:
            return "checkpoint_interval_s must be > 0"
        return None

    def to_jobspec(self):
        """Convert to the v2 resource model (kind ``train``)."""
        from repro.core.jobspec import JobSpec, Resources, TrainSpec
        return JobSpec(
            name=self.name,
            kind="train",
            tenant=self.tenant,
            framework=self.framework,
            resources=Resources(replicas=self.learners,
                                gpus_per_replica=self.gpus_per_learner),
            max_restarts=self.max_restarts,
            elastic=self.elastic,
            priority=self.priority,
            seed=self.seed,
            extras=dict(self.extras),
            train=TrainSpec(
                total_steps=self.total_steps,
                step_time_s=self.step_time_s,
                checkpoint_interval_s=self.checkpoint_interval_s,
                data_source=self.data_source,
                dataset_gb=self.dataset_gb,
                result_location=self.result_location,
                real_compute=self.real_compute,
                recovery_mode=self.extras.get("recovery_mode", "checkpoint"),
            ))
