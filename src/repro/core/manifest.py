"""Job manifest — what a user submits (paper §III-a).

``framework`` names one of the registry architectures: the platform treats
architectures the way DLaaS treats frameworks (opaque learner payloads).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional


@dataclass(frozen=True)
class JobManifest:
    name: str
    tenant: str = "default"
    framework: str = "paper-overhead-100m"    # architecture id
    learners: int = 1
    gpus_per_learner: int = 1
    # training params
    total_steps: int = 100
    step_time_s: float = 0.5                  # virtual step time (sim learners)
    checkpoint_interval_s: float = 30.0       # user-configured (paper §III-g)
    max_restarts: int = 3
    elastic: bool = False                     # allow DP shrink on learner loss
    priority: int = 0
    # data / results
    data_source: str = "cos://datasets/synthetic"
    dataset_gb: float = 1.0
    result_location: str = "cos://results"
    # learner payload knobs (real learners)
    real_compute: bool = False                # run actual JAX steps
    seed: int = 0
    extras: Dict[str, str] = field(default_factory=dict)

    def validate(self) -> Optional[str]:
        if self.learners < 1:
            return "learners must be >= 1"
        if self.gpus_per_learner < 0:
            return "gpus_per_learner must be >= 0"
        if self.checkpoint_interval_s <= 0:
            return "checkpoint_interval_s must be > 0"
        return None
