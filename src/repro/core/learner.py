"""Learner container processes (the DL job's compute).

Synchronous data-parallel semantics are modeled honestly: each learner
advances a step only when every peer's heartbeat is fresh — a dead peer
stalls the group exactly like a blocking all-reduce.  Recovery follows the
paper §III-h:

* ``checkpoint`` mode — the whole group rolls back to the latest checkpoint
  (work lost = time since last checkpoint, set by the user's interval);
* ``rejoin`` mode — the restarted learner fetches current parameters from
  its peers (parameter-server style) and the group continues (work lost ≈
  restart time only).

``real_compute`` learners run actual JAX training steps and persist real
parameter trees through the CheckpointManager — crash + restore with loss
continuity is exercised end-to-end in examples/fault_tolerance.py.
"""
from __future__ import annotations

from typing import Any, Optional

from repro.core.checkpoint import CheckpointManager
from repro.core.jobspec import JobSpec

HEARTBEAT_STALE = 3.0          # × step_time ⇒ peer considered unreachable
RESTORE_TIME = (1.0, 3.0)      # checkpoint download+load (virtual)
SAVE_TIME = (0.5, 1.5)         # checkpoint upload (virtual)


class RealPayload:
    """Actual JAX training, injected via platform.register_payload()."""

    def __init__(self, make_state, train_step, data, loss_key="loss"):
        self.make_state = make_state        # () -> TrainState
        self.train_step = train_step        # (state, batch) -> (state, metrics)
        self.data = data                    # .batch_at(step)
        self.loss_key = loss_key
        self.state = None

    def restore(self, tree: Optional[Any]) -> int:
        import jax.numpy as jnp
        self.state = self.make_state()
        if tree is None:
            return 0
        # overlay restored leaves (they come back as numpy)
        import jax
        self.state = jax.tree.map(
            lambda cur, new: jnp.asarray(new).astype(cur.dtype), self.state,
            tree)
        return int(self.state["step"])

    def step(self, step_idx: int) -> float:
        self.state, metrics = self.train_step(
            self.state, self.data.batch_at(step_idx))
        return float(metrics[self.loss_key])

    def snapshot(self):
        import jax
        return jax.tree.map(lambda x: x, self.state)


def make_learner_proc(platform, job_id: str, spec: JobSpec, idx: int):
    """Container process for learner ``idx`` of ``job_id``."""

    def proc(pod):
        sim = platform.sim
        vol = platform.volumes.get(f"vol-{job_id}")
        if vol is None:
            raise RuntimeError("volume not mounted")
        ckpt = CheckpointManager(platform.objectstore, job_id)
        # payload-agnostic dispatch: the framework adapter decides whether
        # this pod drives real compute or stays virtual-time
        payload = platform.frameworks.get(spec.framework).payload(
            platform, job_id, spec)
        # chaos seam: the platform's FaultInjector gates each step (OOM,
        # wedge) and scales this incarnation's step time (straggler)
        faults = getattr(platform, "faults", None)
        slow = faults.incarnation_factor(job_id, idx) \
            if faults is not None else 1.0

        # -- wait for load-data helper ------------------------------------
        while not vol.read("data_ready"):
            yield 0.2

        # -- restore ---------------------------------------------------------
        yield sim.rng.uniform(*RESTORE_TIME)
        step = 0
        group_steps = [vol.read(f"progress/{j}", {"step": 0})["step"]
                       for j in range(spec.learners)]
        if spec.recovery_mode == "rejoin" and \
                max(group_steps) > 0:
            step = max(group_steps)           # catch up from peers (PS-style)
            if payload is not None:
                # A restarted container has no parameters in memory: fetch
                # the peers' current snapshot from the shared volume, or
                # fall back to the latest checkpoint.  Jump-starting ``step``
                # without restoring would make the first payload.step() crash
                # (state=None) — or worse, silently pretend the parameters
                # caught up.
                snap = vol.read("param_snapshot")
                if snap is not None and snap.get("tree") is not None:
                    payload.restore(snap["tree"])
                    step = int(snap["step"])
                else:
                    loaded = ckpt.load()
                    if loaded is not None:
                        payload.restore(loaded[1])
                        step = int(loaded[0])   # params only caught up to here
                    else:
                        payload.restore(None)
                        step = 0
            vol.append(f"log/{idx}", f"[{sim.now:.2f}] rejoined at step {step}")
        else:
            bad = ckpt.newest_invalid()
            if bad is not None:
                # restore evidence for the FailureClassifier: the newest
                # generation failed integrity and is being skipped
                vol.append(f"log/{idx}",
                           f"[{sim.now:.2f}] checkpoint step {bad} failed "
                           f"integrity; falling back")
            loaded = ckpt.load()
            if loaded is not None:
                step = int(loaded[0])
                if payload is not None:
                    payload.restore(loaded[1])
                vol.append(f"log/{idx}",
                           f"[{sim.now:.2f}] restored checkpoint step {step}")
            elif payload is not None:
                payload.restore(None)
        last_ckpt_t = sim.now

        vol.write(f"progress/{idx}", {"step": step, "t": sim.now})

        # -- train loop ---------------------------------------------------------
        while step < spec.total_steps:
            if faults is not None:      # armed faults crash the pod here
                faults.learner_gate(job_id, idx, step, vol)
            # group rollback marker (checkpoint-mode recovery)
            rb = vol.read("rollback_to")
            if rb is not None and rb.get("epoch", -1) > \
                    vol.read(f"rb_ack/{idx}", -1):
                step = min(step, rb["step"])
                vol.write(f"rb_ack/{idx}", rb["epoch"])
                if payload is not None:
                    loaded = ckpt.load(rb["step"]) or ckpt.load()
                    if loaded is not None:
                        payload.restore(loaded[1])
                vol.append(f"log/{idx}",
                           f"[{sim.now:.2f}] rolled back to step {step}")

            # synchronous DP: stall while any peer heartbeat is stale
            # (a finished peer — exit file present — no longer heartbeats).
            # World size is dynamic (elastic re-meshing shrinks it).
            world = vol.read("world", spec.learners)
            if idx >= world:
                return 0                      # resized away (defensive)
            stale = False
            for j in range(world):
                if j == idx or vol.read(f"exit/{j}") is not None:
                    continue
                pr = vol.read(f"progress/{j}")
                allow = HEARTBEAT_STALE * spec.step_time_s + 2.0
                if pr is not None and pr.get("saving"):
                    # peer announced a checkpoint upload: extend the lease by
                    # the worst-case save time so a slow save (or a short
                    # checkpoint interval) doesn't read as a dead peer
                    allow += SAVE_TIME[1]
                if pr is None or (sim.now - pr["t"]) > allow:
                    stale = True
            if stale:
                vol.write(f"progress/{idx}",
                          {"step": step, "t": sim.now, "stalled": True})
                yield spec.step_time_s
                continue

            # one training step
            if payload is not None:
                loss = payload.step(step)
                vol.write("last_loss", loss)
            yield spec.step_time_s * slow
            step += 1
            vol.write(f"progress/{idx}", {"step": step, "t": sim.now})
            if payload is not None and idx == 0 and \
                    spec.recovery_mode == "rejoin":
                # publish the current parameters for rejoin-mode peers
                # (PS-style fetch through the shared volume; cheap — the
                # snapshot holds references, not copies)
                vol.write("param_snapshot",
                          {"step": step, "tree": payload.snapshot()})
            if step % 50 == 0:
                vol.append(f"log/{idx}", f"[{sim.now:.2f}] step {step}")

            # periodic checkpoint (chief learner)
            if idx == 0 and (sim.now - last_ckpt_t) >= spec.checkpoint_interval_s:
                tree = payload.snapshot() if payload is not None \
                    else {"step": step}
                import numpy as np
                tree = tree if payload is not None else {
                    "step": np.asarray(step)}
                ckpt.save(step, tree)
                last_ckpt_t = sim.now
                vol.append(f"log/{idx}", f"[{sim.now:.2f}] checkpoint @ {step}")
                # heartbeat with a save lease, then refresh once the upload
                # finishes — peers must not mistake the save window for a
                # dead chief and spuriously stall the gang
                vol.write(f"progress/{idx}",
                          {"step": step, "t": sim.now, "saving": True})
                yield sim.rng.uniform(*SAVE_TIME)
                vol.write(f"progress/{idx}", {"step": step, "t": sim.now})

        # -- orderly exit: write exit code to the shared volume --------------
        vol.write(f"exit/{idx}", 0)
        vol.append(f"log/{idx}", f"[{sim.now:.2f}] done ({step} steps)")
        return 0

    return proc
