"""ETCD analog: replicated KV on top of core/raft.py.

The controller records learner statuses here; the Guardian reads and
aggregates them (paper §III-f).  Writes are quorum-committed: they succeed
with one replica down and *stall* with two down — the availability property
tests assert both.

Client calls are generator helpers (``yield from store.put(...)``) so
platform processes block in virtual time while Raft replicates.
"""
from __future__ import annotations

from typing import Any, Dict, Generator, Optional

from repro.core.raft import LEADER, RaftNode
from repro.core.sim import Sim

PUT_TIMEOUT = 5.0
POLL = 0.01


class StateStore:
    def __init__(self, sim: Sim, n_replicas: int = 3):
        self.sim = sim
        self.replicas = [RaftNode(sim, i) for i in range(n_replicas)]
        for r in self.replicas:
            r.set_peers(self.replicas)

    # -- admin / fault injection -----------------------------------------
    def leader(self) -> Optional[RaftNode]:
        live = [r for r in self.replicas if r.alive and r.state == LEADER]
        if not live:
            return None
        # the real leader is the one with the highest term
        return max(live, key=lambda r: r.current_term)

    def crash_replica(self, idx: int) -> None:
        self.replicas[idx].crash()

    def restart_replica(self, idx: int) -> None:
        self.replicas[idx].restart()

    def available(self) -> bool:
        return sum(r.alive for r in self.replicas) >= \
            (len(self.replicas) // 2 + 1)

    # -- client API (generators: run inside platform processes) -----------
    def put(self, key: str, value: Any,
            timeout: float = PUT_TIMEOUT) -> Generator[float, None, bool]:
        """Quorum write; returns True on commit, False on timeout."""
        deadline = self.sim.now + timeout
        while self.sim.now < deadline:
            ldr = self.leader()
            if ldr is None:
                yield POLL
                continue
            idx = ldr.propose(("put", key, value))
            if idx is None:
                yield POLL
                continue
            term = ldr.current_term
            while self.sim.now < deadline and ldr.alive and \
                    ldr.current_term == term:
                if ldr.committed(idx):
                    return True
                yield POLL
            # leader changed / crashed before commit: retry via new leader
        return False

    def delete(self, key: str, timeout: float = PUT_TIMEOUT):
        deadline = self.sim.now + timeout
        while self.sim.now < deadline:
            ldr = self.leader()
            if ldr is not None:
                idx = ldr.propose(("del", key))
                if idx is not None:
                    term = ldr.current_term
                    while self.sim.now < deadline and ldr.alive and \
                            ldr.current_term == term:
                        if ldr.committed(idx):
                            return True
                        yield POLL
                    continue
            yield POLL
        return False

    def get(self, key: str, default: Any = None) -> Any:
        """Read from the leader's applied state (leader read)."""
        ldr = self.leader()
        if ldr is None:
            raise TimeoutError("statestore unavailable (no leader)")
        return ldr.kv.get(key, default)

    def get_prefix(self, prefix: str) -> Dict[str, Any]:
        ldr = self.leader()
        if ldr is None:
            raise TimeoutError("statestore unavailable (no leader)")
        return {k: v for k, v in ldr.kv.items() if k.startswith(prefix)}

    def try_get(self, key: str, default: Any = None) -> Any:
        try:
            return self.get(key, default)
        except TimeoutError:
            return default
