"""Checkpoint manager: user-directed + periodic checkpoints to object store.

Layout per checkpoint:
    ckpt/<job>/<step>/blob/<leaf-path>     raw little-endian array bytes
    ckpt/<job>/<step>/manifest             atomic JSON: shapes/dtypes/sha256s

Guarantees:
* **Atomic publish** — the manifest is written last; a checkpoint without a
  valid manifest does not exist (crash-during-save leaves no torn state).
* **Integrity** — every blob's sha256 is verified on load; a corrupt
  checkpoint is skipped and the previous one used (tested).
* **Retention** — keep the most recent ``keep_last`` checkpoints.

Works for real JAX pytrees (e2e fault-tolerance example) and for the tiny
state dicts of simulated learners alike.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.objectstore import ObjectStore

SEP = "/"


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes                      # bf16 etc. (installed with jax)
        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}{SEP}"))
    else:
        out[prefix.rstrip(SEP)] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Any:
    root: Dict[str, Any] = {}
    for path, val in flat.items():
        parts = path.split(SEP)
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = val
    return root


class CheckpointManager:
    def __init__(self, store: ObjectStore, job_id: str, keep_last: int = 3):
        if not job_id or SEP in job_id:
            # a slash would fold extra levels into the key layout and break
            # step parsing / prefix GC
            raise ValueError(f"invalid job_id {job_id!r}: must be non-empty "
                             f"and must not contain {SEP!r}")
        if keep_last < 0:
            raise ValueError(f"keep_last must be >= 0, got {keep_last}")
        self.store = store
        self.job_id = job_id
        self.keep_last = keep_last

    def _base(self, step: int) -> str:
        return f"ckpt/{self.job_id}/{step:012d}"

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any) -> int:
        """Returns total bytes written."""
        flat = _flatten(tree)
        base = self._base(step)
        manifest: Dict[str, Any] = {"step": step, "leaves": {}}
        total = 0
        for path, arr in flat.items():
            data = np.ascontiguousarray(arr).tobytes()
            blob_path = f"{base}/blob/{path}"
            digest = self.store.put(blob_path, data)
            manifest["leaves"][path] = {
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "sha256": digest, "bytes": len(data)}
            total += len(data)
        self.store.put_json_atomic(f"{base}/manifest", manifest)
        self._gc(current=step)
        return total

    # ------------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        prefix = f"ckpt/{self.job_id}/"
        for p in self.store.list_prefix(prefix):
            # parse relative to the listing prefix (an absolute split index
            # would mis-parse if the layout ever gains/loses a level)
            rest = p[len(prefix):]
            head, _, tail = rest.partition("/")
            if tail.rstrip("/") == "manifest" and head.isdigit():
                out.append(int(head))
        return sorted(set(out))

    def latest_valid_step(self) -> Optional[int]:
        for step in reversed(self.steps()):
            if self._valid(step):
                return step
        return None

    def newest_invalid(self) -> Optional[int]:
        """The newest checkpoint generation, iff it fails integrity.

        This is the classifier's CKPT_CORRUPT evidence: a crashed learner
        restoring now would skip this generation and silently lose work
        back to the previous one.
        """
        steps = self.steps()
        if steps and not self._valid(steps[-1]):
            return steps[-1]
        return None

    def fallback_one(self) -> Optional[int]:
        """Safe-list repair for CKPT_CORRUPT: drop exactly one (corrupt)
        newest generation and return the step to roll the gang back to.

        Deliberately bounded — never deletes a generation that passes
        integrity, and never walks further back than one generation, so
        a misclassification cannot destroy good checkpoints.
        """
        bad = self.newest_invalid()
        if bad is not None:
            self.store.delete_prefix(self._base(bad))
        return self.latest_valid_step()

    def _valid(self, step: int) -> bool:
        base = self._base(step)
        man = self.store.get_json_verified(f"{base}/manifest")
        if man is None:
            return False
        for path, meta in man["leaves"].items():
            if not self.store.verify(f"{base}/blob/{path}", meta["sha256"]):
                return False
        return True

    def load(self, step: Optional[int] = None) -> Optional[Tuple[int, Any]]:
        """Load ``step`` (or the latest *valid* checkpoint).  Corrupt or torn
        checkpoints are skipped, falling back to older ones."""
        candidates = [step] if step is not None else list(reversed(self.steps()))
        for s in candidates:
            base = self._base(s)
            man = self.store.get_json_verified(f"{base}/manifest")
            if man is None:
                continue
            flat = {}
            ok = True
            for path, meta in man["leaves"].items():
                blob_path = f"{base}/blob/{path}"
                if not self.store.verify(blob_path, meta["sha256"]):
                    ok = False
                    break
                arr = np.frombuffer(self.store.get(blob_path),
                                    dtype=_np_dtype(meta["dtype"]))
                flat[path] = arr.reshape(meta["shape"])
            if ok:
                return s, _unflatten(flat)
        return None

    def _gc(self, current: Optional[int] = None) -> None:
        """Retention: keep the newest ``keep_last`` checkpoints, always
        including the just-saved ``current``.  ``keep_last=0`` keeps *only*
        the current one (a plain ``steps[:-0]`` slice would be empty and
        delete nothing — the historical bug)."""
        steps = self.steps()
        protect = set(steps[-self.keep_last:]) if self.keep_last > 0 else set()
        if current is not None:
            protect.add(current)
        for s in steps:
            if s not in protect:
                self.store.delete_prefix(self._base(s))
