"""Placement: gang scheduling + tenant quotas + bin-packing.

Distributed DL learners are useless in fractions — a job's learner pods are
admitted all-or-nothing (gang).  Placement packs GPUs to minimize
fragmentation; spread across nodes is available for fault-domain diversity.
"""
from __future__ import annotations

# Unschedulable is defined next to the retry loop that catches it and
# re-exported here for its historical import path.
from repro.core.cluster import Cluster, Node, PodSpec, Unschedulable
from repro.core.tenancy import TenancyManager


class Scheduler:
    def __init__(self, tenancy: TenancyManager, strategy: str = "binpack"):
        self.tenancy = tenancy
        self.strategy = strategy

    # per-pod placement hook used by Cluster._create_pod
    def place(self, cluster: Cluster, spec: PodSpec) -> Node:
        nodes = [n for n in cluster.nodes if n.alive and
                 n.gpus_free() >= spec.gpus]
        if not nodes:
            raise Unschedulable(f"no node fits pod {spec.name} "
                                f"({spec.gpus} GPUs)")
        # system pods (0 GPUs) spread across nodes for fault-domain
        # diversity; GPU pods bin-pack to minimize fragmentation
        if spec.gpus == 0:
            return min(nodes, key=lambda n: sum(1 for p in n.pods
                                                if p.spec.gpus == 0))
        if self.strategy == "binpack":      # fullest node that still fits
            return min(nodes, key=lambda n: n.gpus_free())
        return max(nodes, key=lambda n: n.gpus_free())   # spread

    def max_feasible_gang(self, cluster: Cluster, gpus_each: int,
                          upper: int) -> int:
        """Largest world size ≤ upper that fits current live capacity."""
        free = sorted((n.gpus_free() for n in cluster.nodes if n.alive),
                      reverse=True)
        world = 0
        for _ in range(upper):
            for i, f in enumerate(free):
                if f >= gpus_each:
                    free[i] -= gpus_each
                    world += 1
                    break
            else:
                break
        return world

    # gang admission used by the Guardian before creating learner pods
    def admit_gang(self, cluster: Cluster, tenant: str, n_pods: int,
                   gpus_each: int) -> None:
        """All-or-nothing: quota + capacity for every learner, atomically."""
        self.tenancy.reserve(tenant, n_pods * gpus_each)     # raises on quota
        free = sorted((n.gpus_free() for n in cluster.nodes if n.alive),
                      reverse=True)
        need = [gpus_each] * n_pods
        for g in need:                      # first-fit-decreasing feasibility
            for i, f in enumerate(free):
                if f >= g:
                    free[i] -= g
                    break
            else:
                self.tenancy.release(tenant, n_pods * gpus_each)
                raise Unschedulable(
                    f"gang of {n_pods}×{gpus_each} GPUs does not fit")

    def release_gang(self, tenant: str, n_pods: int, gpus_each: int) -> None:
        self.tenancy.release(tenant, n_pods * gpus_each)
