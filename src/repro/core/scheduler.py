"""Placement: gang scheduling + tenant quotas + bin-packing.

Distributed DL learners are useless in fractions — a job's learner pods are
admitted all-or-nothing (gang).  Placement packs GPUs to minimize
fragmentation; spread across nodes is available for fault-domain diversity.
"""
from __future__ import annotations

from typing import Dict, FrozenSet

# Unschedulable is defined next to the retry loop that catches it and
# re-exported here for its historical import path.
from repro.core.cluster import Cluster, Node, PodSpec, Unschedulable
from repro.core.tenancy import TenancyManager


class Scheduler:
    def __init__(self, tenancy: TenancyManager, strategy: str = "binpack"):
        self.tenancy = tenancy
        self.strategy = strategy
        # per-job node exclusions (POISONED_NODE repair).  Guardian-owned:
        # acquired only through the `_repair_exclude_node` provider and
        # swept by `_rollback` — the SC302 node_exclusion pair checks that
        # an exclusion can never leak past the job that acquired it.
        self._excluded: Dict[str, FrozenSet[str]] = {}

    # -- node exclusion (self-healing repair: reschedule off a node) ----
    def exclude_node(self, job_id: str, node: str) -> None:
        self._excluded[job_id] = \
            self._excluded.get(job_id, frozenset()) | {node}

    def clear_exclusions(self, job_id: str) -> None:
        self._excluded.pop(job_id, None)

    def excluded_for(self, job_id: str) -> FrozenSet[str]:
        return self._excluded.get(job_id, frozenset())

    # per-pod placement hook used by Cluster._create_pod
    def place(self, cluster: Cluster, spec: PodSpec) -> Node:
        excluded = self._excluded.get(spec.labels.get("job"), frozenset())
        nodes = [n for n in cluster.nodes if n.alive and
                 n.name not in excluded and n.gpus_free() >= spec.gpus]
        if not nodes:
            raise Unschedulable(f"no node fits pod {spec.name} "
                                f"({spec.gpus} GPUs)")
        # system pods (0 GPUs) spread across nodes for fault-domain
        # diversity; GPU pods bin-pack to minimize fragmentation
        if spec.gpus == 0:
            return min(nodes, key=lambda n: sum(1 for p in n.pods
                                                if p.spec.gpus == 0))
        if self.strategy == "binpack":      # fullest node that still fits
            return min(nodes, key=lambda n: n.gpus_free())
        return max(nodes, key=lambda n: n.gpus_free())   # spread

    def max_feasible_gang(self, cluster: Cluster, gpus_each: int,
                          upper: int) -> int:
        """Largest world size ≤ upper that fits current live capacity."""
        free = sorted((n.gpus_free() for n in cluster.nodes if n.alive),
                      reverse=True)
        world = 0
        for _ in range(upper):
            for i, f in enumerate(free):
                if f >= gpus_each:
                    free[i] -= gpus_each
                    world += 1
                    break
            else:
                break
        return world

    # gang admission used by the Guardian before creating learner pods
    def admit_gang(self, cluster: Cluster, tenant: str, n_pods: int,
                   gpus_each: int) -> None:
        """All-or-nothing: quota + capacity for every learner, atomically."""
        self.tenancy.reserve(tenant, n_pods * gpus_each)     # raises on quota
        free = sorted((n.gpus_free() for n in cluster.nodes if n.alive),
                      reverse=True)
        need = [gpus_each] * n_pods
        for g in need:                      # first-fit-decreasing feasibility
            for i, f in enumerate(free):
                if f >= g:
                    free[i] -= g
                    break
            else:
                self.tenancy.release(tenant, n_pods * gpus_each)
                raise Unschedulable(
                    f"gang of {n_pods}×{gpus_each} GPUs does not fit")

    def release_gang(self, tenant: str, n_pods: int, gpus_each: int) -> None:
        self.tenancy.release(tenant, n_pods * gpus_each)
