"""Kubernetes analog: nodes, pods, containers, and the three controller
abstractions the paper's design rests on.

* **Job**         — run-to-completion exactly-once semantics: a crashed pod is
                    recreated (fresh process state) until it succeeds or the
                    backoff limit is hit.  The Guardian runs under this.
* **StatefulSet** — N replicas with stable identities (``name-i``) that are
                    individually restarted in place.  Learners run under this.
* **Deployment**  — N interchangeable always-restart replicas behind a
                    service name (API, LCM, helper pods, core services).

Crash injection is first-class: ``kubectl_delete_pod`` / ``crash_node``
model the manual kills used for the paper's Fig. 4 and the node failures of
§II.  Restart latencies are sampled per component class from configured
ranges so recovery-time measurements are honest.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, Generator, List, Optional, Tuple

from repro.core.sim import Sim
from repro.core.states import pod_transition

PENDING, RUNNING, SUCCEEDED, FAILED = "PENDING", "RUNNING", "SUCCEEDED", "FAILED"


class RpcError(Exception):
    """Target service has no live endpoint (connection refused)."""


class Unschedulable(Exception):
    """No node can host the pod right now — placement retries, k8s-style.
    Lives here (not in ``scheduler``) so ``_try_place`` can catch exactly
    this type instead of a broad ``except Exception`` that would also
    swallow scheduler bugs; ``scheduler`` re-exports it."""


@dataclass
class ContainerSpec:
    name: str
    # factory(pod) -> generator yielding sleep durations; return value = exit 0
    proc: Callable[["Pod"], Generator[float, None, Any]]


@dataclass
class PodSpec:
    name: str
    containers: List[ContainerSpec]
    gpus: int = 0
    startup_range: Tuple[float, float] = (1.0, 2.0)   # image pull/bind time
    labels: Dict[str, str] = field(default_factory=dict)
    tenant: str = "default"


class Pod:
    def __init__(self, spec: PodSpec, node: Optional["Node"], cluster: "Cluster"):
        self.spec = spec
        self.node = node
        self.cluster = cluster
        pod_transition(self, PENDING)
        self.incarnation = 0
        self.exit_codes: Dict[str, Any] = {}
        self.exit_detail = ""          # container crash message (evidence)
        self.restarts = 0
        self.started_at: Optional[float] = None

    @property
    def name(self) -> str:
        return self.spec.name

    def alive(self) -> bool:
        return self.status == RUNNING and self.node is not None \
            and self.node.alive

    # ------------------------------------------------------------------
    def _start(self) -> None:
        if self.status != PENDING:
            return   # failed/replaced while its start was queued — stay dead
        if self.node is None or not self.node.alive:
            self.fail()
            return
        sim = self.cluster.sim
        self.incarnation += 1
        inc = self.incarnation
        pod_transition(self, RUNNING)
        self.started_at = sim.now
        self.exit_codes = {}
        self.exit_detail = ""
        sim.log(f"pod/{self.name} RUNNING on {self.node.name} (inc {inc})")
        if self.node.poisoned:
            # poisoned node: every pod placed here dies shortly after
            # starting, with no diagnostic detail — the classifier must
            # infer the cause from node co-occurrence, not from the pod
            sim.schedule(self.cluster.POISON_KILL_DELAY,
                         lambda inc=inc: self.incarnation == inc and
                         self.fail())
        for c in self.spec.containers:
            gen = c.proc(self)
            guard = lambda inc=inc: (self.incarnation == inc and
                                     self.status == RUNNING and self.node.alive)
            sim.spawn(gen, guard=guard,
                      on_exit=lambda v, c=c, inc=inc: self._on_exit(c, inc, v),
                      on_error=lambda e, c=c, inc=inc: self._on_exit(c, inc, e, err=True))

    def _on_exit(self, c: ContainerSpec, inc: int, value: Any, err: bool = False):
        if self.incarnation != inc or self.status != RUNNING:
            return
        self.exit_codes[c.name] = value if not err else f"error:{value}"
        if err:
            self.exit_detail = str(value)
            self.cluster.sim.log(f"pod/{self.name} container {c.name} crashed: {value}")
            self.fail()
        elif len(self.exit_codes) == len(self.spec.containers):
            pod_transition(self, SUCCEEDED)
            self.cluster.sim.log(f"pod/{self.name} SUCCEEDED")
            self.cluster._pod_done(self)

    def fail(self) -> None:
        if self.status in (FAILED, SUCCEEDED):
            return
        pod_transition(self, FAILED)
        self.cluster.sim.log(f"pod/{self.name} FAILED")
        self.cluster._pod_done(self)


@dataclass
class Node:
    name: str
    gpus: int = 8
    alive: bool = True
    # a poisoned node stays alive and schedulable (the failure is hidden
    # from the control plane) but kills every pod placed on it — the
    # §III-f gray-failure mode behind the POISONED_NODE classification
    poisoned: bool = False
    pods: List[Pod] = field(default_factory=list)

    def gpus_free(self) -> int:
        return self.gpus - sum(p.spec.gpus for p in self.pods
                               if p.status in (PENDING, RUNNING))


# ---------------------------------------------------------------------------
class Controller:
    """Base for Job / StatefulSet / Deployment restart semantics."""

    def __init__(self, cluster: "Cluster", name: str):
        self.cluster = cluster
        self.name = name
        self.deleted = False

    def on_pod_done(self, pod: Pod) -> None:
        raise NotImplementedError

    def delete(self) -> None:
        self.deleted = True


class KJob(Controller):
    """K8S Job: reliably run ONE pod to completion; restart on failure up to
    ``backoff_limit`` times."""

    def __init__(self, cluster, name, spec: PodSpec, backoff_limit: int = 6,
                 on_exhausted: Optional[Callable[[], None]] = None,
                 on_success: Optional[Callable[[Any], None]] = None):
        super().__init__(cluster, name)
        self.spec = spec
        self.backoff_limit = backoff_limit
        self.failures = 0
        self.on_exhausted = on_exhausted
        self.on_success = on_success
        self.pod = cluster._create_pod(spec, self)

    def on_pod_done(self, pod: Pod) -> None:
        if self.deleted:
            return
        if pod.status == SUCCEEDED:
            if self.on_success:
                self.on_success(pod.exit_codes)
            return
        self.failures += 1
        if self.failures > self.backoff_limit:
            self.cluster.sim.log(f"job/{self.name} backoff limit exceeded")
            if self.on_exhausted:
                self.on_exhausted()
            return
        self.pod = self.cluster._create_pod(self.spec, self)


class StatefulSet(Controller):
    """Stable-identity replicas; each crashed replica is recreated in place."""

    def __init__(self, cluster, name, make_spec: Callable[[int], PodSpec],
                 replicas: int):
        super().__init__(cluster, name)
        self.make_spec = make_spec
        self.replicas = replicas
        self.restarts_total: List[int] = [0] * replicas
        self.pods: List[Pod] = [
            cluster._create_pod(make_spec(i), self) for i in range(replicas)]

    def on_pod_done(self, pod: Pod) -> None:
        if self.deleted or pod.status == SUCCEEDED:
            return
        idx = next((i for i, p in enumerate(self.pods) if p is pod), None)
        if idx is None or idx >= self.replicas:
            return                            # stale / resized away
        self.restarts_total[idx] += 1
        self.pods[idx] = self.cluster._create_pod(self.make_spec(idx), self)

    def resize(self, n: int) -> None:
        """Elastic shrink/grow.  Shrunk-away replicas are killed and not
        recreated; growth appends fresh stable identities."""
        old = self.replicas
        self.replicas = n
        if n < old:
            for p in self.pods[n:]:
                p.fail()
            self.pods = self.pods[:n]
            self.restarts_total = self.restarts_total[:n]
        else:
            for i in range(old, n):
                self.restarts_total.append(0)
                self.pods.append(
                    self.cluster._create_pod(self.make_spec(i), self))

    def all_succeeded(self) -> bool:
        return all(p.status == SUCCEEDED for p in self.pods)


class Deployment(Controller):
    """Restart-on-failure replicas behind a service name (load-balanced RPC).
    A pod whose containers all exit 0 is left SUCCEEDED (helper pods finish;
    service pods never return)."""

    def __init__(self, cluster, name, make_spec: Callable[[int], PodSpec],
                 replicas: int = 1, service: Optional[str] = None):
        super().__init__(cluster, name)
        self.make_spec = make_spec
        self.pods: List[Pod] = [
            cluster._create_pod(make_spec(i), self) for i in range(replicas)]
        if service:
            cluster.services.setdefault(service, []).append(self)

    def on_pod_done(self, pod: Pod) -> None:
        if self.deleted or pod.status == SUCCEEDED:
            return
        # Stale notifications happen (a watch event for a pod this
        # controller already replaced) — same guard as StatefulSet.
        idx = next((i for i, p in enumerate(self.pods) if p is pod), None)
        if idx is None:
            return
        self.pods[idx] = self.cluster._create_pod(self.make_spec(idx), self)

    def all_succeeded(self) -> bool:
        return all(p.status == SUCCEEDED for p in self.pods)


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PodRecord:
    """Lightweight tombstone for a garbage-collected terminal pod, kept in
    a bounded ring so recovery-time measurements still see short-lived
    incarnations without the live dict growing forever."""

    uid: str
    name: str
    status: str
    started_at: Optional[float]
    finished_at: float
    node: Optional[str] = None        # where the last incarnation ran
    exit_detail: str = ""             # crash message (classifier evidence)


class Cluster:
    """The K8S control plane + scheduler (see core/scheduler.py for policy)."""

    #: terminal-pod tombstones retained for observability (Fig-4 scans)
    HISTORY_LIMIT = 512

    def __init__(self, sim: Sim, n_nodes: int = 16, gpus_per_node: int = 8):
        self.sim = sim
        self.nodes = [Node(f"node-{i}", gpus_per_node) for i in range(n_nodes)]
        self.pods: Dict[str, Pod] = {}
        self.pod_history: Deque[PodRecord] = deque(maxlen=self.HISTORY_LIMIT)
        self.services: Dict[str, List[Deployment]] = {}
        self._uid = itertools.count()
        self.scheduler = None      # injected by platform (core/scheduler.py)

    # -- pod lifecycle --------------------------------------------------
    def _create_pod(self, spec: PodSpec, owner: Controller) -> Pod:
        """Create a pod.  If it is unschedulable NOW (e.g. its node just
        died and no spare capacity exists) it stays PENDING and placement
        retries every few seconds — exactly k8s semantics; the Guardian's
        elastic policy watches for prolonged PENDING."""
        pod = Pod(spec, None, self)
        pod.owner = owner
        pod.uid = f"{spec.name}#{next(self._uid)}"
        self.pods[pod.uid] = pod
        self._try_place(pod)
        return pod

    def _try_place(self, pod: Pod) -> None:
        if pod.status not in (PENDING,):
            return
        try:
            node = self._place(pod.spec)
        except Unschedulable:
            self.sim.schedule(3.0, self._try_place, pod)   # stay PENDING
            return
        pod.node = node
        node.pods.append(pod)
        lo, hi = pod.spec.startup_range
        self.sim.schedule(self.sim.rng.uniform(lo, hi), pod._start)

    def _place(self, spec: PodSpec) -> Node:
        if self.scheduler is not None:
            return self.scheduler.place(self, spec)
        for n in self.nodes:
            if n.alive and n.gpus_free() >= spec.gpus:
                return n
        raise Unschedulable(f"unschedulable pod {spec.name}")

    def _pod_done(self, pod: Pod) -> None:
        if pod.node is not None and pod in pod.node.pods:
            pod.node.pods.remove(pod)
        owner = getattr(pod, "owner", None)
        if owner is not None:
            # controller notices via watch after a short delay
            self.sim.schedule(0.2, self._notify_owner_and_gc, owner, pod)
        else:
            self._gc_pod(pod)

    def _notify_owner_and_gc(self, owner: Controller, pod: Pod) -> None:
        try:
            owner.on_pod_done(pod)
        finally:
            self._gc_pod(pod)

    def _gc_pod(self, pod: Pod) -> None:
        """Drop a terminal pod from the live dict once its controller has
        reacted.  Controllers keep their own references (a Deployment's
        SUCCEEDED helper pods stay visible through ``dep.pods``); this only
        bounds the cluster-wide ``name#uid`` map, which otherwise grows by
        one entry per restart for the life of the simulation."""
        if pod.status not in (SUCCEEDED, FAILED):
            return
        uid = getattr(pod, "uid", None)
        if uid is not None and self.pods.get(uid) is pod:
            del self.pods[uid]
            self.pod_history.append(PodRecord(
                uid=uid, name=pod.spec.name, status=pod.status,
                started_at=pod.started_at, finished_at=self.sim.now,
                node=pod.node.name if pod.node is not None else None,
                exit_detail=pod.exit_detail))

    # -- fault injection (kubectl of the paper's Fig. 4) -----------------
    def kubectl_delete_pod(self, name: str) -> bool:
        for pod in list(self.pods.values()):
            if pod.spec.name == name and pod.status == RUNNING:
                pod.fail()
                return True
        return False

    def crash_node(self, node_name: str) -> None:
        node = next(n for n in self.nodes if n.name == node_name)
        node.alive = False
        self.sim.log(f"node/{node_name} DOWN")
        for pod in list(node.pods):
            pod.fail()

    def heal_node(self, node_name: str) -> None:
        node = next(n for n in self.nodes if n.name == node_name)
        node.alive = True
        node.poisoned = False
        self.sim.log(f"node/{node_name} UP")

    #: poisoned-node kill latency: the pod comes up, then dies
    POISON_KILL_DELAY = 0.5

    def poison_node(self, node_name: str) -> None:
        """Gray failure: the node stays alive and schedulable but every
        pod on it dies shortly after starting (no diagnostic detail)."""
        node = next(n for n in self.nodes if n.name == node_name)
        node.poisoned = True
        self.sim.log(f"node/{node_name} POISONED")
        for pod in list(node.pods):
            if pod.status == RUNNING:
                self.sim.schedule(
                    self.POISON_KILL_DELAY,
                    lambda p=pod, inc=pod.incarnation:
                    p.incarnation == inc and p.fail())

    def cure_node(self, node_name: str) -> None:
        node = next(n for n in self.nodes if n.name == node_name)
        node.poisoned = False
        self.sim.log(f"node/{node_name} CURED")

    # -- service RPC ------------------------------------------------------
    def rpc(self, service: str):
        """Resolve a live endpoint pod for ``service`` (round-robin over live
        replicas); raises RpcError when none — callers retry with backoff."""
        for dep in self.services.get(service, []):
            live = [p for p in dep.pods if p.alive()]
            if live:
                return live[self.sim.rng.randrange(len(live))]
        raise RpcError(f"service {service!r} unavailable")
