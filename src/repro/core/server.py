"""Serve- and dryrun-kind workload pods (Job API v2 kinds beyond training).

Server pods model inference replicas: each drains requests from the job's
shared queue in virtual time and heartbeats through the shared NFS volume —
the same contract learners use, so the Guardian's generic gang monitor
covers every kind.  The shared ``served`` counter lives on the volume, so a
restarted server resumes where the gang left off instead of re-serving.

Both pod types run customer code and are therefore labelled with restricted
``NetworkPolicy`` roles: they may only touch their own volume and their own
job's object-store prefix (where they ship their logs, keeping
``ApiClient.logs`` uniform across kinds).
"""
from __future__ import annotations

import json

from repro.core.jobspec import JobSpec, resolve_cells

LOG_SHIP_EVERY = 10              # requests between log shipments


def _ship_log(platform, job_id: str, idx: int, line: str) -> None:
    """Append one line to the job's COS log key (own-prefix write — the
    only object-store path NetworkPolicy allows a workload pod)."""
    key = f"cos/{job_id}/logs/{idx}"
    existing = platform.objectstore.get(key) if \
        platform.objectstore.exists(key) else b""
    platform.objectstore.put(key, existing + line.encode() + b"\n")


def make_server_proc(platform, job_id: str, spec: JobSpec, idx: int):
    """Container process for server replica ``idx`` of a serve-kind job."""

    def proc(pod):
        sim = platform.sim
        vol = platform.volumes.get(f"vol-{job_id}")
        if vol is None:
            raise RuntimeError("volume not mounted")
        sv = spec.serve
        _ship_log(platform, job_id, idx,
                  f"[{sim.now:.2f}] server {idx} up "
                  f"(framework {spec.framework})")
        while True:
            # claim-then-serve: the claim is atomic (no yield between read
            # and write), so a gang of R replicas serves EXACTLY
            # ``requests`` — no stale-read overshoot of up to R-1
            claimed = vol.read("claimed", 0)
            if sv.requests and claimed >= sv.requests:
                break                         # queue drained by the gang
            vol.write("claimed", claimed + 1)
            yield sv.request_time_s           # process one request
            served = vol.read("served", 0) + 1
            vol.write("served", served)
            vol.write(f"progress/{idx}", {"served": served, "t": sim.now})
            if served % LOG_SHIP_EVERY == 0:
                _ship_log(platform, job_id, idx,
                          f"[{sim.now:.2f}] served {served}")
        vol.write(f"exit/{idx}", 0)
        _ship_log(platform, job_id, idx,
                  f"[{sim.now:.2f}] server {idx} done "
                  f"({vol.read('served', 0)} served)")
        return 0

    return proc


def make_dryrun_proc(platform, job_id: str, spec: JobSpec, idx: int):
    """Container process for a dryrun-kind job: walk the sweep cells,
    publishing one artifact per cell to the job's COS prefix.  Cell
    completion markers live on the volume, so a restarted runner resumes
    the sweep instead of recompiling finished cells."""

    def proc(pod):
        sim = platform.sim
        vol = platform.volumes.get(f"vol-{job_id}")
        if vol is None:
            raise RuntimeError("volume not mounted")
        dr = spec.dryrun
        cells = resolve_cells(dr)
        for ci, cell in enumerate(cells):
            if vol.read(f"cell/{ci}") is not None and not dr.force:
                continue                      # resumable sweep
            yield dr.cell_time_s              # virtual lower + compile
            rec = {"ok": True, "arch": cell.arch, "shape": cell.shape,
                   "mesh": cell.mesh_name, "job": job_id}
            key = (f"cos/{job_id}/dryrun/"
                   f"{cell.arch}__{cell.shape}__{cell.mesh_name}.json")
            platform.objectstore.put(key, json.dumps(rec).encode())
            vol.write(f"cell/{ci}", key)
            vol.write(f"progress/{idx}", {"cells": ci + 1, "t": sim.now})
            _ship_log(platform, job_id, idx,
                      f"[{sim.now:.2f}] cell {cell.arch}×{cell.shape}×"
                      f"{cell.mesh_name} done")
        vol.write(f"exit/{idx}", 0)
        _ship_log(platform, job_id, idx,
                  f"[{sim.now:.2f}] sweep complete ({len(cells)} cells)")
        return 0

    return proc
