"""Serve- and dryrun-kind workload pods (Job API v2 kinds beyond training).

Server pods model inference replicas: each drains requests from the job's
shared queue and heartbeats through the shared NFS volume — the same
contract learners use, so the Guardian's generic gang monitor covers every
kind.  Dispatch is **payload-agnostic**: the framework adapter's
``payload`` hook decides whether a pod runs the virtual-time loop (the
default — fast tests) or drives real compute:

* **serve + RealServePayload** — the pod runs the actual
  :class:`repro.launch.engine.ServingEngine` (paged cache, continuous
  batching, optimistic admission).  The replica gang shares one claim
  counter on the volume (claim-then-serve: the claim is atomic, so R
  replicas serve EXACTLY ``requests`` requests); every claim is journaled,
  engine snapshots land on the volume every ``serve.snapshot_every`` decode
  steps, and completed responses ship to the job's COS prefix.  A killed
  pod restarts, rebuilds the model from the job seed (pure function),
  restores the last snapshot and replays the journal suffix — greedy
  decode is deterministic, so the recovered token streams are
  byte-identical to an uninterrupted run and every request completes
  exactly once across the gang.
* **dryrun + RealDryRunPayload** — the pod lowers + compiles the sweep
  cells for real, publishing genuine compile artifacts (memory/cost/
  collectives) to COS.  Cell completion markers on the volume keep the
  sweep resumable across restarts, as in the virtual path.

Both pod types run customer code and are therefore labelled with restricted
``NetworkPolicy`` roles: they may only touch their own volume and their own
job's object-store prefix (where they ship their logs through
``ObjectStore.append`` — O(line) per shipment, keeping ``ApiClient.logs``
uniform across kinds).
"""
from __future__ import annotations

import json

from repro.core.jobspec import JobSpec, resolve_cells

LOG_SHIP_EVERY = 10              # requests between log shipments


def _ship_log(platform, job_id: str, idx: int, line: str) -> None:
    """Append one line to the job's COS log key (own-prefix write — the
    only object-store path NetworkPolicy allows a workload pod)."""
    platform.objectstore.append(f"cos/{job_id}/logs/{idx}",
                                line.encode() + b"\n")


def make_server_proc(platform, job_id: str, spec: JobSpec, idx: int):
    """Container process for server replica ``idx`` of a serve-kind job."""

    def proc(pod):
        sim = platform.sim
        vol = platform.volumes.get(f"vol-{job_id}")
        if vol is None:
            raise RuntimeError("volume not mounted")
        payload = platform.frameworks.get(spec.framework).payload(
            platform, job_id, spec)
        if payload is not None:
            yield from _real_server_loop(platform, job_id, spec, idx, vol,
                                         payload)
            return 0
        sv = spec.serve
        _ship_log(platform, job_id, idx,
                  f"[{sim.now:.2f}] server {idx} up "
                  f"(framework {spec.framework})")
        while True:
            # claim-then-serve: the claim is atomic (no yield between read
            # and write), so a gang of R replicas serves EXACTLY
            # ``requests`` — no stale-read overshoot of up to R-1
            claimed = vol.read("claimed", 0)
            if sv.requests and claimed >= sv.requests:
                break                         # queue drained by the gang
            vol.write("claimed", claimed + 1)
            yield sv.request_time_s           # process one request
            served = vol.read("served", 0) + 1
            vol.write("served", served)
            vol.write(f"progress/{idx}", {"served": served, "t": sim.now})
            if served % LOG_SHIP_EVERY == 0:
                _ship_log(platform, job_id, idx,
                          f"[{sim.now:.2f}] served {served}")
        vol.write(f"exit/{idx}", 0)
        _ship_log(platform, job_id, idx,
                  f"[{sim.now:.2f}] server {idx} done "
                  f"({vol.read('served', 0)} served)")
        return 0

    return proc


def _real_server_loop(platform, job_id: str, spec: JobSpec, idx: int, vol,
                      payload):
    """Drive the real serving engine under the platform's recovery
    contract: claim-then-serve from the shared volume counter, journal
    every claim, snapshot the engine periodically, ship each completed
    response to COS exactly once."""
    sim = platform.sim
    sv = spec.serve
    skey = f"engine/{idx}/snapshot"
    jkey = f"engine/{idx}/journal"

    engine, requests = payload.build()      # fresh params from the job seed
    snap = vol.read(skey)
    journal = vol.read(jkey, [])
    replay_from = 0
    if snap is not None:
        engine.restore(snap)
        replay_from = snap["vol_journal_len"]
    # journal replay: claims made after the last snapshot are not in the
    # restored queue/slots — resubmit them (order preserved, dedup against
    # everything the snapshot already carries)
    have = (set(engine.responses)
            | {r.request.req for r in engine.active_records()}
            | {r.req for r in engine.queue})
    for ev in journal[replay_from:]:
        if ev["ev"] == "claim" and ev["req"] not in have:
            engine.submit(requests[ev["req"]])
            have.add(ev["req"])
    _ship_log(platform, job_id, idx,
              f"[{sim.now:.2f}] server {idx} up (framework "
              f"{spec.framework}, engine "
              f"{'restored' if snap is not None else 'fresh'})")

    n_req = sv.requests
    # one decode step generates one token per active slot; price a request
    # at ~request_time_s of virtual time spread over its gen tokens
    tick = sv.request_time_s / max(sv.gen, 1)
    steps_since_snap = 0
    shipped = set()                          # ids this incarnation shipped

    def ship_completed():
        """Drain every not-yet-shipped completed response to COS —
        completions happen in admit() too (gen_len == 1 finishes at
        prefill), so drain the response log, not step()'s return."""
        if len(engine.responses) == len(shipped):
            return                       # O(1): nothing new finished
        for r in sorted(set(engine.responses) - shipped):
            body = json.dumps({"req": r, "tokens": engine.responses[r]},
                              sort_keys=True).encode()
            key = f"cos/{job_id}/responses/{r}"
            if platform.objectstore.exists(key):
                # deterministic re-execution after restore: the recovered
                # stream must be byte-identical to what the dead
                # incarnation shipped (exactly-once, nothing re-served)
                assert platform.objectstore.get(key) == body, \
                    f"response divergence on replay: request {r}"
            else:
                # not a read-modify-write: the get() above only *verifies*
                # an already-shipped response on replay; put() runs on the
                # disjoint not-yet-shipped branch and writes fresh bytes
                platform.objectstore.put(key, body)  # staticcheck: ignore[SC103]
                served = vol.read("served", 0) + 1
                vol.write("served", served)
                if served % LOG_SHIP_EVERY == 0:
                    _ship_log(platform, job_id, idx,
                              f"[{sim.now:.2f}] served {served}")
            shipped.add(r)

    while True:
        # claim one request per free slot (atomic: no yield in the loop)
        while len(engine.queue) < engine.free_slot_count():
            claimed = vol.read("claimed", 0)
            if claimed >= n_req:
                break
            vol.write("claimed", claimed + 1)
            vol.append(jkey, {"ev": "claim", "req": claimed})
            engine.submit(requests[claimed])
        engine.admit()
        if engine.idle:
            ship_completed()                 # gen_len==1 round completions
            if vol.read("claimed", 0) >= n_req:
                break                        # gang drained the queue
            yield tick
            continue
        engine.step()
        ship_completed()
        vol.write(f"progress/{idx}",
                  {"served": vol.read("served", 0), "t": sim.now})
        steps_since_snap += 1
        if steps_since_snap >= sv.snapshot_every:
            snap_doc = engine.snapshot()
            snap_doc["vol_journal_len"] = len(vol.read(jkey, []))
            vol.write(skey, snap_doc)
            steps_since_snap = 0
        yield tick

    vol.write(f"exit/{idx}", 0)
    _ship_log(platform, job_id, idx,
              f"[{sim.now:.2f}] server {idx} done "
              f"({vol.read('served', 0)} served, "
              f"{engine.decode_steps} decode steps, "
              f"{engine.evictions} evictions)")


def make_dryrun_proc(platform, job_id: str, spec: JobSpec, idx: int):
    """Container process for a dryrun-kind job: walk the sweep cells,
    publishing one artifact per cell to the job's COS prefix.  With a real
    payload the cells are lowered + compiled for real; cell completion
    markers live on the volume either way, so a restarted runner resumes
    the sweep instead of recompiling finished cells."""

    def proc(pod):
        sim = platform.sim
        vol = platform.volumes.get(f"vol-{job_id}")
        if vol is None:
            raise RuntimeError("volume not mounted")
        dr = spec.dryrun
        payload = platform.frameworks.get(spec.framework).payload(
            platform, job_id, spec)
        cells = resolve_cells(dr)
        for ci, cell in enumerate(cells):
            if vol.read(f"cell/{ci}") is not None and not dr.force:
                continue                      # resumable sweep
            if payload is None:
                yield dr.cell_time_s          # virtual lower + compile
                rec = {"ok": True}
            else:
                rec = dict(payload.run_cell(cell))   # real lower + compile
                yield 0.01                    # publish tick (work was real)
            rec.update(arch=cell.arch, shape=cell.shape,
                       mesh=cell.mesh_name, job=job_id)
            rec.setdefault("ok", True)
            key = (f"cos/{job_id}/dryrun/"
                   f"{cell.arch}__{cell.shape}__{cell.mesh_name}.json")
            platform.objectstore.put(key, json.dumps(rec).encode())
            vol.write(f"cell/{ci}", key)
            vol.write(f"progress/{idx}", {"cells": ci + 1, "t": sim.now})
            _ship_log(platform, job_id, idx,
                      f"[{sim.now:.2f}] cell {cell.arch}×{cell.shape}×"
                      f"{cell.mesh_name} done")
        vol.write(f"exit/{idx}", 0)
        _ship_log(platform, job_id, idx,
                  f"[{sim.now:.2f}] sweep complete ({len(cells)} cells)")
        return 0

    return proc
