"""Cloud object store analog (checkpoints, results, logs).

Content integrity is first-class: every blob carries its sha256; manifests
are published atomically (a checkpoint either has a complete valid manifest
or does not exist).  ``corrupt()`` flips bytes for the corruption-detection
tests — a restored learner must reject a damaged checkpoint and fall back
to the previous one.
"""
from __future__ import annotations

import hashlib
import json
from typing import Dict, List, Optional


class ObjectStore:
    def __init__(self):
        self._blobs: Dict[str, bytes] = {}
        self.alive = True
        self.put_count = 0
        self.bytes_written = 0

    def _check(self):
        if not self.alive:
            raise ConnectionError("object store unavailable")

    # -- raw blobs --------------------------------------------------------
    def put(self, path: str, data: bytes) -> str:
        self._check()
        digest = hashlib.sha256(data).hexdigest()
        self._blobs[path] = data
        self.put_count += 1
        self.bytes_written += len(data)
        return digest

    def append(self, path: str, data: bytes) -> None:
        """Append to a blob without rewriting it (the log-shipping path).
        Costs O(len(data)) per call — the blob grows in place (bytearray),
        so shipping n log lines writes O(total) bytes, not O(n²) as the
        old read-modify-write ``get`` + ``put`` per line did."""
        self._check()
        buf = self._blobs.get(path)
        if not isinstance(buf, bytearray):
            buf = bytearray(buf if buf is not None else b"")
            self._blobs[path] = buf
        buf += data
        self.put_count += 1
        self.bytes_written += len(data)

    def get(self, path: str) -> bytes:
        self._check()
        raw = self._blobs[path]
        # only append()-grown blobs are bytearray-backed; don't tax every
        # read (checkpoint shards are large) with a defensive copy
        return bytes(raw) if isinstance(raw, bytearray) else raw

    def exists(self, path: str) -> bool:
        return path in self._blobs

    def delete_prefix(self, prefix: str) -> int:
        self._check()
        doomed = [k for k in self._blobs if k.startswith(prefix)]
        for k in doomed:
            del self._blobs[k]
        return len(doomed)

    def list_prefix(self, prefix: str) -> List[str]:
        self._check()
        return sorted(k for k in self._blobs if k.startswith(prefix))

    # -- integrity-checked documents ---------------------------------------
    def put_json_atomic(self, path: str, obj: dict) -> None:
        """Manifest publish: serialize + checksum + single-key insert (the
        atomicity unit).  Readers see old manifest or new, never torn."""
        body = json.dumps(obj, sort_keys=True).encode()
        digest = hashlib.sha256(body).hexdigest()
        self._check()
        self._blobs[path] = json.dumps(
            {"sha256": digest, "body": obj}, sort_keys=True).encode()
        self.put_count += 1
        self.bytes_written += len(body)

    def get_json_verified(self, path: str) -> Optional[dict]:
        """Returns the manifest body, or None if missing/corrupt."""
        self._check()
        raw = self._blobs.get(path)
        if raw is None:
            return None
        try:
            wrapper = json.loads(raw.decode())
            body = wrapper["body"]
            digest = hashlib.sha256(
                json.dumps(body, sort_keys=True).encode()).hexdigest()
            if digest != wrapper["sha256"]:
                return None
            return body
        except (ValueError, KeyError, TypeError, UnicodeDecodeError):
            # the corruption modes of a torn/garbage manifest: bad UTF-8,
            # bad JSON (ValueError), missing wrapper keys, non-dict wrapper
            return None

    def verify(self, path: str, sha256: str) -> bool:
        raw = self._blobs.get(path)
        return raw is not None and hashlib.sha256(raw).hexdigest() == sha256

    # -- fault injection -----------------------------------------------------
    def corrupt(self, path: str, byte_index: int = 0) -> None:
        raw = bytearray(self._blobs[path])
        raw[byte_index % len(raw)] ^= 0xFF
        self._blobs[path] = bytes(raw)
