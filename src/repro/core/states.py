"""Declared lifecycle state machines for jobs and pods.

This module is the single source of truth for platform lifecycle
vocabulary and legal transitions.  Runtime components (LCM, Guardian,
cluster, helper) route every state write through the helpers below, and
``repro.staticcheck``'s SC301 checker independently model-checks the
declared graphs (reachability, terminal absorption, settlement) and
verifies that no component writes state by hand — the same
declared-artifact seam as ``kernels/layout.py``.

Graph notes:

* ``(None, X)`` edges mark entry points (the API inserts jobs at the
  job machine's initial state; pods are born PENDING).
* ``PROCESSING -> DEPLOYING`` is the restart back-edge: a Guardian
  incarnation that finds a half-deployed or crashed predecessor rolls
  the job back to DEPLOYING before redeploying.
* Same-state re-assertion (``X -> X``) is deliberately NOT a table
  edge; terminal states stay absorbing in the declared graph.  The
  ``job_transition`` helper still tolerates it at runtime, because a
  retry after a partially-committed write (update landed, event append
  hit ``Unavailable``) legitimately re-asserts the state it already
  wrote.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple


class InvalidTransition(ValueError):
    """An undeclared lifecycle transition was attempted.

    Subclasses ValueError so in-pod failures keep the platform's error
    contract (pods fail their own job; they never exit the simulator).
    """


@dataclass(frozen=True)
class StateMachine:
    name: str
    initial: str
    # (from_state, to_state); from_state None marks an entry point.
    transitions: Tuple[Tuple[Optional[str], str], ...]
    terminal: Tuple[str, ...]
    states: frozenset = field(init=False)

    def __post_init__(self) -> None:
        states = {t for _, t in self.transitions}
        states |= {f for f, _ in self.transitions if f is not None}
        object.__setattr__(self, "states", frozenset(states))

    def allowed(self, cur: Optional[str], new: str) -> bool:
        if cur == new and new in self.states:
            return True  # idempotent re-assertion (retry/race tolerance)
        return (cur, new) in self.transitions

    def check(self, cur: Optional[str], new: str) -> None:
        if not self.allowed(cur, new):
            edges = sorted(self.transitions, key=lambda e: (e[0] or "", e[1]))
            raise InvalidTransition(
                f"{self.name}: illegal transition {cur!r} -> {new!r} "
                f"(declared edges: {edges})"
            )


JOB = StateMachine(
    name="job",
    initial="SUBMITTED",
    transitions=(
        (None, "SUBMITTED"),          # API gateway inserts the job doc
        ("SUBMITTED", "DEPLOYING"),   # LCM creates the guardian
        ("SUBMITTED", "FAILED"),      # guardian exhausted before first write
        ("DEPLOYING", "PROCESSING"),  # deploy finished, monitors take over
        ("DEPLOYING", "FAILED"),      # restart budget exhausted mid-deploy
        ("PROCESSING", "DEPLOYING"),  # restart back-edge (guardian redeploy)
        ("PROCESSING", "COMPLETED"),
        ("PROCESSING", "FAILED"),
        ("PROCESSING", "HALTED"),
    ),
    terminal=("COMPLETED", "FAILED", "HALTED"),
)

POD = StateMachine(
    name="pod",
    initial="PENDING",
    transitions=(
        (None, "PENDING"),
        ("PENDING", "RUNNING"),
        ("PENDING", "FAILED"),        # node died / pod deleted before start
        ("RUNNING", "SUCCEEDED"),
        ("RUNNING", "FAILED"),
    ),
    terminal=("SUCCEEDED", "FAILED"),
)

# Failure-classification vocabulary (self-healing Guardian).  The
# FailureClassifier (core/failures.py) may only emit these categories;
# ``journal_failure`` validates reports the same way ``job_transition``
# validates states, so a typo'd category can never reach the journal.
FAILURE_CATEGORIES = (
    "OOM",              # learner memory/page budget exceeded (exit 137)
    "CKPT_CORRUPT",     # newest checkpoint generation fails integrity
    "FLAKY_POD",        # one-shot pod crash, no deeper signature
    "POISONED_NODE",    # co-occurring pod deaths on one live node
    "STRAGGLER",        # alive but lagging the gang (gray failure)
    "UNKNOWN",          # unrecognized evidence — never auto-repaired
)


def journal_failure(
    metadata: Any,
    now: float,
    job_id: str,
    report: Dict[str, Any],
) -> None:
    """Journal a validated FailureReport doc as a job event.

    The event carries no ``state`` key — classification never moves the
    lifecycle machine by itself; repairs and budget exhaustion go through
    ``job_transition`` like every other write.
    """
    category = report.get("category")
    if category not in FAILURE_CATEGORIES:
        raise InvalidTransition(
            f"failure: unknown category {category!r} "
            f"(vocabulary: {list(FAILURE_CATEGORIES)})"
        )
    confidence = float(report.get("confidence", 0.0))
    if not 0.0 <= confidence <= 1.0:
        raise InvalidTransition(
            f"failure: confidence {confidence!r} outside [0, 1]"
        )
    metadata.append_event(
        "jobs", job_id,
        {"t": now,
         "event": f"FAILURE {category} "
                  f"(confidence {confidence:.2f}, pod {report.get('pod')})",
         "failure": dict(report)},
    )


# Learner status vocabulary as reported by the helper controller.
# UNKNOWN is synthetic: the aggregator's placeholder for a learner with
# no status doc yet.
LEARNER_STATES = frozenset(
    {"STARTING", "RUNNING", "UNREACHABLE", "SUCCEEDED", "FAILED"}
)
UNKNOWN = "UNKNOWN"

# Aggregation priority, worst first: any FAILED learner fails the gang
# before an UNREACHABLE one marks it degraded, and only an all-SUCCEEDED
# gang reads SUCCEEDED.
LEARNER_PRIORITY = (
    "FAILED", "UNREACHABLE", "STARTING", UNKNOWN, "RUNNING", "SUCCEEDED",
)


def job_transition(
    metadata: Any,
    now: float,
    job_id: str,
    state: str,
    fields: Optional[Dict[str, Any]] = None,
    event: Optional[str] = None,
) -> None:
    """Validated job state write: get -> check -> update -> journal.

    Raises InvalidTransition on an undeclared edge, and propagates the
    metadata store's own errors (Unavailable, KeyError) so callers keep
    their retry semantics.  Not atomic: a crash between update and
    append_event loses the event but never the state, and the
    idempotent-same-state rule makes the retry safe.
    """
    doc = metadata.get("jobs", job_id)
    cur = (doc or {}).get("state")
    JOB.check(cur, state)
    payload = dict(fields) if fields else {}
    payload["state"] = state
    metadata.update("jobs", job_id, payload)
    metadata.append_event(
        "jobs", job_id,
        {"t": now, "event": event or state, "from": cur, "to": state},
    )


def learner_status(state: str, **fields: Any) -> Dict[str, Any]:
    """Build a learner status doc, validating the state vocabulary."""
    if state not in LEARNER_STATES:
        raise InvalidTransition(
            f"learner: unknown status {state!r} "
            f"(vocabulary: {sorted(LEARNER_STATES)})"
        )
    doc: Dict[str, Any] = {"state": state}
    doc.update(fields)
    return doc


def pod_transition(pod: Any, status: str) -> None:
    """Validated pod status write — the only place pod.status is set."""
    POD.check(getattr(pod, "status", None), status)
    pod.status = status


def render_mermaid(machine: StateMachine) -> str:
    """Render a machine as a mermaid stateDiagram-v2 (for the README)."""
    lines = ["stateDiagram-v2"]
    for cur, new in machine.transitions:
        if cur is None:
            lines.append(f"    [*] --> {new}")
        else:
            lines.append(f"    {cur} --> {new}")
    for t in machine.terminal:
        lines.append(f"    {t} --> [*]")
    return "\n".join(lines)
