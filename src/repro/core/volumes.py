"""Shared NFS volume analog (paper §III-e).

One volume per job, mounted by both the learner pods and the helper pod.
Learners redirect exit status and progress into files; the isolated
controller detects completion/failure by reading them — the volume state
survives crashes of *either* side.
"""
from __future__ import annotations

from typing import Any, Dict, Optional


class Volume:
    def __init__(self, name: str):
        self.name = name
        self.files: Dict[str, Any] = {}

    def write(self, path: str, data: Any) -> None:
        self.files[path] = data

    def append(self, path: str, line: str) -> None:
        self.files.setdefault(path, [])
        self.files[path].append(line)

    def read(self, path: str, default: Any = None) -> Any:
        return self.files.get(path, default)

    def ls(self, prefix: str = ""):
        return sorted(k for k in self.files if k.startswith(prefix))


class VolumeManager:
    def __init__(self):
        self._vols: Dict[str, Volume] = {}

    def provision(self, name: str) -> Volume:
        if name not in self._vols:
            self._vols[name] = Volume(name)
        return self._vols[name]

    def get(self, name: str) -> Optional[Volume]:
        return self._vols.get(name)

    def release(self, name: str) -> bool:
        return self._vols.pop(name, None) is not None

    def active(self):
        return sorted(self._vols)
