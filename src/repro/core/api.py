"""API service: submission, status, logs, halt (paper §III-c).

Runs as a multi-replica Deployment behind the ``dlaas-api`` service name —
requests fail over to a live replica.  The dependability contract: a job is
acked **only after** its metadata is durably in Mongo, so acked jobs are
never lost, even if every other component crashes immediately after.
The LCM discovers SUBMITTED jobs from Mongo (reconciliation), so the
API→LCM handoff itself carries no state that can be lost.
"""
from __future__ import annotations

import itertools
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional

from repro.core.cluster import RpcError
from repro.core.manifest import JobManifest
from repro.core.metadata import Unavailable

_job_counter = itertools.count(1)


@dataclass
class SubmitHandle:
    manifest: JobManifest
    job_id: Optional[str] = None
    acked: bool = False
    rejected: Optional[str] = None


def make_api_proc(platform):
    """API pod main loop: serves queued requests (submissions)."""

    def proc(pod):
        q = platform.api_queue
        while True:
            if not q:
                yield 0.05
                continue
            handle = q.pop(0)
            err = handle.manifest.validate()
            if err:
                handle.rejected = err
                continue
            if handle.manifest.tenant not in platform.tenancy.tenants:
                handle.rejected = f"unknown tenant {handle.manifest.tenant}"
                continue
            job_id = f"job-{next(_job_counter):04d}"
            doc = {"id": job_id, "manifest": asdict(handle.manifest),
                   "state": "SUBMITTED", "desired_state": "RUNNING",
                   "restarts": 0,
                   "events": [{"t": platform.sim.now, "event": "SUBMITTED"}]}
            # persist BEFORE ack (jobs are never lost once acked)
            while True:
                try:
                    platform.metadata.insert("jobs", job_id, doc)
                    break
                except Unavailable:
                    yield 0.5
            handle.job_id = job_id
            handle.acked = True
            platform.sim.log(f"api: acked {job_id}")

    return proc


class ApiClient:
    """User-facing client: resolves a live API pod per call (load-balanced,
    fails over); raises RpcError when the API service is fully down."""

    def __init__(self, platform):
        self.platform = platform

    def _endpoint(self):
        return self.platform.cluster.rpc("dlaas-api")    # RpcError if down

    def submit(self, manifest: JobManifest) -> SubmitHandle:
        self._endpoint()
        h = SubmitHandle(manifest)
        self.platform.api_queue.append(h)
        return h

    def status(self, job_id: str) -> Dict[str, Any]:
        self._endpoint()
        doc = self.platform.metadata.get("jobs", job_id)
        if doc is None:
            raise KeyError(job_id)
        return {"id": doc["id"], "state": doc["state"],
                "restarts": doc.get("restarts", 0),
                "learner_states": doc.get("learner_states")}

    def events(self, job_id: str) -> List[dict]:
        self._endpoint()
        doc = self.platform.metadata.get("jobs", job_id)
        return list(doc.get("events", [])) if doc else []

    def logs(self, job_id: str, learner: int = 0) -> str:
        """Logs stream from the object store — readable even after crashes."""
        self._endpoint()
        key = f"cos/{job_id}/logs/{learner}"
        if not self.platform.objectstore.exists(key):
            return ""
        return self.platform.objectstore.get(key).decode()

    def halt(self, job_id: str) -> None:
        self._endpoint()
        self.platform.metadata.update("jobs", job_id,
                                      {"desired_state": "HALTED"})

    def gpu_seconds(self, tenant: str) -> float:
        self._endpoint()
        return self.platform.tenancy.metering.gpu_seconds(tenant)
