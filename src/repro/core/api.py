"""API gateway: submission, list, status, logs, halt, delete (paper §III-c).

Runs as a multi-replica Deployment behind the ``dlaas-api`` service name —
requests fail over to a live replica.  Job API v2 semantics:

* **Durable ack** — a job is acked **only after** its document is durably
  in Mongo, so acked jobs are never lost, even if every other component
  crashes immediately after.  The LCM discovers SUBMITTED jobs from Mongo
  (reconciliation), so the API→LCM handoff carries no state that can be
  lost.
* **Idempotent submission** — every submission carries a client-supplied
  ``request_id``; the job document records it.  Resubmitting after an ack
  was lost to an API-pod failover returns the SAME job, never a duplicate
  (the dedup index is the durable job collection itself, so it survives
  any number of API-pod deaths).
* **Metadata-backed id allocation** — job ids come from a durable counter
  in Mongo, so ids are unique per platform, survive API-pod restarts, and
  never bleed across ``DLaaSPlatform`` instances in one process.
* **Uniform verbs** — ``get/events/logs/halt/delete`` all raise
  :class:`JobNotFound` for unknown jobs (no more KeyError-vs-empty
  inconsistency), and ``list`` filters by tenant/state/kind with
  pagination.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple, Union

from repro.core import states
from repro.core.jobspec import JobSpec
from repro.core.manifest import JobManifest
from repro.core.metadata import Unavailable


class JobNotFound(KeyError):
    """No job with this id exists (uniform across every API verb)."""


class InvalidJobState(Exception):
    """The verb is not applicable in the job's current state."""


@dataclass
class SubmitHandle:
    spec: JobSpec
    request_id: str = ""
    job_id: Optional[str] = None
    acked: bool = False
    rejected: Optional[str] = None
    deduplicated: bool = False          # ack resolved by the request_id index


def _alloc_job_id(platform) -> str:
    """Allocate the next job id from the durable metadata counter.  The
    defensive existence probe keeps allocation collision-free even against
    job documents written by an older platform incarnation."""
    while True:
        n = platform.metadata.bump_counter("job-id")
        job_id = f"job-{n:04d}"
        if platform.metadata.get("jobs", job_id) is None:
            return job_id


def make_api_proc(platform):
    """API pod main loop: serves queued requests (submissions)."""

    def proc(pod):
        q = platform.api_queue
        while True:
            if not q:
                yield 0.05
                continue
            handle = q.pop(0)
            spec = handle.spec
            err = spec.validate(platform.frameworks)
            if err:
                handle.rejected = err
                continue
            if spec.tenant not in platform.tenancy.tenants:
                handle.rejected = f"unknown tenant {spec.tenant}"
                continue
            rid = handle.request_id
            while True:
                try:
                    # idempotency: the durable job collection IS the dedup
                    # index — a lost ack is recovered by resubmission.
                    # Scoped per tenant: request_ids are a client-chosen
                    # namespace, and tenant A reusing tenant B's id must
                    # never be handed B's job.
                    dup = platform.metadata.find(
                        "jobs", lambda d: rid
                        and d.get("request_id") == rid
                        and d.get("tenant") == spec.tenant)
                    if dup:
                        handle.job_id = dup[0]["id"]
                        handle.acked = True
                        handle.deduplicated = True
                        platform.sim.log(
                            f"api: dedup {rid} -> {handle.job_id}")
                        break
                    job_id = _alloc_job_id(platform)
                    doc = {"id": job_id, "request_id": rid,
                           "name": spec.name, "kind": spec.kind,
                           "tenant": spec.tenant, "spec": spec.to_doc(),
                           "state": states.JOB.initial,
                           "desired_state": "RUNNING",
                           "restarts": 0,
                           "events": [{"t": platform.sim.now,
                                       "event": states.JOB.initial}]}
                    # persist BEFORE ack (jobs are never lost once acked);
                    # the insert is the atomicity unit, so a crash between
                    # id allocation and insert only burns an id
                    platform.metadata.insert("jobs", job_id, doc)
                    handle.job_id = job_id
                    handle.acked = True
                    platform.sim.log(f"api: acked {job_id}")
                    break
                except Unavailable:
                    yield 0.5

    return proc


class ApiClient:
    """User-facing client: resolves a live API pod per call (load-balanced,
    fails over); raises RpcError when the API service is fully down."""

    def __init__(self, platform):
        self.platform = platform
        # auto request_ids draw from a per-PLATFORM counter: two client
        # instances must never generate the same id and silently dedup
        # each other's unrelated submissions
        self._auto_rid = platform.__dict__.setdefault(
            "_auto_rid_counter", itertools.count(1))

    def _endpoint(self):
        return self.platform.cluster.rpc("dlaas-api")    # RpcError if down

    def _doc(self, job_id: str) -> Dict[str, Any]:
        doc = self.platform.metadata.get("jobs", job_id)
        if doc is None:
            raise JobNotFound(job_id)
        return doc

    # -- submission --------------------------------------------------------
    def submit(self, spec: Union[JobSpec, JobManifest],
               request_id: Optional[str] = None) -> SubmitHandle:
        """Submit a job.  Pass the SAME ``request_id`` to resubmit after a
        lost ack — the platform returns the original job, never a
        duplicate.  v1 ``JobManifest`` is accepted via the shim."""
        if isinstance(spec, JobManifest):
            spec = spec.to_jobspec()
        self._endpoint()
        if request_id is None:
            request_id = f"req-auto-{next(self._auto_rid):06d}"
        h = SubmitHandle(spec=spec, request_id=request_id)
        self.platform.api_queue.append(h)
        return h

    # -- read verbs --------------------------------------------------------
    def get(self, job_id: str) -> Dict[str, Any]:
        self._endpoint()
        doc = self._doc(job_id)
        return {"id": doc["id"], "name": doc.get("name"),
                "kind": doc.get("kind", "train"),
                "tenant": doc.get("tenant"),
                "state": doc["state"],
                "restarts": doc.get("restarts", 0),
                "failures_by_category": doc.get("failures_by_category", {}),
                "learner_states": doc.get("learner_states")}

    # v1 alias
    def status(self, job_id: str) -> Dict[str, Any]:
        return self.get(job_id)

    def list(self, tenant: Optional[str] = None, state: Optional[str] = None,
             kind: Optional[str] = None, limit: int = 50,
             page_token: Optional[str] = None
             ) -> Tuple[List[Dict[str, Any]], Optional[str]]:
        """Filtered listing, paginated by job id.  Returns
        ``(jobs, next_page_token)``; pass the token back to continue."""
        self._endpoint()
        if limit < 1:
            return [], None

        def pred(d):
            return ((tenant is None or d.get("tenant") == tenant)
                    and (state is None or d.get("state") == state)
                    and (kind is None or d.get("kind", "train") == kind))

        # length-first ordering keeps allocation order once ids outgrow
        # the zero padding ("job-10000" must sort after "job-9999")
        order = lambda jid: (len(jid), jid)
        docs = sorted(self.platform.metadata.find("jobs", pred),
                      key=lambda d: order(d["id"]))
        if page_token is not None:
            docs = [d for d in docs if order(d["id"]) > order(page_token)]
        page, rest = docs[:limit], docs[limit:]
        items = [{"id": d["id"], "name": d.get("name"),
                  "kind": d.get("kind", "train"),
                  "tenant": d.get("tenant"), "state": d["state"],
                  "restarts": d.get("restarts", 0)} for d in page]
        next_token = page[-1]["id"] if rest else None
        return items, next_token

    def events(self, job_id: str) -> List[dict]:
        self._endpoint()
        return list(self._doc(job_id).get("events", []))

    def logs(self, job_id: str, learner: int = 0) -> str:
        """Logs stream from the object store — readable even after crashes.
        Empty string means the job exists but shipped nothing yet."""
        self._endpoint()
        self._doc(job_id)
        key = f"cos/{job_id}/logs/{learner}"
        if not self.platform.objectstore.exists(key):
            return ""
        return self.platform.objectstore.get(key).decode()

    # -- write verbs -------------------------------------------------------
    def halt(self, job_id: str) -> None:
        self._endpoint()
        self._doc(job_id)
        self.platform.metadata.update("jobs", job_id,
                                      {"desired_state": "HALTED"})

    def delete(self, job_id: str) -> None:
        """Remove a TERMINAL job's document (its COS artifacts remain —
        results may outlive the job resource)."""
        self._endpoint()
        doc = self._doc(job_id)
        if doc["state"] not in ("COMPLETED", "FAILED", "HALTED"):
            raise InvalidJobState(
                f"cannot delete {job_id} in state {doc['state']}; halt first")
        self.platform.metadata.delete("jobs", job_id)

    # -- metering ----------------------------------------------------------
    def gpu_seconds(self, tenant: str) -> float:
        self._endpoint()
        return self.platform.tenancy.metering.gpu_seconds(
            tenant, now=self.platform.sim.now)
