"""JobSpec v2 — the versioned, multi-kind job resource model (paper §III-a).

The paper's platform fronts every workload with ONE declarative manifest
submitted to a multi-tenant service; "multi-framework" means heterogeneous
workloads ride the same submission path (FfDL does this in production with
one manifest schema + framework plugins behind a single gateway).  This
module is that resource model for our platform:

* ``JobSpec`` — the versioned envelope (``api_version``, ``kind``, tenant,
  framework id, gang resources, restart policy) with exactly one per-kind
  spec block: ``TrainSpec`` | ``ServeSpec`` | ``DryRunSpec``.  The blocks
  carry the knobs that used to live in three disconnected argparse CLIs
  (arch/mesh/steps/batch/seq, cache layout, continuous batching, sweep
  cells), so every workload kind is schedulable and meterable.
* ``FrameworkAdapter`` / ``FrameworkRegistry`` — pluggable mapping from a
  ``framework`` id to payload builders (validate → resources → workload
  pod procs), replacing the implicit "framework is an architecture string"
  convention.  The default registry wraps the architecture registry
  (``repro.configs``): every registered arch is a framework, the way DLaaS
  treats Caffe/TF/Torch as opaque learner payloads.

``JobManifest`` (v1) remains as a deprecated shim that converts to a
``JobSpec`` via :meth:`repro.core.manifest.JobManifest.to_jobspec`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

API_VERSION = "dlaas/v2"
KINDS = ("train", "serve", "dryrun")


# ---------------------------------------------------------------------------
# Per-kind spec blocks
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Resources:
    """Gang resources: how many workload pods, how many GPUs each."""

    replicas: int = 1
    gpus_per_replica: int = 1


@dataclass(frozen=True)
class TrainSpec:
    """Training knobs — the union of the old CLI flags and JobManifest."""

    total_steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    learning_rate: float = 1e-3
    num_microbatches: int = 1
    remat_policy: str = "none"                # none | dots | full
    mesh: str = "host"                        # host | prod | multipod
    use_pallas: bool = False
    reduced: bool = True
    log_every: int = 10
    # platform-sim knobs (virtual learners)
    step_time_s: float = 0.5
    checkpoint_interval_s: float = 30.0       # user-configured (paper §III-g)
    data_source: str = "cos://datasets/synthetic"
    dataset_gb: float = 1.0
    result_location: str = "cos://results"
    real_compute: bool = False                # run actual JAX steps
    recovery_mode: str = "checkpoint"         # checkpoint | rejoin (§III-h)
    # self-healing Guardian knobs (failure classification + safe repair).
    # restart_budgets charges restarts per failure category (keys from
    # states.FAILURE_CATEGORIES); categories without an entry fall back to
    # the envelope's max_restarts, so one pathology cannot exhaust
    # another's budget.
    restart_budgets: Dict[str, int] = field(default_factory=dict)
    repair_policy: str = "auto"               # auto | restart-only
    min_repair_confidence: float = 0.6        # below this: plain restart
    # formerly hard-coded Guardian monitor thresholds
    pending_stuck_s: float = 25.0             # elastic shrink trigger
    helper_drain_s: float = 60.0              # helper log/results drain


@dataclass(frozen=True)
class ServeSpec:
    """Serving knobs: batched prefill + decode, dense or paged KV cache."""

    batch: int = 4                            # concurrent decode slots
    prompt_len: int = 64
    gen: int = 32
    mesh: str = "host"
    reduced: bool = True
    cache_layout: Optional[str] = None        # None = the config's default
    page_size: int = 0                        # 0 = config default
    continuous: bool = False                  # continuous batching (paged)
    requests: int = 8                         # 0 = serve until halted
    page_budget: int = 0                      # 0 = worst case
    use_pallas: bool = False                  # paged flash-decode kernel
    ragged_prefill: Optional[bool] = None     # None = auto (attn-only archs)
    # optimistic admission: reserve worst-case pages up to overcommit ×
    # budget; on page exhaustion the engine evicts the youngest sequence
    # back to the queue (1.0 = conservative, never evicts)
    overcommit: float = 1.0
    # hash-addressed prefix caching + copy-on-write pages: full prompt
    # pages are content-hashed against a refcounted index; hits attach
    # read-only (no prefill compute, no new residency).  Auto-disabled on
    # configs without the chunked-prefill seam (non-all-global stacks)
    prefix_cache: bool = True
    # synthetic-workload knob: fraction of prompt_len every request shares
    # as a common leading prefix (0 = fully independent prompts)
    shared_prefix_frac: float = 0.0
    # platform-sim knob (virtual servers)
    request_time_s: float = 0.2
    # platform real-payload knobs: run the actual ServingEngine inside the
    # server pods (journal + snapshots on the job volume) instead of the
    # virtual-time loop
    real_compute: bool = False
    snapshot_every: int = 8                   # decode steps between snapshots


@dataclass(frozen=True)
class SweepCell:
    """One dry-run cell: lower + compile (arch × shape × mesh)."""

    arch: str
    shape: str
    multi_pod: bool = False

    @property
    def mesh_name(self) -> str:
        return "2x16x16" if self.multi_pod else "16x16"


@dataclass(frozen=True)
class DryRunSpec:
    """Compile-sweep knobs (the roofline evidence generator)."""

    cells: Tuple[SweepCell, ...] = ()
    sweep_all: bool = False                   # full (arch × shape × mesh) grid
    force: bool = False                       # recompute cached cells
    timeout_s: int = 3600                     # per-cell (local execution)
    # platform-sim knob: virtual lower+compile time per cell
    cell_time_s: float = 2.0
    # platform real-payload knob: lower + compile the cells for real
    real_compute: bool = False


def resolve_cells(dr: DryRunSpec) -> Tuple[SweepCell, ...]:
    """Expand ``sweep_all`` into the explicit cell grid (both meshes)."""
    if not dr.sweep_all:
        return tuple(dr.cells)
    from repro.configs import SHAPES, list_configs
    return tuple(SweepCell(arch, shape, mp)
                 for arch in list_configs() if arch != "paper-overhead-100m"
                 for shape in SHAPES for mp in (False, True))


# ---------------------------------------------------------------------------
# The envelope
# ---------------------------------------------------------------------------
_KIND_ROLE = {"train": "learner", "serve": "server", "dryrun": "dryrun"}


@dataclass(frozen=True)
class JobSpec:
    name: str
    kind: str = "train"
    api_version: str = API_VERSION
    tenant: str = "default"
    framework: str = "paper-overhead-100m"    # id in the FrameworkRegistry
    resources: Resources = field(default_factory=Resources)
    max_restarts: int = 3
    elastic: bool = False                     # allow DP shrink (train only)
    priority: int = 0
    seed: int = 0
    extras: Dict[str, str] = field(default_factory=dict)
    train: Optional[TrainSpec] = None
    serve: Optional[ServeSpec] = None
    dryrun: Optional[DryRunSpec] = None

    def __post_init__(self):
        # exactly one kind block is active; default-construct it if absent
        # so `JobSpec(name="j", kind="serve")` is valid shorthand
        if self.kind in KINDS and self.workload is None:
            block = {"train": TrainSpec, "serve": ServeSpec,
                     "dryrun": DryRunSpec}[self.kind]()
            object.__setattr__(self, self.kind, block)

    # -- kind block access -------------------------------------------------
    @property
    def workload(self):
        """The active per-kind spec block."""
        return getattr(self, self.kind, None) if self.kind in KINDS else None

    @property
    def role(self) -> str:
        """Pod role label for this kind's workload pods."""
        return _KIND_ROLE.get(self.kind, "worker")

    # -- v1 compatibility accessors (guardian/learner/helper paths) --------
    @property
    def learners(self) -> int:
        return self.resources.replicas

    @property
    def gpus_per_learner(self) -> int:
        return self.resources.gpus_per_replica

    @property
    def total_steps(self) -> int:
        return self.train.total_steps if self.train else 0

    @property
    def step_time_s(self) -> float:
        return self.train.step_time_s if self.train else 0.5

    @property
    def checkpoint_interval_s(self) -> float:
        return self.train.checkpoint_interval_s if self.train else 30.0

    @property
    def dataset_gb(self) -> float:
        return self.train.dataset_gb if self.train else 0.0

    @property
    def real_compute(self) -> bool:
        return bool(self.train and self.train.real_compute)

    @property
    def recovery_mode(self) -> str:
        if self.train is not None:
            return self.train.recovery_mode
        return self.extras.get("recovery_mode", "checkpoint")

    # -- validation ---------------------------------------------------------
    def validate(self, frameworks: Optional["FrameworkRegistry"] = None
                 ) -> Optional[str]:
        """Full submission-time validation; returns an error string or None.

        With a registry, unknown ``framework`` ids are rejected HERE — at
        the gateway — instead of being acked and failing deep inside the
        Guardian (ISSUE 3 satellite)."""
        if self.api_version != API_VERSION:
            return (f"unsupported api_version {self.api_version!r} "
                    f"(expected {API_VERSION!r})")
        if self.kind not in KINDS:
            return f"unknown kind {self.kind!r} (expected one of {KINDS})"
        if not self.name:
            return "name must be non-empty"
        if self.resources.replicas < 1:
            return "resources.replicas must be >= 1"
        if self.resources.gpus_per_replica < 0:
            return "resources.gpus_per_replica must be >= 0"
        if self.max_restarts < 0:
            return "max_restarts must be >= 0"
        if frameworks is not None and self.framework not in frameworks:
            return (f"unknown framework {self.framework!r}; "
                    f"known: {frameworks.known()}")
        for k in KINDS:
            if k != self.kind and getattr(self, k) is not None:
                return (f"kind={self.kind!r} but a {k!r} spec block is set "
                        f"(exactly one per-kind block; it must match kind)")
        err = self._validate_workload()
        if err:
            return err
        if frameworks is not None:
            return frameworks.get(self.framework).validate(self)
        return None

    def _validate_workload(self) -> Optional[str]:
        w = self.workload
        if w is None:
            return f"missing {self.kind!r} spec block"
        if self.kind == "train":
            if w.total_steps < 1:
                return "train.total_steps must be >= 1"
            if w.step_time_s <= 0:
                return "train.step_time_s must be > 0"
            if w.checkpoint_interval_s <= 0:
                return "train.checkpoint_interval_s must be > 0"
            if w.repair_policy not in ("auto", "restart-only"):
                return (f"train.repair_policy {w.repair_policy!r} must be "
                        f"'auto' or 'restart-only'")
            if not 0.0 <= w.min_repair_confidence <= 1.0:
                return "train.min_repair_confidence must be in [0, 1]"
            if w.pending_stuck_s <= 0:
                return "train.pending_stuck_s must be > 0"
            if w.helper_drain_s <= 0:
                return "train.helper_drain_s must be > 0"
            from repro.core.states import FAILURE_CATEGORIES
            for cat, budget in w.restart_budgets.items():
                if cat not in FAILURE_CATEGORIES:
                    return (f"train.restart_budgets: unknown category "
                            f"{cat!r}; known: {list(FAILURE_CATEGORIES)}")
                if budget < 0:
                    return (f"train.restart_budgets[{cat!r}] must be >= 0")
        elif self.kind == "serve":
            if w.batch < 1:
                return "serve.batch must be >= 1"
            if w.prompt_len < 1 or w.gen < 1:
                return "serve.prompt_len and serve.gen must be >= 1"
            if w.requests < 0:
                return "serve.requests must be >= 0 (0 = run until halted)"
            if w.request_time_s <= 0:
                return "serve.request_time_s must be > 0"
            if w.overcommit < 1.0:
                return "serve.overcommit must be >= 1.0"
            if not 0.0 <= w.shared_prefix_frac <= 1.0:
                return "serve.shared_prefix_frac must be in [0, 1]"
            if w.snapshot_every < 1:
                return "serve.snapshot_every must be >= 1"
            if w.real_compute and w.requests < 1:
                return "serve.real_compute needs a bounded request count"
        elif self.kind == "dryrun":
            if not w.sweep_all and not w.cells:
                return "dryrun needs cells or sweep_all=True"
            from repro.configs import SHAPES, list_configs
            known = set(list_configs())
            for c in w.cells:
                if c.arch not in known:
                    return f"dryrun cell: unknown arch {c.arch!r}"
                if c.shape not in SHAPES:
                    return (f"dryrun cell: unknown shape {c.shape!r}; "
                            f"known: {sorted(SHAPES)}")
        return None

    # -- serialization (the metadata store holds plain dicts) ---------------
    def to_doc(self) -> dict:
        return {
            "api_version": self.api_version, "kind": self.kind,
            "name": self.name, "tenant": self.tenant,
            "framework": self.framework,
            "resources": dataclasses.asdict(self.resources),
            "max_restarts": self.max_restarts, "elastic": self.elastic,
            "priority": self.priority, "seed": self.seed,
            "extras": dict(self.extras),
            "train": dataclasses.asdict(self.train) if self.train else None,
            "serve": dataclasses.asdict(self.serve) if self.serve else None,
            "dryrun": dataclasses.asdict(self.dryrun) if self.dryrun else None,
        }

    @classmethod
    def from_doc(cls, doc: dict) -> "JobSpec":
        d = dict(doc)
        d["resources"] = Resources(**d.get("resources") or {})
        for key, block in (("train", TrainSpec), ("serve", ServeSpec)):
            d[key] = block(**d[key]) if d.get(key) else None
        dr = d.get("dryrun")
        if dr:
            dr = dict(dr)
            dr["cells"] = tuple(SweepCell(**c) for c in dr.get("cells") or ())
            d["dryrun"] = DryRunSpec(**dr)
        else:
            d["dryrun"] = None
        return cls(**d)


def spec_from_job_doc(doc: dict) -> JobSpec:
    """Extract the JobSpec from a job document — v2 docs carry ``spec``;
    legacy v1 docs carry ``manifest`` and go through the shim, so jobs
    persisted before the redesign still reconcile after an upgrade."""
    if doc.get("spec") is not None:
        return JobSpec.from_doc(doc["spec"])
    from repro.core.manifest import JobManifest
    return JobManifest(**doc["manifest"]).to_jobspec()


# ---------------------------------------------------------------------------
# Framework adapters
# ---------------------------------------------------------------------------
class FrameworkAdapter:
    """Maps a ``framework`` id to its payload builders.

    The platform calls, in order: :meth:`validate` (at the API gateway),
    :meth:`gang` (at Guardian admission) and :meth:`workload_proc` (one
    call per workload pod); the workload pods call :meth:`payload` to
    obtain the *real* compute payload — or ``None`` for the virtual-time
    default.  LCM/Guardian never look inside any of these: dispatch is
    payload-agnostic, so plugging in a new framework (or a real payload
    for an existing kind) touches neither the gateway nor the Guardian."""

    def __init__(self, framework: str):
        self.framework = framework

    def validate(self, spec: JobSpec) -> Optional[str]:
        return None

    def gang(self, spec: JobSpec) -> Resources:
        return spec.resources

    def workload_proc(self, platform, job_id: str, spec: JobSpec, idx: int):
        raise NotImplementedError

    def payload(self, platform, job_id: str, spec: JobSpec):
        """Payload-builder hook: the real compute object a workload pod
        should drive, or ``None`` to run the virtual-time loop (the
        default — fast tests never touch JAX).  ``real_compute`` on the
        workload block is the virtual-vs-real switch (the pre-v2 learner
        contract); when it is set, the base implementation returns the
        payload registered via ``platform.register_payload`` — the
        external-trainer seam and the test-injection point — so EVERY
        adapter inherits registration without overriding."""
        if not getattr(spec.workload, "real_compute", False):
            return None
        return platform.payloads.get(job_id)


class ArchitectureAdapter(FrameworkAdapter):
    """Default adapter: the framework id is a registry architecture, the
    workload pods are the stock learner/server/dryrun container procs."""

    def validate(self, spec: JobSpec) -> Optional[str]:
        if spec.kind == "serve" and spec.serve.continuous:
            if spec.serve.cache_layout == "dense":
                return "serve.continuous requires the paged cache layout"
        if spec.kind == "serve" and spec.serve.real_compute:
            sv = spec.serve
            if sv.cache_layout == "dense":
                return "serve.real_compute runs the paged serving engine"
            from repro.configs import get_config
            from repro.configs.base import GLOBAL_ATTN, LOCAL_ATTN
            cfg = get_config(spec.framework)
            if cfg.use_mla or cfg.is_encoder_decoder:
                return ("serve.real_compute needs per-sequence decode "
                        "positions; MLA / enc-dec caches are lockstep-only")
            # reject engine-constructor failures HERE, at the gateway —
            # inside a pod they would burn the job's whole restart budget
            if sv.reduced:
                cfg = cfg.reduced()
            ps = sv.page_size or cfg.page_size
            pps = -(-(sv.prompt_len + sv.gen) // ps)
            if sv.page_budget and sv.page_budget < pps:
                return (f"serve.page_budget {sv.page_budget} cannot hold "
                        f"one request ({pps} pages)")
            attn_only = set(cfg.layer_kinds()) <= {GLOBAL_ATTN, LOCAL_ATTN}
            if sv.ragged_prefill and not attn_only:
                return ("serve.ragged_prefill needs an attention-only "
                        "decoder; recurrent/RWKV state would scan the "
                        "padding")
        return None

    def workload_proc(self, platform, job_id: str, spec: JobSpec, idx: int):
        if spec.kind == "train":
            from repro.core.learner import make_learner_proc
            return make_learner_proc(platform, job_id, spec, idx)
        from repro.core.server import make_dryrun_proc, make_server_proc
        if spec.kind == "serve":
            return make_server_proc(platform, job_id, spec, idx)
        return make_dryrun_proc(platform, job_id, spec, idx)

    def payload(self, platform, job_id: str, spec: JobSpec):
        """Real payloads, by kind: an explicitly registered payload wins
        (base behavior); serve and dryrun kinds otherwise build their
        stock real payloads when the spec asks for real compute.  Train
        has no default builder — real training state (step fn, data)
        must be registered."""
        registered = super().payload(platform, job_id, spec)
        if registered is not None:
            return registered
        if not getattr(spec.workload, "real_compute", False):
            return None
        if spec.kind == "serve":
            from repro.launch.engine import RealServePayload
            return RealServePayload(spec)
        if spec.kind == "dryrun":
            from repro.launch.engine import RealDryRunPayload
            return RealDryRunPayload(spec)
        return None


class FrameworkRegistry:
    def __init__(self):
        self._adapters: Dict[str, FrameworkAdapter] = {}

    def register(self, adapter: FrameworkAdapter) -> FrameworkAdapter:
        self._adapters[adapter.framework] = adapter
        return adapter

    def get(self, framework: str) -> FrameworkAdapter:
        if framework not in self._adapters:
            raise KeyError(f"unknown framework {framework!r}; "
                           f"known: {self.known()}")
        return self._adapters[framework]

    def __contains__(self, framework: str) -> bool:
        return framework in self._adapters

    def known(self) -> Tuple[str, ...]:
        return tuple(sorted(self._adapters))

    @classmethod
    def default(cls) -> "FrameworkRegistry":
        """One adapter per registered architecture (configs are pure
        dataclasses — importing them pulls in no accelerator deps)."""
        from repro.configs import list_configs
        reg = cls()
        for arch in list_configs():
            reg.register(ArchitectureAdapter(arch))
        return reg
