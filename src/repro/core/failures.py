"""Failure taxonomy: typed fault injection + evidence-based classification.

The paper's dependability story stops at "detect failure, restart within
budget"; FfDL (arXiv:1909.06526) and the IBM DLaaS paper (arXiv:1709.05871)
both diagnose failure *causes* before choosing a remedy.  This module is
that diagnose-then-repair layer for our platform, in three pieces:

* **FaultPlan / FaultInjector** — chaos injection as a first-class platform
  API.  A plan is a tuple of typed, timed faults (OOM, checkpoint
  corruption, flaky pod, poisoned node, slow-loss straggler, wedge); the
  injector schedules them on the sim's virtual clock (``Sim.at``), so a
  chaos scenario is scripted and replayable — never a hand-rolled
  ``kill_pod`` at an eyeballed time.
* **FailureClassifier** — turns pod exit evidence (exit detail, node
  co-occurrence from the cluster's tombstone history, checkpoint
  integrity, ETCD status docs, restart history) into a
  :class:`FailureReport` with a category from
  ``states.FAILURE_CATEGORIES`` and a confidence.
* **Repair registry** — the *safe list*: each category maps to exactly one
  registered repair action.  ``UNKNOWN`` is deliberately absent — an
  unrecognized or low-confidence failure gets a plain restart, never a
  guessed repair.  The Guardian applies the action and charges the restart
  to the category's own budget (``TrainSpec.restart_budgets``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.core.states import FAILURE_CATEGORIES

FAULT_KINDS = ("oom", "ckpt_corrupt", "flaky_pod", "poison_node",
               "straggler", "wedge")

#: exit-detail signature the OOM gate raises with (exit 137 = SIGKILL by
#: the kernel OOM killer — the signature real K8s surfaces)
OOM_SIGNATURE = "OOMKilled (exit 137)"


class InjectedOOM(RuntimeError):
    """Learner memory budget exceeded (injected).  RuntimeError so the pod
    fails its own job under the sim's sandbox (SC101)."""


# ---------------------------------------------------------------------------
# Fault plans
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Fault:
    """One typed, timed fault.

    ``at`` is absolute virtual time.  Gate kinds (``oom``, ``straggler``,
    ``wedge``) arm a condition the learner procs consult; trigger kinds
    (``flaky_pod``, ``ckpt_corrupt``, ``poison_node``) act on the cluster
    when their time arrives.
    """

    kind: str
    at: float = 0.0
    job: str = ""                 # job id the fault targets
    learner: int = 0              # learner/replica index
    pod: str = ""                 # explicit pod name (default learner-job-i)
    node: str = ""                # poison_node: explicit node (default: the
                                  # node hosting the target pod)
    at_step: int = 0              # oom/wedge: fire once step >= at_step
    clears_below: float = 0.5     # oom: gate clears once the repair has
                                  # lowered repair/mem_scale to <= this
    slow_factor: float = 4.0      # straggler: per-step slowdown multiplier
    incarnations: int = 1         # straggler: how many incarnations stay slow
    detail: str = ""              # wedge: the (unrecognized) crash message

    def pod_name(self) -> str:
        return self.pod or f"learner-{self.job}-{self.learner}"


@dataclass(frozen=True)
class FaultPlan:
    """A deterministic, seed-independent chaos script."""

    faults: Tuple[Fault, ...] = ()

    def validate(self) -> Optional[str]:
        for f in self.faults:
            if f.kind not in FAULT_KINDS:
                return (f"unknown fault kind {f.kind!r}; "
                        f"known: {list(FAULT_KINDS)}")
            if f.kind != "poison_node" and not f.job and not f.pod:
                return f"fault {f.kind!r} needs a target job or pod"
            if f.kind == "poison_node" and not (f.node or f.job or f.pod):
                return "poison_node needs a node or a target pod"
            if f.at < 0:
                return f"fault {f.kind!r}: at must be >= 0"
            if f.kind == "straggler" and (f.slow_factor <= 1.0
                                          or f.incarnations < 1):
                return ("straggler needs slow_factor > 1 and "
                        "incarnations >= 1")
        return None


class FaultInjector:
    """Platform-resident executor for :class:`FaultPlan`s.

    Owned by ``DLaaSPlatform`` (``platform.faults``); armed via
    ``platform.inject(plan)``.  Learner procs consult the gate hooks
    (``learner_gate`` / ``incarnation_factor``) every step, so gates fire
    deterministically at the declared step regardless of restart timing.
    """

    CKPT_RETRY_S = 5.0     # ckpt_corrupt waits for a checkpoint to exist

    def __init__(self, platform):
        self.platform = platform
        self._oom: Dict[Tuple[str, int], Fault] = {}
        self._wedge: Dict[Tuple[str, int], Fault] = {}
        self._slow: Dict[Tuple[str, int], Fault] = {}
        self._slow_left: Dict[Tuple[str, int], int] = {}

    # -- arming ---------------------------------------------------------
    def arm(self, plan: FaultPlan) -> None:
        err = plan.validate()
        if err:
            raise ValueError(f"invalid FaultPlan: {err}")
        for f in plan.faults:
            self.platform.sim.at(f.at, self._trigger, f)

    def _trigger(self, f: Fault) -> None:
        sim = self.platform.sim
        key = (f.job, f.learner)
        if f.kind == "oom":
            self._oom[key] = f
        elif f.kind == "wedge":
            self._wedge[key] = f
        elif f.kind == "straggler":
            self._slow[key] = f
            self._slow_left[key] = f.incarnations
        elif f.kind == "flaky_pod":
            sim.log(f"fault: flaky_pod kills {f.pod_name()}")
            self.platform.cluster.kubectl_delete_pod(f.pod_name())
        elif f.kind == "poison_node":
            node = f.node or self._node_of(f.pod_name())
            if node is None:
                sim.log(f"fault: poison_node target {f.pod_name()} "
                        f"not placed yet; retrying")
                sim.schedule(self.CKPT_RETRY_S, self._trigger, f)
                return
            self.platform.cluster.poison_node(node)
        elif f.kind == "ckpt_corrupt":
            self._corrupt_newest(f)

    def _node_of(self, pod_name: str) -> Optional[str]:
        for pod in self.platform.cluster.pods.values():
            if pod.spec.name == pod_name and pod.node is not None:
                return pod.node.name
        return None

    def _corrupt_newest(self, f: Fault) -> None:
        """Flip bytes in every blob of the newest checkpoint generation,
        then kill the chief (the incident a corrupt write rides in on).
        Retries until the job has published a checkpoint."""
        from repro.core.checkpoint import CheckpointManager
        sim = self.platform.sim
        store = self.platform.objectstore
        ck = CheckpointManager(store, f.job)
        steps = ck.steps()
        if not steps:
            sim.schedule(self.CKPT_RETRY_S, self._trigger, f)
            return
        base = f"ckpt/{f.job}/{steps[-1]:012d}/blob/"
        for path in store.list_prefix(base):
            store.corrupt(path)
        sim.log(f"fault: ckpt_corrupt step {steps[-1]} of {f.job}")
        self.platform.cluster.kubectl_delete_pod(f.pod_name())

    # -- gates consulted by learner procs -------------------------------
    def learner_gate(self, job_id: str, idx: int, step: int, vol) -> None:
        """Called once per training step; raises to crash the learner."""
        key = (job_id, idx)
        f = self._oom.get(key)
        if f is not None and step >= f.at_step:
            if vol.read("repair/mem_scale", 1.0) > f.clears_below:
                raise InjectedOOM(
                    f"{OOM_SIGNATURE}: learner memory budget exceeded "
                    f"at step {step}")
        w = self._wedge.get(key)
        if w is not None and step >= w.at_step:
            del self._wedge[key]          # one-shot
            raise RuntimeError(
                w.detail or "container terminated unexpectedly "
                            "(cause undetermined)")

    def incarnation_factor(self, job_id: str, idx: int) -> float:
        """Per-incarnation step-time multiplier (slow-loss straggler).
        Consumes one armed incarnation per call; after the budgeted
        incarnations a restarted learner runs at full speed — so the
        registered restart repair genuinely cures the straggler."""
        key = (job_id, idx)
        if self._slow_left.get(key, 0) > 0:
            self._slow_left[key] -= 1
            return self._slow[key].slow_factor
        return 1.0


# ---------------------------------------------------------------------------
# Failure reports + classification
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class FailureReport:
    """Classified failure: category + confidence + the evidence used."""

    category: str
    confidence: float
    pod: str = ""
    learner: int = -1
    node: str = ""
    evidence: Dict[str, Any] = field(default_factory=dict)

    def to_doc(self) -> Dict[str, Any]:
        return {"category": self.category, "confidence": self.confidence,
                "pod": self.pod, "learner": self.learner, "node": self.node,
                "evidence": dict(self.evidence)}


class FailureClassifier:
    """Evidence → FailureReport, priority-ordered by signature strength.

    1. ``OOM``            — the OOM-killer signature in the exit detail;
    2. ``CKPT_CORRUPT``   — the newest checkpoint generation fails
       integrity (a restore now silently loses work);
    3. ``POISONED_NODE``  — >= 2 *distinct* pods recently died on the same
       still-alive node (a dead node is the scheduler's problem already);
    4. ``UNKNOWN``        — an exit detail nobody recognizes (low
       confidence: never auto-repaired);
    5. ``FLAKY_POD``      — a detail-free one-shot crash.
    """

    CO_OCCUR_WINDOW_S = 120.0
    CO_OCCUR_MIN_PODS = 2

    def __init__(self, platform, job_id: str, spec, role: str = "learner"):
        self.platform = platform
        self.job_id = job_id
        self.spec = spec
        self.role = role

    # -- evidence gathering ---------------------------------------------
    def _latest_failed_record(self, name: str):
        for rec in reversed(self.platform.cluster.pod_history):
            if rec.name == name and rec.status == "FAILED":
                return rec
        return None

    def _node_cofailures(self, node: str) -> Set[str]:
        now = self.platform.sim.now
        return {rec.name for rec in self.platform.cluster.pod_history
                if rec.node == node and rec.status == "FAILED"
                and now - rec.finished_at <= self.CO_OCCUR_WINDOW_S}

    def _node_alive(self, node: str) -> bool:
        return any(n.name == node and n.alive
                   for n in self.platform.cluster.nodes)

    # -- classification --------------------------------------------------
    def classify(self, idx: int, restarts: int = 0) -> FailureReport:
        name = f"{self.role}-{self.job_id}-{idx}"
        rec = self._latest_failed_record(name)
        detail = rec.exit_detail if rec is not None else ""
        node = (rec.node or "") if rec is not None else ""
        status = self.platform.statestore.try_get(
            f"status/{self.job_id}/learner/{idx}")
        evidence: Dict[str, Any] = {
            "exit_detail": detail, "restarts": restarts,
            "last_status": status.get("state") if status else None,
        }
        mk = lambda cat, conf: FailureReport(
            category=cat, confidence=conf, pod=name, learner=idx,
            node=node, evidence=evidence)

        if OOM_SIGNATURE in detail or "exit 137" in detail:
            return mk("OOM", 0.95)

        if self.spec.kind == "train":
            from repro.core.checkpoint import CheckpointManager
            bad = CheckpointManager(
                self.platform.objectstore, self.job_id).newest_invalid()
            if bad is not None:
                evidence["corrupt_step"] = bad
                return mk("CKPT_CORRUPT", 0.9)

        if node and self._node_alive(node):
            cofailed = self._node_cofailures(node)
            if len(cofailed) >= self.CO_OCCUR_MIN_PODS:
                evidence["co_failed"] = sorted(cofailed)
                return mk("POISONED_NODE", 0.85)

        if detail:
            return mk("UNKNOWN", 0.3)
        return mk("FLAKY_POD", 0.6)

    def straggler_report(self, idx: int, **evidence: Any) -> FailureReport:
        """STRAGGLER reports come from the progress detector, not from
        crash evidence — the pod is alive, just lagging."""
        name = f"{self.role}-{self.job_id}-{idx}"
        ev: Dict[str, Any] = {"detector": "progress-lag"}
        ev.update(evidence)
        return FailureReport(category="STRAGGLER", confidence=0.9,
                             pod=name, learner=idx, evidence=ev)


# ---------------------------------------------------------------------------
# Safe-repair registry
# ---------------------------------------------------------------------------
#: category -> registered repair action.  THE safe list: the Guardian will
#: only ever apply an action found here.  UNKNOWN is deliberately absent.
SAFE_REPAIRS: Dict[str, str] = {
    "OOM": "reduce_memory",
    "CKPT_CORRUPT": "checkpoint_fallback",
    "FLAKY_POD": "restart_in_place",
    "POISONED_NODE": "reschedule_exclude_node",
    "STRAGGLER": "restart_in_place",
}

PLAIN_RESTART = "restart"


def action_for(report: FailureReport, policy: str = "auto",
               min_confidence: float = 0.6) -> Tuple[str, bool]:
    """Resolve the repair for a report.  Returns ``(action, is_repair)``;
    ``is_repair=False`` means plain restart (no safe-list action applies:
    unknown category, confidence below threshold, or restart-only policy).
    """
    action = SAFE_REPAIRS.get(report.category)
    if (policy != "auto" or action is None
            or report.confidence < min_confidence):
        return PLAIN_RESTART, False
    return action, True


class SelfHealer:
    """Per-job failure bookkeeping shared by both Guardian monitors:
    expected-restart absorption (repair-initiated kills are not failures),
    per-category charge counters, and poisoned-node incident dedup (one
    node incident = one charge, however many pods it took down)."""

    POISON_INCIDENT_S = 60.0

    def __init__(self, platform, job_id: str, spec, role: str, n: int):
        self.platform = platform
        self.job_id = job_id
        self.spec = spec
        self.role = role
        self.classifier = FailureClassifier(platform, job_id, spec, role)
        self.counts: Dict[str, int] = {}
        self.total = 0
        self.seen: List[int] = [0] * n        # restarts already processed
        self.expected: List[int] = [0] * n
        self._poison_repaired: Dict[str, float] = {}

    # -- knobs (train block when present, envelope defaults otherwise) --
    @property
    def _train(self):
        return getattr(self.spec, "train", None)

    @property
    def policy(self) -> str:
        tr = self._train
        return tr.repair_policy if tr is not None else "auto"

    @property
    def min_confidence(self) -> float:
        tr = self._train
        return tr.min_repair_confidence if tr is not None else 0.6

    def budget_for(self, category: str) -> int:
        tr = self._train
        budgets = tr.restart_budgets if tr is not None else {}
        return budgets.get(category, self.spec.max_restarts)

    # -- bookkeeping -----------------------------------------------------
    def align(self, n: int) -> None:
        """Track elastic growth (shrink keeps stale slots harmlessly)."""
        while len(self.seen) < n:
            self.seen.append(0)
            self.expected.append(0)

    def expect_restart(self, idx: int) -> None:
        if 0 <= idx < len(self.expected):
            self.expected[idx] += 1

    def absorb_expected(self, idx: int) -> bool:
        if 0 <= idx < len(self.expected) and self.expected[idx] > 0:
            self.expected[idx] -= 1
            return True
        return False

    def absorb_poison_incident(self, report: FailureReport) -> bool:
        """True if this POISONED_NODE report belongs to an incident the
        Guardian already repaired — same node, within the window."""
        if report.category != "POISONED_NODE":
            return False
        t = self._poison_repaired.get(report.node)
        return t is not None and \
            self.platform.sim.now - t <= self.POISON_INCIDENT_S

    def note_poison_repaired(self, node: str) -> None:
        self._poison_repaired[node] = self.platform.sim.now

    def charge(self, category: str) -> int:
        """Charge one failure to the category's budget; returns the count."""
        if category not in FAILURE_CATEGORIES:
            raise ValueError(f"unknown failure category {category!r}")
        self.counts[category] = self.counts.get(category, 0) + 1
        return self.counts[category]
