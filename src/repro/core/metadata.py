"""MongoDB analog: the job-metadata system of record.

Semantics the platform depends on (paper §III-c):
* **Durable**: documents survive pod crashes (disk-backed).
* **Available or refusing**: while the mongo pod is down, reads/writes raise
  ``Unavailable`` — callers (API, LCM, Guardian) retry.  Jobs acked by the
  API are therefore never lost: the ack happens only *after* a successful
  write here.

A write-ahead journal makes crash-during-write atomic: a document is either
fully present or absent (torn writes are discarded on recovery).
"""
from __future__ import annotations

import copy
from typing import Callable, Dict, List, Optional


class Unavailable(Exception):
    pass


class MetadataStore:
    def __init__(self):
        self._disk: Dict[str, Dict[str, dict]] = {}     # collection -> id -> doc
        self._journal: List[tuple] = []
        self.alive = True                               # pod up?

    # -- fault injection ---------------------------------------------------
    def crash(self) -> None:
        self.alive = False
        # torn journal entries are discarded; _disk only ever holds
        # fully-committed docs (commit is the dict assignment below)
        self._journal.clear()

    def restart(self) -> None:
        self.alive = True

    def _check(self) -> None:
        if not self.alive:
            raise Unavailable("metadata store down")

    # -- API -----------------------------------------------------------------
    def insert(self, coll: str, doc_id: str, doc: dict) -> None:
        self._check()
        self._journal.append(("insert", coll, doc_id))
        self._disk.setdefault(coll, {})[doc_id] = copy.deepcopy(doc)

    def update(self, coll: str, doc_id: str, fields: dict) -> None:
        self._check()
        d = self._disk.get(coll, {}).get(doc_id)
        if d is None:
            raise KeyError(f"{coll}/{doc_id}")
        self._journal.append(("update", coll, doc_id))
        d.update(copy.deepcopy(fields))

    def get(self, coll: str, doc_id: str) -> Optional[dict]:
        self._check()
        d = self._disk.get(coll, {}).get(doc_id)
        return copy.deepcopy(d) if d is not None else None

    def find(self, coll: str, pred: Callable[[dict], bool]) -> List[dict]:
        self._check()
        return [copy.deepcopy(d) for d in self._disk.get(coll, {}).values()
                if pred(d)]

    def delete(self, coll: str, doc_id: str) -> None:
        self._check()
        d = self._disk.get(coll, {})
        if doc_id not in d:
            raise KeyError(f"{coll}/{doc_id}")
        self._journal.append(("delete", coll, doc_id))
        del d[doc_id]

    def bump_counter(self, name: str) -> int:
        """Durable monotonic counter (findAndModify analog): returns the
        next value and persists the advance atomically.  Survives API-pod
        restarts, so id allocation never rewinds."""
        self._check()
        doc = self._disk.get("counters", {}).get(name)
        n = (doc or {}).get("next", 1)
        self._journal.append(("counter", name, n))
        self._disk.setdefault("counters", {})[name] = {"next": n + 1}
        return n

    def append_event(self, coll: str, doc_id: str, event: dict) -> None:
        self._check()
        d = self._disk.get(coll, {}).get(doc_id)
        if d is None:
            raise KeyError(f"{coll}/{doc_id}")
        d.setdefault("events", []).append(copy.deepcopy(event))
