"""Helper pod: load-data, controller, log-collector, store-results.

The helper pod is isolated from the learner pods (different pod, same NFS
volume).  The controller detects learner completion/failure from exit files
and heartbeats on the shared volume and records per-learner status in the
replicated state store (ETCD) — resilient to crashes of the controller
(restart re-reads the volume), of the Guardian (statuses wait in ETCD) and
of learners (stale heartbeats).
"""
from __future__ import annotations

from typing import Dict

from repro.core import states
from repro.core.jobspec import JobSpec

DATA_BW_GBPS = 0.5           # object-store → volume streaming bandwidth


def make_load_data_proc(platform, job_id: str, spec: JobSpec):
    def proc(pod):
        vol = platform.volumes.get(f"vol-{job_id}")
        # stream the dataset from COS to the shared volume
        remaining = vol.read("data_remaining_gb", spec.dataset_gb)
        while remaining > 0:
            yield 1.0
            remaining = max(0.0, remaining - DATA_BW_GBPS)
            vol.write("data_remaining_gb", remaining)   # resumable download
        vol.write("data_ready", True)
        return 0
    return proc


def make_controller_proc(platform, job_id: str, spec: JobSpec):
    """Watches the volume; writes learner statuses to ETCD; decides
    checkpoint-mode rollbacks on learner failure."""

    def proc(pod):
        sim = platform.sim
        vol = platform.volumes.get(f"vol-{job_id}")
        store = platform.statestore
        stale_after = 3.0 * spec.step_time_s + 2.0
        was_unreachable = False

        while True:
            world = vol.read("world", spec.learners)
            any_running = False
            for i in range(world):
                ex = vol.read(f"exit/{i}")
                pr = vol.read(f"progress/{i}")
                if ex == 0:
                    st = states.learner_status(
                        "SUCCEEDED", step=pr["step"] if pr else None,
                        t=sim.now)
                elif ex is not None:
                    st = states.learner_status("FAILED", exit=ex, t=sim.now)
                elif pr is None:
                    st = states.learner_status("STARTING", t=sim.now)
                    any_running = True
                elif sim.now - pr["t"] > stale_after:
                    st = states.learner_status(
                        "UNREACHABLE", step=pr["step"], t=sim.now,
                        last_seen=pr["t"])
                    any_running = True
                else:
                    st = states.learner_status(
                        "RUNNING", step=pr["step"], t=sim.now,
                        stalled=pr.get("stalled", False))
                    any_running = True
                ok = yield from store.put(f"status/{job_id}/learner/{i}", st)
                if not ok:
                    # statestore momentarily without quorum; retry next tick
                    pass

            # checkpoint-mode group rollback: once per failure incident
            if spec.recovery_mode == "checkpoint" \
                    and world > 1:
                sts = [store.try_get(f"status/{job_id}/learner/{i}")
                       for i in range(world)]
                unreachable = any(s and s["state"] == "UNREACHABLE" for s in sts)
                if unreachable and not was_unreachable:
                    from repro.core.checkpoint import CheckpointManager
                    ck = CheckpointManager(platform.objectstore, job_id)
                    target = ck.latest_valid_step() or 0
                    # re-read per incident: the Guardian's checkpoint-
                    # fallback repair also bumps this counter, and a stale
                    # cached value here would reuse its epoch (learners
                    # would ack one rollback and skip the other)
                    rb_epoch = vol.read("rollback_epoch", 0) + 1
                    vol.write("rollback_epoch", rb_epoch)
                    vol.write("rollback_to", {"step": target, "epoch": rb_epoch})
                    vol.append("log/controller",
                               f"[{sim.now:.2f}] rollback to {target}")
                was_unreachable = unreachable

            if not any_running:
                return 0
            yield 1.0

    return proc


def make_log_collector_proc(platform, job_id: str, spec: JobSpec):
    def proc(pod):
        vol = platform.volumes.get(f"vol-{job_id}")
        store = platform.objectstore
        shipped: Dict[str, int] = {}
        while True:
            done = all(vol.read(f"exit/{i}") is not None
                       for i in range(vol.read("world", spec.learners)))
            for path in vol.ls("log/"):
                lines = vol.read(path, [])
                n0 = shipped.get(path, 0)
                if len(lines) > n0:
                    # append-only shipping: logs survive learner crashes,
                    # and the blob grows in place — get()+put() here wrote
                    # O(n²) bytes over a job's lifetime
                    key = f"cos/{job_id}/logs/{path.split('/', 1)[1]}"
                    new = "\n".join(lines[n0:]).encode()
                    store.append(key, new + b"\n")
                    shipped[path] = len(lines)
            if done:
                return 0
            yield 2.0
    return proc


def make_store_results_proc(platform, job_id: str, spec: JobSpec):
    def proc(pod):
        vol = platform.volumes.get(f"vol-{job_id}")
        while True:
            world = vol.read("world", spec.learners)
            exits = [vol.read(f"exit/{i}") for i in range(world)]
            if all(e is not None for e in exits):
                if all(e == 0 for e in exits):
                    platform.objectstore.put(
                        f"cos/{job_id}/results/model",
                        f"trained:{spec.framework}:{spec.total_steps}"
                        .encode())
                return 0
            yield 2.0
    return proc
