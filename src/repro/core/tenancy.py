"""Multi-tenancy: quotas, metering, network isolation (paper §II, §III-d).

DL frameworks run arbitrary customer code, so learner pods must be isolated
from DLaaS system processes and from each other.  ``NetworkPolicy.allowed``
is the single enforcement point — the cluster's RPC layer and the learner
processes consult it; tests assert cross-tenant and learner→control-plane
traffic is refused.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass
class Tenant:
    name: str
    gpu_quota: int = 64


class QuotaExceeded(Exception):
    pass


class Metering:
    """GPU-seconds per tenant (the API layer's 'metering')."""

    def __init__(self):
        self.usage: Dict[str, float] = {}
        self._running: Dict[str, Tuple[str, int, float]] = {}  # job: tenant,gpus,t0

    def job_started(self, job_id: str, tenant: str, gpus: int, now: float):
        self._running[job_id] = (tenant, gpus, now)

    def job_stopped(self, job_id: str, now: float):
        rec = self._running.pop(job_id, None)
        if rec:
            tenant, gpus, t0 = rec
            self.usage[tenant] = self.usage.get(tenant, 0.0) + gpus * (now - t0)

    def gpu_seconds(self, tenant: str, now: Optional[float] = None) -> float:
        """Metered usage.  With ``now``, in-flight jobs accrue up to the
        read time — a tenant with only running jobs no longer meters 0.0
        until the first ``job_stopped``."""
        total = self.usage.get(tenant, 0.0)
        if now is not None:
            for t, gpus, t0 in self._running.values():
                if t == tenant:
                    total += gpus * max(0.0, now - t0)
        return total


class TenancyManager:
    def __init__(self):
        self.tenants: Dict[str, Tenant] = {"default": Tenant("default", 10_000)}
        self.allocated: Dict[str, int] = {}
        self.metering = Metering()

    def add_tenant(self, name: str, gpu_quota: int) -> Tenant:
        t = Tenant(name, gpu_quota)
        self.tenants[name] = t
        return t

    def reserve(self, tenant: str, gpus: int) -> None:
        t = self.tenants.get(tenant)
        if t is None:
            raise KeyError(f"unknown tenant {tenant}")
        used = self.allocated.get(tenant, 0)
        if used + gpus > t.gpu_quota:
            raise QuotaExceeded(
                f"tenant {tenant}: {used}+{gpus} > quota {t.gpu_quota}")
        self.allocated[tenant] = used + gpus

    def release(self, tenant: str, gpus: int) -> None:
        self.allocated[tenant] = max(0, self.allocated.get(tenant, 0) - gpus)


class NetworkPolicy:
    """Workload pods (learners, servers, dryrun runners — they all execute
    customer code) may talk only to their own job's resources."""

    SYSTEM_SERVICES = ("dlaas-api", "dlaas-lcm", "mongo", "etcd")
    WORKLOAD_ROLES = ("learner", "server", "dryrun")

    @staticmethod
    def allowed(src_labels: Dict[str, str], dst: str) -> bool:
        role = src_labels.get("role", "")
        if role not in NetworkPolicy.WORKLOAD_ROLES:
            return True                        # system pods are trusted
        job = src_labels.get("job", "")
        # workloads: own volume, own status prefix, object store paths of
        # own job.  Prefix matches are segment-anchored: job-001 must NOT
        # be allowed to read cos/job-0010/... .
        if dst in NetworkPolicy.SYSTEM_SERVICES:
            return False
        if dst.startswith("volume/"):
            return dst == f"volume/{job}"
        if dst.startswith("status/"):
            return dst.startswith(f"status/{job}/")
        if dst.startswith("cos/"):
            return (dst == f"cos/{job}" or dst.startswith(f"cos/{job}/")
                    or dst == "cos/datasets"
                    or dst.startswith("cos/datasets/"))
        return False
