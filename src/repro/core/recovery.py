"""Failure detection & straggler mitigation primitives.

Heartbeat staleness handles *crash* failures; stragglers are the gray
failures — a learner that is alive but progressing far slower than its
peers stalls synchronous training for everyone.  The detector flags a
learner whose progress falls behind the group median by more than
``lag_factor`` × the median per-window progress, sustained over
``patience`` windows.
"""
from __future__ import annotations

from typing import List, Optional


class StragglerDetector:
    def __init__(self, n_learners: int, lag_factor: float = 0.5,
                 patience: int = 3, window_s: float = 10.0):
        self.n = n_learners
        self.lag_factor = lag_factor
        self.patience = patience
        self.window_s = window_s
        self._last_t: Optional[float] = None
        self._last_steps: Optional[List[Optional[int]]] = None
        self._strikes = [0] * n_learners

    def update(self, now: float, steps: List[Optional[int]]) -> List[int]:
        """Feed current per-learner steps; returns learners to restart."""
        if self.n < 3:
            return []                       # need a quorum of peers to judge
        if self._last_t is None or now - self._last_t < self.window_s:
            if self._last_t is None:
                self._last_t, self._last_steps = now, list(steps)
            return []
        deltas = []
        for i in range(self.n):
            if steps[i] is None or self._last_steps[i] is None:
                deltas.append(None)
            else:
                deltas.append(steps[i] - self._last_steps[i])
        self._last_t, self._last_steps = now, list(steps)
        known = sorted(d for d in deltas if d is not None)
        if len(known) < max(3, self.n // 2):
            return []
        median = known[len(known) // 2]
        if median <= 0:
            return []                       # whole group stalled — not a straggler
        out = []
        for i, d in enumerate(deltas):
            if d is not None and d < self.lag_factor * median:
                self._strikes[i] += 1
                if self._strikes[i] >= self.patience:
                    self._strikes[i] = 0
                    out.append(i)
            else:
                self._strikes[i] = 0
        return out
