"""The Guardian: per-job agent run as a K8S Job (paper §III-d/e/f).

Atomic deployment: the Guardian performs the multi-step deploy (volume,
network policy, gang admission, helper pod, learner stateful set).  Because
it runs under K8S-Job semantics, a crash at ANY step restarts it with fresh
process state; the restarted incarnation first **rolls back** whatever the
previous incarnation partially deployed (recorded step-by-step in ETCD),
then redeploys from scratch.  After ``backoff_limit`` exhaustion the job is
marked FAILED in Mongo by the LCM.

After a successful deploy the Guardian monitors: aggregates per-learner
statuses from ETCD into the job document, counts learner restarts against
``max_restarts``, emits user-visible timestamped events (restarts included —
users' training-progress graphs differ after a failure, §II), detects
stragglers, and garbage-collects all job resources at the end.
"""
from __future__ import annotations

from typing import Any, Dict, List

from repro.core.cluster import ContainerSpec, Deployment, PodSpec, StatefulSet
from repro.core.helper import (
    make_controller_proc, make_load_data_proc, make_log_collector_proc,
    make_store_results_proc)
from repro.core.learner import make_learner_proc
from repro.core.manifest import JobManifest
from repro.core.metadata import Unavailable
from repro.core.recovery import StragglerDetector

DEPLOY_STEP_TIME = (0.1, 0.4)        # per multi-step-deploy action
MONITOR_PERIOD = 1.0

# Fig-4 startup ranges
HELPER_STARTUP = (3.0, 4.0)
LEARNER_STARTUP = (10.0, 20.0)


def make_guardian_proc(platform, job_id: str, manifest: JobManifest):
    def proc(pod):
        sim = platform.sim
        store = platform.statestore
        cluster = platform.cluster

        # -- helpers --------------------------------------------------------
        def update_job(fields: Dict[str, Any], event: str = None):
            while True:
                try:
                    platform.metadata.update("jobs", job_id, fields)
                    if event:
                        platform.metadata.append_event(
                            "jobs", job_id,
                            {"t": sim.now, "event": event})
                    return
                except Unavailable:
                    yield 0.5

        # ---- 1. read prior deploy record; roll back partial deployment ----
        prior = store.try_get(f"deploy/{job_id}/resources", [])
        if prior:
            sim.log(f"guardian/{job_id}: rolling back partial deploy {prior}")
            yield from _rollback(platform, job_id, manifest, prior)
            yield from store.put(f"deploy/{job_id}/resources", [])
            yield from update_job(
                {}, event="ROLLBACK of partial deployment")

        # ---- 2. multi-step atomic deploy ------------------------------------
        resources: List[str] = []

        def record(res: str):
            resources.append(res)
            return store.put(f"deploy/{job_id}/resources", resources)

        yield from update_job({"state": "DEPLOYING"}, "DEPLOYING")

        # (a) shared NFS volume
        yield sim.rng.uniform(*DEPLOY_STEP_TIME)
        platform.volumes.provision(f"vol-{job_id}")
        ok = yield from record(f"volume/vol-{job_id}")
        if not ok:
            raise RuntimeError("etcd unavailable during deploy")

        # (b) network policy for tenant isolation
        yield sim.rng.uniform(*DEPLOY_STEP_TIME)
        platform.netpolicies[job_id] = {"tenant": manifest.tenant,
                                        "job": job_id}
        yield from record(f"netpolicy/{job_id}")

        # (c) gang admission (quota + capacity, all-or-nothing).  Elastic
        # jobs admit the largest feasible world when full capacity is gone
        # (e.g. a redeploy after a node died) instead of failing.
        yield sim.rng.uniform(*DEPLOY_STEP_TIME)
        world = manifest.learners
        try:
            platform.scheduler.admit_gang(
                cluster, manifest.tenant, world, manifest.gpus_per_learner)
        except Exception:
            if not manifest.elastic:
                raise
            world = platform.scheduler.max_feasible_gang(
                cluster, manifest.gpus_per_learner, manifest.learners)
            if world < 1:
                raise
            platform.scheduler.admit_gang(
                cluster, manifest.tenant, world, manifest.gpus_per_learner)
            yield from update_job(
                {"world": world},
                f"ELASTIC admission {manifest.learners} -> {world}")
        platform.gang_sizes[job_id] = world
        platform.volumes.get(f"vol-{job_id}").write("world", world)
        yield from record(f"gang/{job_id}")

        # (d) helper pod (controller, load-data, log-collector, store-results)
        yield sim.rng.uniform(*DEPLOY_STEP_TIME)
        helper_spec = lambda i: PodSpec(
            name=f"helper-{job_id}",
            containers=[
                ContainerSpec("load-data", make_load_data_proc(platform, job_id, manifest)),
                ContainerSpec("controller", make_controller_proc(platform, job_id, manifest)),
                ContainerSpec("log-collector", make_log_collector_proc(platform, job_id, manifest)),
                ContainerSpec("store-results", make_store_results_proc(platform, job_id, manifest)),
            ],
            startup_range=HELPER_STARTUP,
            labels={"role": "helper", "job": job_id},
            tenant=manifest.tenant)
        platform.deployments[f"helper-{job_id}"] = Deployment(
            cluster, f"helper-{job_id}", helper_spec, replicas=1)
        yield from record(f"deployment/helper-{job_id}")

        # (e) learner stateful set (stable identities learner-<job>-i)
        yield sim.rng.uniform(*DEPLOY_STEP_TIME)
        mk = lambda i: PodSpec(
            name=f"learner-{job_id}-{i}",
            containers=[ContainerSpec(
                "learner", make_learner_proc(platform, job_id, manifest, i))],
            gpus=manifest.gpus_per_learner,
            startup_range=LEARNER_STARTUP,
            labels={"role": "learner", "job": job_id,
                    "tenant": manifest.tenant},
            tenant=manifest.tenant)
        ss = StatefulSet(cluster, f"learners-{job_id}", mk, replicas=world)
        platform.statefulsets[f"learners-{job_id}"] = ss
        yield from record(f"statefulset/learners-{job_id}")

        platform.tenancy.metering.job_started(
            job_id, manifest.tenant,
            manifest.learners * manifest.gpus_per_learner, sim.now)
        yield from update_job({"state": "PROCESSING"}, "PROCESSING")

        # ---- 3. monitor until completion/failure/halt -------------------------
        from repro.core.elastic import ElasticPolicy
        straggler = StragglerDetector(manifest.learners)
        elastic = ElasticPolicy(min_world=1)
        learner_failures = 0
        seen_restarts = [0] * manifest.learners
        last_agg = None
        pending_since: Dict[int, float] = {}
        vol = platform.volumes.get(f"vol-{job_id}")
        while True:
            yield MONITOR_PERIOD

            # ---- elastic DP shrink: a learner stuck PENDING (capacity lost,
            # e.g. node died with no spare GPUs) stalls synchronous training
            # forever; if the job opted in, shrink the world instead.
            if manifest.elastic:
                world = vol.read("world", manifest.learners)
                stuck = 0
                for i, p in enumerate(ss.pods[:world]):
                    if p.status == "PENDING":
                        pending_since.setdefault(i, sim.now)
                        if sim.now - pending_since[i] > 25.0:
                            stuck += 1
                    else:
                        pending_since.pop(i, None)
                if stuck:
                    new_world = elastic.decide(world, world - stuck)
                    if new_world and new_world < world:
                        plan = elastic.remesh_plan(world, new_world, 256)
                        vol.write("world", new_world)
                        vol.write("remesh",
                                  {"old": world, "new": new_world,
                                   "shard_map": {str(k): v for k, v in
                                                 plan.shard_map.items()}})
                        ss.resize(new_world)
                        platform.scheduler.release_gang(
                            manifest.tenant, world - new_world,
                            manifest.gpus_per_learner)
                        platform.gang_sizes[job_id] = new_world
                        yield from update_job(
                            {"world": new_world},
                            f"ELASTIC shrink {world} -> {new_world} "
                            f"(capacity lost; DP re-mesh)")
                        pending_since.clear()

            # user-initiated halt?
            try:
                doc = platform.metadata.get("jobs", job_id)
            except Unavailable:
                doc = None
            if doc and doc.get("desired_state") == "HALTED":
                yield from _teardown(platform, job_id, manifest, store)
                yield from update_job({"state": "HALTED"}, "HALTED by user")
                platform.tenancy.metering.job_stopped(job_id, sim.now)
                return 0

            # count learner pod restarts (failure detection by K8S + ss)
            for i in range(min(len(ss.restarts_total), len(seen_restarts))):
                if ss.restarts_total[i] > seen_restarts[i]:
                    learner_failures += ss.restarts_total[i] - seen_restarts[i]
                    seen_restarts[i] = ss.restarts_total[i]
                    yield from update_job(
                        {"restarts": learner_failures},
                        f"learner-{i} RESTARTED "
                        f"(total restarts {learner_failures})")

            if learner_failures > manifest.max_restarts:
                yield from _teardown(platform, job_id, manifest, store)
                yield from update_job(
                    {"state": "FAILED"},
                    f"FAILED: restarts {learner_failures} > "
                    f"max_restarts {manifest.max_restarts}")
                platform.tenancy.metering.job_stopped(job_id, sim.now)
                return 0

            # aggregate learner statuses from ETCD -> Mongo
            world = vol.read("world", manifest.learners) if vol else \
                manifest.learners
            sts = [store.try_get(f"status/{job_id}/learner/{i}")
                   for i in range(world)]
            if all(s and s["state"] == "SUCCEEDED" for s in sts):
                # let the helper finish log shipping + results upload first
                helper = platform.deployments.get(f"helper-{job_id}")
                deadline = sim.now + 60.0
                while helper is not None and not helper.all_succeeded() \
                        and sim.now < deadline:
                    yield 1.0
                yield from _teardown(platform, job_id, manifest, store)
                yield from update_job({"state": "COMPLETED"}, "COMPLETED")
                platform.tenancy.metering.job_stopped(job_id, sim.now)
                return 0

            agg = _aggregate(sts)
            if agg != last_agg:
                yield from update_job(
                    {"learner_states": agg}, f"status: {agg}")
                last_agg = agg

            # straggler detection from heartbeat progress
            steps_list = [s.get("step") if s else None for s in sts]
            steps_list += [None] * (manifest.learners - len(steps_list))
            slow = straggler.update(sim.now, steps_list)
            for i in slow:
                yield from update_job(
                    {}, f"learner-{i} STRAGGLER (progress lag); restarting")
                cluster.kubectl_delete_pod(f"learner-{job_id}-{i}")

    return proc


def _aggregate(sts) -> str:
    states = [s["state"] if s else "UNKNOWN" for s in sts]
    order = ["FAILED", "UNREACHABLE", "STARTING", "UNKNOWN", "RUNNING",
             "SUCCEEDED"]
    for o in order:
        if o in states:
            worst = o
            break
    steps = [s.get("step") for s in sts if s and s.get("step") is not None]
    return f"{worst} (min step {min(steps) if steps else 0})"


def _rollback(platform, job_id, manifest, resources):
    """Delete partially-created resources in reverse creation order."""
    for res in reversed(resources):
        kind, name = res.split("/", 1)
        yield platform.sim.rng.uniform(*DEPLOY_STEP_TIME)
        if kind == "statefulset" and name in platform.statefulsets:
            ss = platform.statefulsets.pop(name)
            ss.delete()
            for p in ss.pods:
                p.fail()
        elif kind == "deployment" and name in platform.deployments:
            d = platform.deployments.pop(name)
            d.delete()
            for p in d.pods:
                p.fail()
        elif kind == "gang":
            n = platform.gang_sizes.pop(job_id, manifest.learners)
            platform.scheduler.release_gang(
                manifest.tenant, n, manifest.gpus_per_learner)
        elif kind == "netpolicy":
            platform.netpolicies.pop(job_id, None)
        elif kind == "volume":
            platform.volumes.release(name)


def _teardown(platform, job_id, manifest, store):
    """Orderly cleanup at job end (volume contents are shipped already)."""
    res = store.try_get(f"deploy/{job_id}/resources", [])
    yield from _rollback(platform, job_id, manifest, res)
    yield from store.put(f"deploy/{job_id}/resources", [])
