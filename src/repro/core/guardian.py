"""The Guardian: per-job agent run as a K8S Job (paper §III-d/e/f).

Atomic deployment: the Guardian performs the multi-step deploy (volume,
network policy, gang admission, helper pod, workload pod set).  Because
it runs under K8S-Job semantics, a crash at ANY step restarts it with fresh
process state; the restarted incarnation first **rolls back** whatever the
previous incarnation partially deployed (recorded step-by-step in ETCD),
then redeploys from scratch.  After ``backoff_limit`` exhaustion the job is
marked FAILED in Mongo by the LCM.

Job API v2: the Guardian dispatches on ``JobSpec.kind`` through the
framework-adapter registry.  Train jobs get the full helper-pod + learner
StatefulSet topology with straggler detection and elastic DP; serve and
dryrun jobs get a gang of workload pods (servers / sweep runners) under
the same quota, metering, restart-budget, halt and teardown machinery —
every kind is a first-class, dependable platform job.
"""
from __future__ import annotations

from typing import Any, Dict, List

from repro.core import states
from repro.core.cluster import ContainerSpec, Deployment, PodSpec, StatefulSet
from repro.core.failures import SelfHealer, action_for
from repro.core.helper import (
    make_controller_proc, make_load_data_proc, make_log_collector_proc,
    make_store_results_proc)
from repro.core.jobspec import JobSpec
from repro.core.metadata import Unavailable
from repro.core.recovery import StragglerDetector

DEPLOY_STEP_TIME = (0.1, 0.4)        # per multi-step-deploy action
MONITOR_PERIOD = 1.0

# Fig-4 startup ranges
HELPER_STARTUP = (3.0, 4.0)
LEARNER_STARTUP = (10.0, 20.0)
SERVER_STARTUP = (5.0, 10.0)         # inference replicas boot faster


def make_guardian_proc(platform, job_id: str, spec: JobSpec):
    def proc(pod):
        sim = platform.sim
        store = platform.statestore
        cluster = platform.cluster
        adapter = platform.frameworks.get(spec.framework)

        # -- helpers --------------------------------------------------------
        def update_job(fields: Dict[str, Any], event: str = None, *,
                       state: str = None):
            while True:
                try:
                    if state is not None:
                        states.job_transition(
                            platform.metadata, sim.now, job_id, state,
                            fields, event)
                    else:
                        platform.metadata.update("jobs", job_id, fields)
                        if event:
                            platform.metadata.append_event(
                                "jobs", job_id,
                                {"t": sim.now, "event": event})
                    return
                except Unavailable:
                    yield 0.5

        # ---- 1. read prior deploy record; roll back partial deployment ----
        prior = store.try_get(f"deploy/{job_id}/resources", [])
        if prior:
            sim.log(f"guardian/{job_id}: rolling back partial deploy {prior}")
            yield from _rollback(platform, job_id, spec, prior)
            yield from store.put(f"deploy/{job_id}/resources", [])
            yield from update_job(
                {}, event="ROLLBACK of partial deployment")

        # ---- 2. multi-step atomic deploy ------------------------------------
        resources: List[str] = []

        def record(res: str):
            resources.append(res)
            return store.put(f"deploy/{job_id}/resources", resources)

        yield from update_job({}, "DEPLOYING", state="DEPLOYING")

        # (a) shared NFS volume
        yield sim.rng.uniform(*DEPLOY_STEP_TIME)
        platform.volumes.provision(f"vol-{job_id}")
        ok = yield from record(f"volume/vol-{job_id}")
        if not ok:
            raise RuntimeError("etcd unavailable during deploy")

        # (b) network policy for tenant isolation
        yield sim.rng.uniform(*DEPLOY_STEP_TIME)
        platform.netpolicies[job_id] = {"tenant": spec.tenant,
                                        "job": job_id}
        yield from record(f"netpolicy/{job_id}")

        # (c) gang admission (quota + capacity, all-or-nothing).  Elastic
        # train jobs admit the largest feasible world when full capacity is
        # gone (e.g. a redeploy after a node died) instead of failing.
        yield sim.rng.uniform(*DEPLOY_STEP_TIME)
        gang = adapter.gang(spec)
        world, gpus_each = gang.replicas, gang.gpus_per_replica
        # gang_sizes must be updated in the same synchronous step as the
        # admission: a guardian crash happens only at a yield, and a yield
        # between admit_gang and the record would strand quota the next
        # incarnation's rollback cannot see (SC302 flags this window).
        try:
            platform.scheduler.admit_gang(
                cluster, spec.tenant, world, gpus_each)
            platform.gang_sizes[job_id] = world
        except Exception:
            if not (spec.elastic and spec.kind == "train"):
                raise
            world = platform.scheduler.max_feasible_gang(
                cluster, gpus_each, gang.replicas)
            if world < 1:
                raise
            platform.scheduler.admit_gang(
                cluster, spec.tenant, world, gpus_each)
            platform.gang_sizes[job_id] = world
            yield from update_job(
                {"world": world},
                f"ELASTIC admission {gang.replicas} -> {world}")
        platform.volumes.get(f"vol-{job_id}").write("world", world)
        yield from record(f"gang/{job_id}")

        # (d) helper pod (controller, load-data, log-collector,
        #     store-results) — train kind only; serve/dryrun workloads
        #     heartbeat straight through the volume and ship their own logs
        if spec.kind == "train":
            yield sim.rng.uniform(*DEPLOY_STEP_TIME)
            helper_spec = lambda i: PodSpec(
                name=f"helper-{job_id}",
                containers=[
                    ContainerSpec("load-data", make_load_data_proc(platform, job_id, spec)),
                    ContainerSpec("controller", make_controller_proc(platform, job_id, spec)),
                    ContainerSpec("log-collector", make_log_collector_proc(platform, job_id, spec)),
                    ContainerSpec("store-results", make_store_results_proc(platform, job_id, spec)),
                ],
                startup_range=HELPER_STARTUP,
                labels={"role": "helper", "job": job_id},
                tenant=spec.tenant)
            platform.deployments[f"helper-{job_id}"] = Deployment(
                cluster, f"helper-{job_id}", helper_spec, replicas=1)
            yield from record(f"deployment/helper-{job_id}")

        # (e) workload pod set (stable identities <role>-<job>-i), built by
        #     the framework adapter: learners / servers / sweep runners
        yield sim.rng.uniform(*DEPLOY_STEP_TIME)
        role = spec.role
        startup = LEARNER_STARTUP if spec.kind == "train" else SERVER_STARTUP
        mk = lambda i: PodSpec(
            name=f"{role}-{job_id}-{i}",
            containers=[ContainerSpec(
                role, adapter.workload_proc(platform, job_id, spec, i))],
            gpus=gpus_each,
            startup_range=startup,
            labels={"role": role, "job": job_id,
                    "tenant": spec.tenant},
            tenant=spec.tenant)
        ss = StatefulSet(cluster, f"learners-{job_id}", mk, replicas=world)
        platform.statefulsets[f"learners-{job_id}"] = ss
        yield from record(f"statefulset/learners-{job_id}")

        platform.tenancy.metering.job_started(
            job_id, spec.tenant, gang.replicas * gpus_each, sim.now)
        yield from update_job({}, "PROCESSING", state="PROCESSING")

        # ---- 3. monitor until completion/failure/halt -------------------------
        if spec.kind == "train":
            yield from _monitor_train(platform, job_id, spec, ss, store,
                                      update_job)
        else:
            yield from _monitor_gang(platform, job_id, spec, ss, store,
                                     update_job, world)
        return 0

    return proc


def _finish(platform, job_id: str, spec: JobSpec, store, update_job,
            state: str, event: str):
    """Shared terminal sequence: teardown, final state + event, settle
    metering.  Every monitor endgame (halt/fail/complete, any kind) runs
    through here so the bookkeeping can never drift apart."""
    yield from _teardown(platform, job_id, spec, store)
    yield from update_job({}, event, state=state)
    platform.tenancy.metering.job_stopped(job_id, platform.sim.now)


# ---------------------------------------------------------------------------
# Self-healing: classify → journal → safe-list repair → per-category budget
# ---------------------------------------------------------------------------
def _journal(platform, job_id: str, report):
    """Journal a FailureReport as a job event (Unavailable-tolerant, same
    retry discipline as update_job)."""
    while True:
        try:
            states.journal_failure(platform.metadata, platform.sim.now,
                                   job_id, report.to_doc())
            return
        except Unavailable:
            yield 0.5


def _heal_restarts(platform, job_id: str, spec: JobSpec, ss, update_job,
                   healer: SelfHealer):
    """Process restart bumps since the last monitor tick: classify each
    failure from pod-exit evidence, journal the report, apply the safe-list
    repair (or a plain restart for unknown/low-confidence failures), and
    charge the restart to its category's budget.

    Returns a FAILED message when some category's budget is exhausted,
    else None.  Repair-initiated kills (straggler restarts, poisoned-node
    evictions) were pre-announced via ``healer.expect_restart`` and are
    not charged; secondary pod deaths of an already-repaired poisoned-node
    incident are journaled but charged only once per incident.
    """
    role = healer.role
    healer.align(len(ss.restarts_total))
    for i in range(min(len(ss.restarts_total), len(healer.seen))):
        while ss.restarts_total[i] > healer.seen[i]:
            healer.seen[i] += 1
            healer.total += 1
            yield from update_job(
                {"restarts": healer.total},
                f"{role}-{i} RESTARTED (total restarts {healer.total})")
            if healer.absorb_expected(i):
                continue                  # our own kill — not a failure
            report = healer.classifier.classify(i, restarts=healer.seen[i])
            yield from _journal(platform, job_id, report)
            if healer.absorb_poison_incident(report):
                continue                  # incident already charged+repaired
            count = healer.charge(report.category)
            yield from update_job(
                {"failures_by_category": dict(healer.counts)})
            if count > healer.budget_for(report.category):
                return (f"FAILED: {report.category} failures {count} > "
                        f"budget {healer.budget_for(report.category)}")
            action, is_repair = action_for(
                report, healer.policy, healer.min_confidence)
            if is_repair:
                yield from _apply_repair(platform, job_id, spec, healer,
                                         report, action, update_job)
            else:
                yield from update_job(
                    {}, f"RESTART plain (no auto-repair: {report.category}, "
                        f"confidence {report.confidence:.2f})")
    return None


def _apply_repair(platform, job_id: str, spec: JobSpec, healer: SelfHealer,
                  report, action: str, update_job):
    """Apply one registered safe-list action (see failures.SAFE_REPAIRS).
    Every branch is bounded and reversible-by-restart; nothing here guesses.
    """
    vol = platform.volumes.get(f"vol-{job_id}")
    if action == "reduce_memory":
        # halve the learner page/memory budget; learners read the knob from
        # the shared volume on every step
        if vol is not None:
            vol.write("repair/mem_scale",
                      vol.read("repair/mem_scale", 1.0) * 0.5)
    elif action == "checkpoint_fallback":
        # drop exactly one (integrity-failed) newest generation and roll
        # the gang back to the newest valid one
        from repro.core.checkpoint import CheckpointManager
        ck = CheckpointManager(platform.objectstore, job_id)
        target = ck.fallback_one()
        if vol is not None:
            epoch = vol.read("rollback_epoch", 0) + 1
            vol.write("rollback_epoch", epoch)
            vol.write("rollback_to", {"step": target or 0, "epoch": epoch})
    elif action == "reschedule_exclude_node":
        _repair_exclude_node(platform, job_id, report.node, healer)
        healer.note_poison_repaired(report.node)
    # restart_in_place: the StatefulSet already recreated the pod with a
    # fresh identity — the restart itself IS the registered repair
    yield from update_job(
        {}, f"REPAIR {action} ({report.category}, pod {report.pod})")


def _repair_exclude_node(platform, job_id: str, node: str,
                         healer: SelfHealer) -> None:
    """POISONED_NODE repair: exclude ``node`` from this job's placement and
    evict the job's remaining pods there so their controllers reschedule
    them elsewhere.  Synchronous on purpose (SC302 node_exclusion provider):
    no yield can separate the acquire from the evictions, so a Guardian
    crash cannot leave pods pinned to a node the job just excluded.  The
    exclusion is held until ``_rollback``'s sweep releases it."""
    platform.scheduler.exclude_node(job_id, node)
    prefix = f"{healer.role}-{job_id}-"
    for pod in list(platform.cluster.pods.values()):
        if pod.spec.labels.get("job") != job_id:
            continue
        if pod.node is None or pod.node.name != node:
            continue
        if pod.status not in ("PENDING", "RUNNING"):
            continue
        name = pod.spec.name
        if name.startswith(prefix) and name[len(prefix):].isdigit():
            healer.expect_restart(int(name[len(prefix):]))
        pod.fail()


def _monitor_train(platform, job_id: str, spec: JobSpec, ss, store,
                   update_job):
    """Training monitor: elastic DP shrink, straggler detection, failure
    classification + safe auto-repair, per-category restart budgets,
    ETCD→Mongo status aggregation, halt, completion."""
    sim = platform.sim
    cluster = platform.cluster
    from repro.core.elastic import ElasticPolicy
    straggler = StragglerDetector(spec.learners)
    elastic = ElasticPolicy(min_world=1)
    healer = SelfHealer(platform, job_id, spec, spec.role, spec.learners)
    tr = spec.train
    pending_stuck_s = tr.pending_stuck_s if tr is not None else 25.0
    helper_drain_s = tr.helper_drain_s if tr is not None else 60.0
    last_agg = None
    pending_since: Dict[int, float] = {}
    vol = platform.volumes.get(f"vol-{job_id}")
    while True:
        yield MONITOR_PERIOD

        # ---- elastic DP shrink: a learner stuck PENDING (capacity lost,
        # e.g. node died with no spare GPUs) stalls synchronous training
        # forever; if the job opted in, shrink the world instead.
        if spec.elastic:
            world = vol.read("world", spec.learners)
            stuck = 0
            for i, p in enumerate(ss.pods[:world]):
                if p.status == "PENDING":
                    pending_since.setdefault(i, sim.now)
                    if sim.now - pending_since[i] > pending_stuck_s:
                        stuck += 1
                else:
                    pending_since.pop(i, None)
            if stuck:
                new_world = elastic.decide(world, world - stuck)
                if new_world and new_world < world:
                    plan = elastic.remesh_plan(world, new_world, 256)
                    vol.write("world", new_world)
                    vol.write("remesh",
                              {"old": world, "new": new_world,
                               "shard_map": {str(k): v for k, v in
                                             plan.shard_map.items()}})
                    ss.resize(new_world)
                    platform.scheduler.release_gang(
                        spec.tenant, world - new_world,
                        spec.gpus_per_learner)
                    platform.gang_sizes[job_id] = new_world
                    yield from update_job(
                        {"world": new_world},
                        f"ELASTIC shrink {world} -> {new_world} "
                        f"(capacity lost; DP re-mesh)")
                    pending_since.clear()

        # user-initiated halt?
        try:
            doc = platform.metadata.get("jobs", job_id)
        except Unavailable:
            doc = None
        if doc and doc.get("desired_state") == "HALTED":
            yield from _finish(platform, job_id, spec, store, update_job,
                               "HALTED", "HALTED by user")
            return 0

        # failure detection: classify each restart from pod-exit evidence,
        # journal it, auto-repair from the safe list, charge its budget
        fail = yield from _heal_restarts(platform, job_id, spec, ss,
                                         update_job, healer)
        if fail:
            yield from _finish(platform, job_id, spec, store, update_job,
                               "FAILED", fail)
            return 0

        # aggregate learner statuses from ETCD -> Mongo
        world = vol.read("world", spec.learners) if vol else \
            spec.learners
        sts = [store.try_get(f"status/{job_id}/learner/{i}")
               for i in range(world)]
        if all(s and s["state"] == "SUCCEEDED" for s in sts):
            # let the helper finish log shipping + results upload first
            helper = platform.deployments.get(f"helper-{job_id}")
            deadline = sim.now + helper_drain_s
            while helper is not None and not helper.all_succeeded() \
                    and sim.now < deadline:
                yield 1.0
            yield from _finish(platform, job_id, spec, store, update_job,
                               "COMPLETED", "COMPLETED")
            return 0

        agg = _aggregate(sts)
        if agg != last_agg:
            yield from update_job(
                {"learner_states": agg}, f"status: {agg}")
            last_agg = agg

        # straggler detection from heartbeat progress; the restart is a
        # registered repair (restart_in_place), pre-announced so the bump
        # is absorbed instead of being classified as a fresh failure
        steps_list = [s.get("step") if s else None for s in sts]
        steps_list += [None] * (spec.learners - len(steps_list))
        slow = straggler.update(sim.now, steps_list)
        for i in slow:
            report = healer.classifier.straggler_report(
                i, step=steps_list[i] if i < len(steps_list) else None)
            yield from _journal(platform, job_id, report)
            count = healer.charge("STRAGGLER")
            yield from update_job(
                {"failures_by_category": dict(healer.counts)},
                f"learner-{i} STRAGGLER (progress lag); restarting")
            if count > healer.budget_for("STRAGGLER"):
                yield from _finish(
                    platform, job_id, spec, store, update_job, "FAILED",
                    f"FAILED: STRAGGLER failures {count} > "
                    f"budget {healer.budget_for('STRAGGLER')}")
                return 0
            action, is_repair = action_for(
                report, healer.policy, healer.min_confidence)
            healer.expect_restart(i)
            cluster.kubectl_delete_pod(f"learner-{job_id}-{i}")
            if is_repair:
                yield from update_job(
                    {}, f"REPAIR {action} ({report.category}, "
                        f"pod {report.pod})")


def _monitor_gang(platform, job_id: str, spec: JobSpec, ss, store,
                  update_job, world: int):
    """Generic gang monitor for serve/dryrun kinds: halt, failure
    classification + per-category restart budgets, volume-exit completion,
    progress surfaced into the job document."""
    vol = platform.volumes.get(f"vol-{job_id}")
    healer = SelfHealer(platform, job_id, spec, spec.role, world)
    last_note = None
    while True:
        yield MONITOR_PERIOD

        # user-initiated halt?
        try:
            doc = platform.metadata.get("jobs", job_id)
        except Unavailable:
            doc = None
        if doc and doc.get("desired_state") == "HALTED":
            yield from _finish(platform, job_id, spec, store, update_job,
                               "HALTED", "HALTED by user")
            return 0

        # failure classification + per-category budgets (K8S recreates
        # crashed replicas in place; every bump is classified + journaled)
        fail = yield from _heal_restarts(platform, job_id, spec, ss,
                                         update_job, healer)
        if fail:
            yield from _finish(platform, job_id, spec, store, update_job,
                               "FAILED", fail)
            return 0

        # completion: every workload pod wrote its exit file
        exits = [vol.read(f"exit/{i}") for i in range(world)]
        if all(e is not None for e in exits):
            ok = all(e == 0 for e in exits)
            yield from _finish(
                platform, job_id, spec, store, update_job,
                "COMPLETED" if ok else "FAILED",
                "COMPLETED" if ok else f"FAILED: exit codes {exits}")
            return 0

        # surface gang progress into the job document
        if spec.kind == "serve":
            note = f"RUNNING (served {vol.read('served', 0)})"
        else:
            done = len(vol.ls("cell/"))
            note = f"RUNNING (cells {done})"
        if note != last_note:
            yield from update_job({"learner_states": note}, f"status: {note}")
            last_note = note


def _aggregate(sts) -> str:
    seen = [s["state"] if s else states.UNKNOWN for s in sts]
    worst = states.UNKNOWN
    for o in states.LEARNER_PRIORITY:
        if o in seen:
            worst = o
            break
    steps = [s.get("step") for s in sts if s and s.get("step") is not None]
    return f"{worst} (min step {min(steps) if steps else 0})"


def _delete_pod_set(registry, name):
    ctl = registry.pop(name, None)
    if ctl is not None:
        ctl.delete()
        for p in ctl.pods:
            p.fail()


def _release_gang(platform, job_id, spec):
    # gang_sizes (not spec.learners) is the amount actually admitted —
    # elastic jobs may hold less, and releasing a gang that was never
    # admitted would corrupt another tenant's quota.
    n = platform.gang_sizes.pop(job_id, None)
    if n is not None:
        platform.scheduler.release_gang(
            spec.tenant, n, spec.gpus_per_learner)


def _rollback(platform, job_id, spec, resources):
    """Delete partially-created resources in reverse creation order, then
    sweep anything the deploy created but never recorded — a crash can
    land between a resource's creation and its ETCD record, and resource
    names are deterministic per job, so the sweep is idempotent."""
    for res in reversed(resources):
        kind, name = res.split("/", 1)
        yield platform.sim.rng.uniform(*DEPLOY_STEP_TIME)
        if kind == "statefulset":
            _delete_pod_set(platform.statefulsets, name)
        elif kind == "deployment":
            _delete_pod_set(platform.deployments, name)
        elif kind == "gang":
            _release_gang(platform, job_id, spec)
        elif kind == "netpolicy":
            platform.netpolicies.pop(job_id, None)
        elif kind == "volume":
            platform.volumes.release(name)
    # safety-net sweep for unrecorded leftovers, reverse creation order
    _delete_pod_set(platform.statefulsets, f"learners-{job_id}")
    _delete_pod_set(platform.deployments, f"helper-{job_id}")
    _release_gang(platform, job_id, spec)
    # node exclusions acquired by the POISONED_NODE repair die with the
    # job (or with the incarnation that held them — a restarted Guardian
    # re-learns them from fresh evidence if the node is still bad)
    platform.scheduler.clear_exclusions(job_id)
    platform.netpolicies.pop(job_id, None)
    platform.volumes.release(f"vol-{job_id}")


def _teardown(platform, job_id, spec, store):
    """Orderly cleanup at job end (volume contents are shipped already)."""
    res = store.try_get(f"deploy/{job_id}/resources", [])
    yield from _rollback(platform, job_id, spec, res)
    yield from store.put(f"deploy/{job_id}/resources", [])
