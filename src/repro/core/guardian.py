"""The Guardian: per-job agent run as a K8S Job (paper §III-d/e/f).

Atomic deployment: the Guardian performs the multi-step deploy (volume,
network policy, gang admission, helper pod, workload pod set).  Because
it runs under K8S-Job semantics, a crash at ANY step restarts it with fresh
process state; the restarted incarnation first **rolls back** whatever the
previous incarnation partially deployed (recorded step-by-step in ETCD),
then redeploys from scratch.  After ``backoff_limit`` exhaustion the job is
marked FAILED in Mongo by the LCM.

Job API v2: the Guardian dispatches on ``JobSpec.kind`` through the
framework-adapter registry.  Train jobs get the full helper-pod + learner
StatefulSet topology with straggler detection and elastic DP; serve and
dryrun jobs get a gang of workload pods (servers / sweep runners) under
the same quota, metering, restart-budget, halt and teardown machinery —
every kind is a first-class, dependable platform job.
"""
from __future__ import annotations

from typing import Any, Dict, List

from repro.core import states
from repro.core.cluster import ContainerSpec, Deployment, PodSpec, StatefulSet
from repro.core.helper import (
    make_controller_proc, make_load_data_proc, make_log_collector_proc,
    make_store_results_proc)
from repro.core.jobspec import JobSpec
from repro.core.metadata import Unavailable
from repro.core.recovery import StragglerDetector

DEPLOY_STEP_TIME = (0.1, 0.4)        # per multi-step-deploy action
MONITOR_PERIOD = 1.0

# Fig-4 startup ranges
HELPER_STARTUP = (3.0, 4.0)
LEARNER_STARTUP = (10.0, 20.0)
SERVER_STARTUP = (5.0, 10.0)         # inference replicas boot faster


def make_guardian_proc(platform, job_id: str, spec: JobSpec):
    def proc(pod):
        sim = platform.sim
        store = platform.statestore
        cluster = platform.cluster
        adapter = platform.frameworks.get(spec.framework)

        # -- helpers --------------------------------------------------------
        def update_job(fields: Dict[str, Any], event: str = None, *,
                       state: str = None):
            while True:
                try:
                    if state is not None:
                        states.job_transition(
                            platform.metadata, sim.now, job_id, state,
                            fields, event)
                    else:
                        platform.metadata.update("jobs", job_id, fields)
                        if event:
                            platform.metadata.append_event(
                                "jobs", job_id,
                                {"t": sim.now, "event": event})
                    return
                except Unavailable:
                    yield 0.5

        # ---- 1. read prior deploy record; roll back partial deployment ----
        prior = store.try_get(f"deploy/{job_id}/resources", [])
        if prior:
            sim.log(f"guardian/{job_id}: rolling back partial deploy {prior}")
            yield from _rollback(platform, job_id, spec, prior)
            yield from store.put(f"deploy/{job_id}/resources", [])
            yield from update_job(
                {}, event="ROLLBACK of partial deployment")

        # ---- 2. multi-step atomic deploy ------------------------------------
        resources: List[str] = []

        def record(res: str):
            resources.append(res)
            return store.put(f"deploy/{job_id}/resources", resources)

        yield from update_job({}, "DEPLOYING", state="DEPLOYING")

        # (a) shared NFS volume
        yield sim.rng.uniform(*DEPLOY_STEP_TIME)
        platform.volumes.provision(f"vol-{job_id}")
        ok = yield from record(f"volume/vol-{job_id}")
        if not ok:
            raise RuntimeError("etcd unavailable during deploy")

        # (b) network policy for tenant isolation
        yield sim.rng.uniform(*DEPLOY_STEP_TIME)
        platform.netpolicies[job_id] = {"tenant": spec.tenant,
                                        "job": job_id}
        yield from record(f"netpolicy/{job_id}")

        # (c) gang admission (quota + capacity, all-or-nothing).  Elastic
        # train jobs admit the largest feasible world when full capacity is
        # gone (e.g. a redeploy after a node died) instead of failing.
        yield sim.rng.uniform(*DEPLOY_STEP_TIME)
        gang = adapter.gang(spec)
        world, gpus_each = gang.replicas, gang.gpus_per_replica
        # gang_sizes must be updated in the same synchronous step as the
        # admission: a guardian crash happens only at a yield, and a yield
        # between admit_gang and the record would strand quota the next
        # incarnation's rollback cannot see (SC302 flags this window).
        try:
            platform.scheduler.admit_gang(
                cluster, spec.tenant, world, gpus_each)
            platform.gang_sizes[job_id] = world
        except Exception:
            if not (spec.elastic and spec.kind == "train"):
                raise
            world = platform.scheduler.max_feasible_gang(
                cluster, gpus_each, gang.replicas)
            if world < 1:
                raise
            platform.scheduler.admit_gang(
                cluster, spec.tenant, world, gpus_each)
            platform.gang_sizes[job_id] = world
            yield from update_job(
                {"world": world},
                f"ELASTIC admission {gang.replicas} -> {world}")
        platform.volumes.get(f"vol-{job_id}").write("world", world)
        yield from record(f"gang/{job_id}")

        # (d) helper pod (controller, load-data, log-collector,
        #     store-results) — train kind only; serve/dryrun workloads
        #     heartbeat straight through the volume and ship their own logs
        if spec.kind == "train":
            yield sim.rng.uniform(*DEPLOY_STEP_TIME)
            helper_spec = lambda i: PodSpec(
                name=f"helper-{job_id}",
                containers=[
                    ContainerSpec("load-data", make_load_data_proc(platform, job_id, spec)),
                    ContainerSpec("controller", make_controller_proc(platform, job_id, spec)),
                    ContainerSpec("log-collector", make_log_collector_proc(platform, job_id, spec)),
                    ContainerSpec("store-results", make_store_results_proc(platform, job_id, spec)),
                ],
                startup_range=HELPER_STARTUP,
                labels={"role": "helper", "job": job_id},
                tenant=spec.tenant)
            platform.deployments[f"helper-{job_id}"] = Deployment(
                cluster, f"helper-{job_id}", helper_spec, replicas=1)
            yield from record(f"deployment/helper-{job_id}")

        # (e) workload pod set (stable identities <role>-<job>-i), built by
        #     the framework adapter: learners / servers / sweep runners
        yield sim.rng.uniform(*DEPLOY_STEP_TIME)
        role = spec.role
        startup = LEARNER_STARTUP if spec.kind == "train" else SERVER_STARTUP
        mk = lambda i: PodSpec(
            name=f"{role}-{job_id}-{i}",
            containers=[ContainerSpec(
                role, adapter.workload_proc(platform, job_id, spec, i))],
            gpus=gpus_each,
            startup_range=startup,
            labels={"role": role, "job": job_id,
                    "tenant": spec.tenant},
            tenant=spec.tenant)
        ss = StatefulSet(cluster, f"learners-{job_id}", mk, replicas=world)
        platform.statefulsets[f"learners-{job_id}"] = ss
        yield from record(f"statefulset/learners-{job_id}")

        platform.tenancy.metering.job_started(
            job_id, spec.tenant, gang.replicas * gpus_each, sim.now)
        yield from update_job({}, "PROCESSING", state="PROCESSING")

        # ---- 3. monitor until completion/failure/halt -------------------------
        if spec.kind == "train":
            yield from _monitor_train(platform, job_id, spec, ss, store,
                                      update_job)
        else:
            yield from _monitor_gang(platform, job_id, spec, ss, store,
                                     update_job, world)
        return 0

    return proc


def _finish(platform, job_id: str, spec: JobSpec, store, update_job,
            state: str, event: str):
    """Shared terminal sequence: teardown, final state + event, settle
    metering.  Every monitor endgame (halt/fail/complete, any kind) runs
    through here so the bookkeeping can never drift apart."""
    yield from _teardown(platform, job_id, spec, store)
    yield from update_job({}, event, state=state)
    platform.tenancy.metering.job_stopped(job_id, platform.sim.now)


def _monitor_train(platform, job_id: str, spec: JobSpec, ss, store,
                   update_job):
    """Training monitor: elastic DP shrink, straggler detection, restart
    budget, ETCD→Mongo status aggregation, halt, completion."""
    sim = platform.sim
    cluster = platform.cluster
    from repro.core.elastic import ElasticPolicy
    straggler = StragglerDetector(spec.learners)
    elastic = ElasticPolicy(min_world=1)
    learner_failures = 0
    seen_restarts = [0] * spec.learners
    last_agg = None
    pending_since: Dict[int, float] = {}
    vol = platform.volumes.get(f"vol-{job_id}")
    while True:
        yield MONITOR_PERIOD

        # ---- elastic DP shrink: a learner stuck PENDING (capacity lost,
        # e.g. node died with no spare GPUs) stalls synchronous training
        # forever; if the job opted in, shrink the world instead.
        if spec.elastic:
            world = vol.read("world", spec.learners)
            stuck = 0
            for i, p in enumerate(ss.pods[:world]):
                if p.status == "PENDING":
                    pending_since.setdefault(i, sim.now)
                    if sim.now - pending_since[i] > 25.0:
                        stuck += 1
                else:
                    pending_since.pop(i, None)
            if stuck:
                new_world = elastic.decide(world, world - stuck)
                if new_world and new_world < world:
                    plan = elastic.remesh_plan(world, new_world, 256)
                    vol.write("world", new_world)
                    vol.write("remesh",
                              {"old": world, "new": new_world,
                               "shard_map": {str(k): v for k, v in
                                             plan.shard_map.items()}})
                    ss.resize(new_world)
                    platform.scheduler.release_gang(
                        spec.tenant, world - new_world,
                        spec.gpus_per_learner)
                    platform.gang_sizes[job_id] = new_world
                    yield from update_job(
                        {"world": new_world},
                        f"ELASTIC shrink {world} -> {new_world} "
                        f"(capacity lost; DP re-mesh)")
                    pending_since.clear()

        # user-initiated halt?
        try:
            doc = platform.metadata.get("jobs", job_id)
        except Unavailable:
            doc = None
        if doc and doc.get("desired_state") == "HALTED":
            yield from _finish(platform, job_id, spec, store, update_job,
                               "HALTED", "HALTED by user")
            return 0

        # count learner pod restarts (failure detection by K8S + ss)
        for i in range(min(len(ss.restarts_total), len(seen_restarts))):
            if ss.restarts_total[i] > seen_restarts[i]:
                learner_failures += ss.restarts_total[i] - seen_restarts[i]
                seen_restarts[i] = ss.restarts_total[i]
                yield from update_job(
                    {"restarts": learner_failures},
                    f"learner-{i} RESTARTED "
                    f"(total restarts {learner_failures})")

        if learner_failures > spec.max_restarts:
            yield from _finish(
                platform, job_id, spec, store, update_job, "FAILED",
                f"FAILED: restarts {learner_failures} > "
                f"max_restarts {spec.max_restarts}")
            return 0

        # aggregate learner statuses from ETCD -> Mongo
        world = vol.read("world", spec.learners) if vol else \
            spec.learners
        sts = [store.try_get(f"status/{job_id}/learner/{i}")
               for i in range(world)]
        if all(s and s["state"] == "SUCCEEDED" for s in sts):
            # let the helper finish log shipping + results upload first
            helper = platform.deployments.get(f"helper-{job_id}")
            deadline = sim.now + 60.0
            while helper is not None and not helper.all_succeeded() \
                    and sim.now < deadline:
                yield 1.0
            yield from _finish(platform, job_id, spec, store, update_job,
                               "COMPLETED", "COMPLETED")
            return 0

        agg = _aggregate(sts)
        if agg != last_agg:
            yield from update_job(
                {"learner_states": agg}, f"status: {agg}")
            last_agg = agg

        # straggler detection from heartbeat progress
        steps_list = [s.get("step") if s else None for s in sts]
        steps_list += [None] * (spec.learners - len(steps_list))
        slow = straggler.update(sim.now, steps_list)
        for i in slow:
            yield from update_job(
                {}, f"learner-{i} STRAGGLER (progress lag); restarting")
            cluster.kubectl_delete_pod(f"learner-{job_id}-{i}")


def _monitor_gang(platform, job_id: str, spec: JobSpec, ss, store,
                  update_job, world: int):
    """Generic gang monitor for serve/dryrun kinds: halt, restart budget,
    volume-exit completion, progress surfaced into the job document."""
    vol = platform.volumes.get(f"vol-{job_id}")
    failures = 0
    seen_restarts = [0] * world
    last_note = None
    while True:
        yield MONITOR_PERIOD

        # user-initiated halt?
        try:
            doc = platform.metadata.get("jobs", job_id)
        except Unavailable:
            doc = None
        if doc and doc.get("desired_state") == "HALTED":
            yield from _finish(platform, job_id, spec, store, update_job,
                               "HALTED", "HALTED by user")
            return 0

        # restart budget (K8S recreates crashed replicas in place)
        for i in range(min(len(ss.restarts_total), world)):
            if ss.restarts_total[i] > seen_restarts[i]:
                failures += ss.restarts_total[i] - seen_restarts[i]
                seen_restarts[i] = ss.restarts_total[i]
                yield from update_job(
                    {"restarts": failures},
                    f"{spec.role}-{i} RESTARTED (total restarts {failures})")
        if failures > spec.max_restarts:
            yield from _finish(
                platform, job_id, spec, store, update_job, "FAILED",
                f"FAILED: restarts {failures} > "
                f"max_restarts {spec.max_restarts}")
            return 0

        # completion: every workload pod wrote its exit file
        exits = [vol.read(f"exit/{i}") for i in range(world)]
        if all(e is not None for e in exits):
            ok = all(e == 0 for e in exits)
            yield from _finish(
                platform, job_id, spec, store, update_job,
                "COMPLETED" if ok else "FAILED",
                "COMPLETED" if ok else f"FAILED: exit codes {exits}")
            return 0

        # surface gang progress into the job document
        if spec.kind == "serve":
            note = f"RUNNING (served {vol.read('served', 0)})"
        else:
            done = len(vol.ls("cell/"))
            note = f"RUNNING (cells {done})"
        if note != last_note:
            yield from update_job({"learner_states": note}, f"status: {note}")
            last_note = note


def _aggregate(sts) -> str:
    seen = [s["state"] if s else states.UNKNOWN for s in sts]
    worst = states.UNKNOWN
    for o in states.LEARNER_PRIORITY:
        if o in seen:
            worst = o
            break
    steps = [s.get("step") for s in sts if s and s.get("step") is not None]
    return f"{worst} (min step {min(steps) if steps else 0})"


def _delete_pod_set(registry, name):
    ctl = registry.pop(name, None)
    if ctl is not None:
        ctl.delete()
        for p in ctl.pods:
            p.fail()


def _release_gang(platform, job_id, spec):
    # gang_sizes (not spec.learners) is the amount actually admitted —
    # elastic jobs may hold less, and releasing a gang that was never
    # admitted would corrupt another tenant's quota.
    n = platform.gang_sizes.pop(job_id, None)
    if n is not None:
        platform.scheduler.release_gang(
            spec.tenant, n, spec.gpus_per_learner)


def _rollback(platform, job_id, spec, resources):
    """Delete partially-created resources in reverse creation order, then
    sweep anything the deploy created but never recorded — a crash can
    land between a resource's creation and its ETCD record, and resource
    names are deterministic per job, so the sweep is idempotent."""
    for res in reversed(resources):
        kind, name = res.split("/", 1)
        yield platform.sim.rng.uniform(*DEPLOY_STEP_TIME)
        if kind == "statefulset":
            _delete_pod_set(platform.statefulsets, name)
        elif kind == "deployment":
            _delete_pod_set(platform.deployments, name)
        elif kind == "gang":
            _release_gang(platform, job_id, spec)
        elif kind == "netpolicy":
            platform.netpolicies.pop(job_id, None)
        elif kind == "volume":
            platform.volumes.release(name)
    # safety-net sweep for unrecorded leftovers, reverse creation order
    _delete_pod_set(platform.statefulsets, f"learners-{job_id}")
    _delete_pod_set(platform.deployments, f"helper-{job_id}")
    _release_gang(platform, job_id, spec)
    platform.netpolicies.pop(job_id, None)
    platform.volumes.release(f"vol-{job_id}")


def _teardown(platform, job_id, spec, store):
    """Orderly cleanup at job end (volume contents are shipped already)."""
    res = store.try_get(f"deploy/{job_id}/resources", [])
    yield from _rollback(platform, job_id, spec, res)
    yield from store.put(f"deploy/{job_id}/resources", [])
