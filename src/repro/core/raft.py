"""Minimal Raft (leader election + replicated log + quorum commit).

The paper coordinates controller ↔ Guardian status through a 3-way
replicated ETCD.  This is a faithful small Raft: randomized election
timeouts, term-checked votes, log-matching AppendEntries, commit on
majority *of the leader's current term*, deterministic state-machine
apply.  No snapshots / membership changes (the paper's usage doesn't
need them).

Persistence model: ``current_term``, ``voted_for`` and ``log`` survive a
crash (they are on disk in real Raft); volatile state (commit/applied
indices, leadership) is rebuilt.  The KV state machine is rebuilt by
replaying the log on restart — honest crash semantics.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.core.sim import Sim

FOLLOWER, CANDIDATE, LEADER = "follower", "candidate", "leader"

ELECTION_TIMEOUT = (0.15, 0.30)
HEARTBEAT = 0.05
NET_DELAY = (0.001, 0.005)


@dataclass
class Entry:
    term: int
    cmd: Tuple             # ("put", key, value) | ("del", key)


class RaftNode:
    def __init__(self, sim: Sim, idx: int):
        self.sim = sim
        self.idx = idx
        self.peers: List["RaftNode"] = []
        self.alive = True
        # persistent
        self.current_term = 0
        self.voted_for: Optional[int] = None
        self.log: List[Entry] = []
        # volatile
        self.state = FOLLOWER
        self.commit_index = 0       # 1-based count of committed entries
        self.last_applied = 0
        self.kv: Dict[str, Any] = {}
        self.next_index: Dict[int, int] = {}
        self.match_index: Dict[int, int] = {}
        self._timer = None
        self._reset_election_timer()
        # telemetry for safety property tests
        self.leader_history: List[Tuple[int, int]] = []   # (term, idx)

    # -- wiring ----------------------------------------------------------
    def set_peers(self, nodes: List["RaftNode"]) -> None:
        self.peers = [n for n in nodes if n is not self]

    def quorum(self) -> int:
        return (len(self.peers) + 1) // 2 + 1

    def _send(self, to: "RaftNode", fn: str, **msg) -> None:
        if not self.alive:
            return
        delay = self.sim.rng.uniform(*NET_DELAY)

        def deliver():
            if to.alive:
                getattr(to, fn)(**msg)

        self.sim.schedule(delay, deliver)

    # -- crash / restart ---------------------------------------------------
    def crash(self) -> None:
        self.alive = False
        self.sim.log(f"raft-{self.idx} CRASH")

    def restart(self) -> None:
        self.alive = True
        self.state = FOLLOWER
        self.commit_index = 0
        self.last_applied = 0
        self.kv = {}
        self._reset_election_timer()
        self.sim.log(f"raft-{self.idx} RESTART")

    # -- timers --------------------------------------------------------------
    def _reset_election_timer(self) -> None:
        if self._timer is not None:
            self.sim.cancel(self._timer)
        t = self.sim.rng.uniform(*ELECTION_TIMEOUT)
        self._timer = self.sim.schedule(t, self._election_timeout)

    def _election_timeout(self) -> None:
        if not self.alive or self.state == LEADER:
            self._reset_election_timer()
            return
        self._start_election()

    def _start_election(self) -> None:
        self.state = CANDIDATE
        self.current_term += 1
        self.voted_for = self.idx
        self._votes = {self.idx}
        self.sim.log(f"raft-{self.idx} candidate term {self.current_term}")
        lt = self.log[-1].term if self.log else 0
        for p in self.peers:
            self._send(p, "on_request_vote", term=self.current_term,
                       candidate=self.idx, last_log_index=len(self.log),
                       last_log_term=lt)
        self._reset_election_timer()

    # -- RPC handlers ---------------------------------------------------------
    def _maybe_step_down(self, term: int) -> None:
        if term > self.current_term:
            self.current_term = term
            self.voted_for = None
            self.state = FOLLOWER

    def on_request_vote(self, term, candidate, last_log_index, last_log_term):
        self._maybe_step_down(term)
        grant = False
        if term == self.current_term and self.voted_for in (None, candidate):
            my_lt = self.log[-1].term if self.log else 0
            up_to_date = (last_log_term, last_log_index) >= (my_lt, len(self.log))
            if up_to_date:
                grant = True
                self.voted_for = candidate
                self._reset_election_timer()
        peer = next(p for p in self.peers if p.idx == candidate)
        self._send(peer, "on_vote_reply", term=self.current_term, granted=grant,
                   voter=self.idx)

    def on_vote_reply(self, term, granted, voter):
        self._maybe_step_down(term)
        if self.state != CANDIDATE or term != self.current_term or not granted:
            return
        self._votes.add(voter)
        if len(self._votes) >= self.quorum():
            self._become_leader()

    def _become_leader(self) -> None:
        self.state = LEADER
        self.leader_history.append((self.current_term, self.idx))
        self.sim.log(f"raft-{self.idx} LEADER term {self.current_term}")
        for p in self.peers:
            self.next_index[p.idx] = len(self.log) + 1
            self.match_index[p.idx] = 0
        self._broadcast_append()
        self._heartbeat_loop()

    def _heartbeat_loop(self) -> None:
        if not self.alive or self.state != LEADER:
            return
        self._broadcast_append()
        self.sim.schedule(HEARTBEAT, self._heartbeat_loop)

    def _broadcast_append(self) -> None:
        for p in self.peers:
            ni = self.next_index.get(p.idx, len(self.log) + 1)
            prev_idx = ni - 1
            prev_term = self.log[prev_idx - 1].term if prev_idx >= 1 and prev_idx <= len(self.log) else 0
            entries = self.log[prev_idx:]
            self._send(p, "on_append", term=self.current_term, leader=self.idx,
                       prev_index=prev_idx, prev_term=prev_term,
                       entries=list(entries), leader_commit=self.commit_index)

    def on_append(self, term, leader, prev_index, prev_term, entries, leader_commit):
        self._maybe_step_down(term)
        ok = False
        if term == self.current_term:
            if self.state != FOLLOWER:
                self.state = FOLLOWER
            self._reset_election_timer()
            # log matching
            if prev_index == 0 or (prev_index <= len(self.log) and
                                   self.log[prev_index - 1].term == prev_term):
                ok = True
                # append/overwrite
                self.log = self.log[:prev_index] + list(entries)
                if leader_commit > self.commit_index:
                    self.commit_index = min(leader_commit, len(self.log))
                    self._apply()
        peer = next(p for p in self.peers if p.idx == leader)
        self._send(peer, "on_append_reply", term=self.current_term,
                   follower=self.idx, ok=ok,
                   match=prev_index + len(entries) if ok else 0)

    def on_append_reply(self, term, follower, ok, match):
        self._maybe_step_down(term)
        if self.state != LEADER or term != self.current_term:
            return
        if ok:
            self.match_index[follower] = max(self.match_index.get(follower, 0), match)
            self.next_index[follower] = self.match_index[follower] + 1
            self._advance_commit()
        else:
            self.next_index[follower] = max(1, self.next_index.get(follower, 1) - 1)

    def _advance_commit(self) -> None:
        for n in range(len(self.log), self.commit_index, -1):
            if self.log[n - 1].term != self.current_term:
                break                       # §5.4.2: only current-term entries
            votes = 1 + sum(1 for p in self.peers
                            if self.match_index.get(p.idx, 0) >= n)
            if votes >= self.quorum():
                self.commit_index = n
                self._apply()
                break

    # -- state machine ---------------------------------------------------------
    def _apply(self) -> None:
        while self.last_applied < self.commit_index:
            self.last_applied += 1
            cmd = self.log[self.last_applied - 1].cmd
            if cmd[0] == "put":
                self.kv[cmd[1]] = cmd[2]
            elif cmd[0] == "del":
                self.kv.pop(cmd[1], None)

    # -- client interface --------------------------------------------------------
    def propose(self, cmd: Tuple) -> Optional[int]:
        """Leader-only: append a command; returns its (1-based) log index."""
        if not self.alive or self.state != LEADER:
            return None
        self.log.append(Entry(self.current_term, cmd))
        self._broadcast_append()
        return len(self.log)

    def committed(self, index: int) -> bool:
        return self.commit_index >= index
