"""The paper's primary contribution: the DLaaS dependability/orchestration
layer (API → LCM → Guardian → helpers/learners on K8S/ETCD/Mongo analogs)."""
from repro.core.manifest import JobManifest            # noqa: F401
from repro.core.platform import DLaaSPlatform          # noqa: F401
from repro.core.checkpoint import CheckpointManager    # noqa: F401
from repro.core.objectstore import ObjectStore         # noqa: F401
from repro.core.sim import Sim                         # noqa: F401
