"""The paper's primary contribution: the DLaaS dependability/orchestration
layer (API → LCM → Guardian → helpers/learners on K8S/ETCD/Mongo analogs).

Job API v2 (``repro.core.jobspec``) is the public resource model: one
versioned ``JobSpec`` envelope with per-kind blocks for train/serve/dryrun
workloads, behind a framework-adapter registry.  ``JobManifest`` is the
deprecated v1 shim."""
from repro.core.jobspec import (                       # noqa: F401
    DryRunSpec,
    FrameworkAdapter,
    FrameworkRegistry,
    JobSpec,
    Resources,
    ServeSpec,
    SweepCell,
    TrainSpec,
)
from repro.core.api import InvalidJobState, JobNotFound  # noqa: F401
from repro.core.failures import (                      # noqa: F401
    SAFE_REPAIRS,
    FailureClassifier,
    FailureReport,
    Fault,
    FaultInjector,
    FaultPlan,
)
from repro.core.manifest import JobManifest            # noqa: F401
from repro.core.platform import DLaaSPlatform          # noqa: F401
from repro.core.checkpoint import CheckpointManager    # noqa: F401
from repro.core.objectstore import ObjectStore         # noqa: F401
from repro.core.sim import Sim                         # noqa: F401
