"""Lifecycle Manager: owns jobs from submission to completion (§III-c/d).

Reconciliation-loop design (our K8S-idiomatic adaptation of the paper's
API→LCM gRPC handoff, recorded in DESIGN.md): the LCM polls Mongo for
SUBMITTED jobs and creates a **Guardian K8S Job** for each — a quick single
step (paper: <3 s), after which K8S owns guardian restarts.  An LCM crash
loses nothing: the next incarnation resumes from Mongo state.  Garbage
collection reaps resources of terminal jobs whose guardian died for good.
"""
from __future__ import annotations

from repro.core import states
from repro.core.cluster import ContainerSpec, KJob, PodSpec
from repro.core.guardian import make_guardian_proc, _rollback
from repro.core.jobspec import spec_from_job_doc
from repro.core.metadata import Unavailable

GUARDIAN_STARTUP = (1.0, 2.0)        # Fig-4: guardian creation < 3 s
GUARDIAN_BACKOFF_LIMIT = 6
POLL = 1.0


def make_lcm_proc(platform):
    def proc(pod):
        sim = platform.sim
        while True:
            yield POLL
            try:
                subs = platform.metadata.find(
                    "jobs", lambda d: d["state"] == "SUBMITTED")
                terminal = platform.metadata.find(
                    "jobs", lambda d: d["state"] in
                    ("COMPLETED", "FAILED", "HALTED"))
            except Unavailable:
                continue

            for doc in subs:
                job_id = doc["id"]
                if job_id in platform.guardians:
                    continue                     # another LCM replica won
                spec = spec_from_job_doc(doc)    # v2 doc or legacy manifest
                pod_spec = PodSpec(
                    name=f"guardian-{job_id}",
                    containers=[ContainerSpec(
                        "guardian",
                        make_guardian_proc(platform, job_id, spec))],
                    startup_range=GUARDIAN_STARTUP,
                    labels={"role": "guardian", "job": job_id})

                def on_exhausted(job_id=job_id, spec=spec):
                    # guardian retries exhausted -> FAIL the job + reap
                    def reaper():
                        res = platform.statestore.try_get(
                            f"deploy/{job_id}/resources", [])
                        yield from _rollback(platform, job_id, spec, res)
                        # settle metering if the guardian died after
                        # job_started — otherwise the dead job would accrue
                        # in-flight GPU-seconds forever
                        platform.tenancy.metering.job_stopped(job_id, sim.now)
                        try:
                            states.job_transition(
                                platform.metadata, sim.now, job_id, "FAILED",
                                event="FAILED: guardian backoff exhausted")
                        except Unavailable:
                            pass
                    sim.spawn(reaper())

                platform.guardians[job_id] = KJob(
                    platform.cluster, f"guardian-{job_id}", pod_spec,
                    backoff_limit=GUARDIAN_BACKOFF_LIMIT,
                    on_exhausted=on_exhausted)
                try:
                    states.job_transition(
                        platform.metadata, sim.now, job_id, "DEPLOYING",
                        event="DEPLOYING (guardian created)")
                except Unavailable:
                    pass
                sim.log(f"lcm: guardian created for {job_id}")

            # GC: terminal job whose learner set still exists (guardian died
            # before teardown) — safety net
            for doc in terminal:
                job_id = doc["id"]
                name = f"learners-{job_id}"
                if name in platform.statefulsets:
                    spec = spec_from_job_doc(doc)
                    res = platform.statestore.try_get(
                        f"deploy/{job_id}/resources", [])
                    if res:
                        sim.log(f"lcm: gc {job_id}")
                        yield from _rollback(platform, job_id, spec, res)
                        yield from platform.statestore.put(
                            f"deploy/{job_id}/resources", [])

    return proc
