"""Deterministic discrete-event simulation kernel (virtual time).

The paper's platform runs on a real Kubernetes cluster; this container is a
single CPU host, so the *control plane* runs in virtual time while learner
compute can be real JAX work (see core/learner.py).  Every dependability
mechanism — atomic deployment, quorum writes, restart policies, rollback —
is implemented for real on top of this kernel; only the clock is simulated.

Processes are generator functions yielding sleep durations (seconds of
virtual time).  A crashed process is simply an abandoned generator; a
*restart* creates a fresh generator from the same factory — exactly the
semantics of a restarted OS process, which is what makes mid-operation
crash testing honest (no hidden state survives).
"""
from __future__ import annotations

import heapq
import itertools
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Generator, Iterator, Optional

ProcFn = Callable[..., Generator[float, None, Any]]


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    fn: Callable = field(compare=False)
    cancelled: bool = field(default=False, compare=False)


class Sim:
    def __init__(self, seed: int = 0):
        self.now = 0.0
        self.rng = random.Random(seed)
        self._heap: list[_Event] = []
        self._seq = itertools.count()
        self.trace: list[tuple[float, str]] = []

    # ------------------------------------------------------------------
    def log(self, msg: str) -> None:
        self.trace.append((self.now, msg))

    def schedule(self, delay: float, fn: Callable, *args, **kw) -> _Event:
        ev = _Event(self.now + max(delay, 0.0), next(self._seq),
                    lambda: fn(*args, **kw))
        heapq.heappush(self._heap, ev)
        return ev

    def at(self, t: float, fn: Callable, *args, **kw) -> _Event:
        """Schedule at an *absolute* virtual time (the FaultPlan seam):
        scripted fault injection declares event times, not delays, so a
        plan replays identically regardless of when it is armed.  Times
        already in the past fire on the next dispatch."""
        return self.schedule(t - self.now, fn, *args, **kw)

    def cancel(self, ev: _Event) -> None:
        ev.cancelled = True

    # ------------------------------------------------------------------
    def spawn(self, gen: Iterator[float], guard: Optional[Callable[[], bool]] = None,
              on_exit: Optional[Callable[[Any], None]] = None,
              on_error: Optional[Callable[[BaseException], None]] = None) -> None:
        """Drive a generator: each yielded float is a virtual-time sleep.
        ``guard`` is re-checked before every step — returning False abandons
        the generator (models a killed process).  ``on_exit(value)`` fires on
        normal return; ``on_error(exc)`` on an uncaught exception."""

        def step():
            if guard is not None and not guard():
                return
            try:
                delay = next(gen)
            except StopIteration as stop:
                if on_exit is not None:
                    on_exit(stop.value)
                return
            except Exception as e:           # process "exits nonzero"
                if on_error is not None:
                    on_error(e)
                else:
                    raise
                return
            self.schedule(float(delay), step)

        self.schedule(0.0, step)

    # ------------------------------------------------------------------
    def run(self, until: Optional[float] = None, max_events: int = 2_000_000) -> int:
        n = 0
        while self._heap and n < max_events:
            ev = self._heap[0]
            if until is not None and ev.time > until:
                break
            heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            self.now = max(self.now, ev.time)
            ev.fn()
            n += 1
        if until is not None:
            self.now = max(self.now, until)
        if n >= max_events:
            raise RuntimeError("sim event budget exceeded (livelock?)")
        return n

    def run_for(self, seconds: float) -> int:
        return self.run(until=self.now + seconds)
