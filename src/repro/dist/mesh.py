"""Device-mesh construction.  Functions, not module constants — importing
this module never touches jax device state.

Two mesh vocabularies are in play and ``repro.dist.sharding``'s rule
table lists alternatives for both (absent axis names auto-drop):

* the fixed production pod meshes, axes ``("pod", "data", "model")`` —
  what the dry-run compiles against;
* generic ``("data", "fsdp", "tensor")`` meshes sized to whatever
  devices exist — what a learner pod builds at startup, with a
  single-host fallback so the same code path runs on one CPU.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import jax
from jax.sharding import Mesh


def axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis is pure
    data parallelism (cross-pod traffic = one gradient all-reduce/step)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_abstract_production_mesh(*, multi_pod: bool = False):
    """AbstractMesh twin of :func:`make_production_mesh` — carries only axis
    names/sizes, so placement analytics (``launch.specs.placement_report``)
    can price the 256/512-chip meshes on a single-CPU test host.
    ``NamedSharding.shard_shape`` works on it; compiling does not."""
    from jax.sharding import AbstractMesh
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return AbstractMesh(tuple(zip(axes, shape)))


def make_host_mesh() -> Mesh:
    """1-device mesh for CPU smoke tests (same code path as production)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_device_mesh(
    *,
    data: Optional[int] = None,
    fsdp: int = 1,
    tensor: int = 1,
    devices: Optional[Sequence] = None,
) -> Mesh:
    """(data, fsdp, tensor) mesh over the available devices.

    ``data=None`` absorbs whatever devices remain after fsdp × tensor.
    If the request doesn't fit the device count the mesh degrades to pure
    data parallelism over every device (single-host fallback) — the same
    step function still compiles, just without model sharding.
    """
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    if data is None:
        data = max(1, n // (fsdp * tensor))
    if data * fsdp * tensor != n:
        data, fsdp, tensor = n, 1, 1
    import numpy as np
    arr = np.asarray(devices).reshape(data, fsdp, tensor)
    return Mesh(arr, ("data", "fsdp", "tensor"))
