"""Gradient compression with error feedback.

The paper frames dependable multi-tenant training as a tradeoff between
per-step efficiency and gang-wide robustness: the gradient all-reduce is
the step's dominant cross-learner traffic, and compressing it shrinks
both the wire time and the window in which a slow/flaky link stalls the
gang.  Compression must not change what the optimizer converges to, so
every scheme here is paired with *error feedback* (Seide et al., 2014):
the quantization residual is carried into the next step, making the
cumulative transmitted gradient exact:

    sum_k  deq_k  +  err_n  ==  sum_k  grad_k          (up to fp rounding)

Two schemes, selected by :class:`CompressionConfig`:

* ``int8`` — max-abs scaling to int8 levels, **actually packed**: the
  values path round-trips through :func:`pack_int8` / :func:`unpack_int8`
  (1 byte/element int8 payload + one fp32 scale per chunk), so the
  optimizer sees exactly what the int8 all-reduce wire would deliver and
  the payload the transport would ship exists as a real ``int8`` array.
  ``chunk_size=0`` (default) scales per tensor; a positive chunk size
  gives per-chunk scales (finer dynamic range on large tensors, one extra
  fp32 per chunk of wire).
* ``topk`` — magnitude top-k sparsification (send the largest ``ratio``
  fraction of |grad + err|, accumulate the rest).

``kind="none"`` is the identity — the config knob the launcher flips when
a tenant opts out of the efficiency side of the tradeoff.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

Tree = Dict[str, Any]


@dataclass(frozen=True)
class CompressionConfig:
    kind: str = "int8"         # int8 | topk | none
    topk_ratio: float = 0.05   # fraction of entries kept per tensor (topk)
    levels: int = 127          # quantization levels per sign (int8)
    chunk_size: int = 0        # int8 scale granularity; 0 = per tensor

    def __post_init__(self):
        if self.kind not in ("int8", "topk", "none"):
            raise ValueError(f"unknown compression kind {self.kind!r}")
        if self.chunk_size < 0:
            raise ValueError(f"chunk_size must be >= 0, got {self.chunk_size}")


def resolve_compression(
    flag: Union[None, bool, str, CompressionConfig],
) -> Optional[CompressionConfig]:
    """Normalize the historical bool knob / a kind string / a full config
    into Optional[CompressionConfig] (None = no compression)."""
    if isinstance(flag, CompressionConfig):
        return None if flag.kind == "none" else flag
    if flag is True:
        return CompressionConfig()
    if not flag or flag == "none":
        return None
    return CompressionConfig(kind=str(flag))


def init_error_buffers(params: Tree) -> Tree:
    """fp32 zero residual per leaf (works on concrete arrays and on
    ShapeDtypeStructs alike — only ``.shape`` is consulted)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def pack_int8(t: jax.Array, cfg: Optional[CompressionConfig] = None,
              ) -> Tuple[jax.Array, jax.Array]:
    """Quantize a tensor to the int8 wire format.

    Returns ``(payload, scales)``: ``payload`` is a flat ``int8`` array of
    ``ceil(size/chunk)·chunk`` entries (zero-padded tail) — the bytes the
    all-reduce would put on the wire — and ``scales`` is one fp32 max-abs
    scale per chunk (``chunk_size=0``: a single chunk spanning the
    tensor).  A zero chunk packs to scale 0 and decodes to exact zeros."""
    cfg = cfg or CompressionConfig()
    flat = t.astype(jnp.float32).ravel()
    chunk = cfg.chunk_size or flat.size
    pad = (-flat.size) % chunk
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, chunk)
    scales = jnp.max(jnp.abs(blocks), axis=1) / cfg.levels      # (n_chunks,)
    safe = jnp.where(scales > 0, scales, 1.0)
    q = jnp.clip(jnp.round(blocks / safe[:, None]),
                 -cfg.levels, cfg.levels).astype(jnp.int8)
    return q.ravel(), scales


def unpack_int8(payload: jax.Array, scales: jax.Array, shape,
                dtype=jnp.float32) -> jax.Array:
    """Decode the int8 wire format back to values (``shape`` drops the
    pack-time zero padding)."""
    import numpy as np
    n = int(np.prod(shape, dtype=np.int64)) if shape else 1
    blocks = payload.reshape(scales.shape[0], -1).astype(jnp.float32)
    vals = blocks * jnp.where(scales > 0, scales, 0.0)[:, None]
    return vals.ravel()[:n].reshape(shape).astype(dtype)


def wire_bytes_int8(t: jax.Array, cfg: Optional[CompressionConfig] = None,
                    ) -> int:
    """Bytes an int8-compressed all-reduce puts on the wire for ``t``:
    1 byte/element (padded to the chunk) + 4 bytes per chunk scale."""
    cfg = cfg or CompressionConfig()
    chunk = cfg.chunk_size or t.size
    n_chunks = -(-t.size // chunk) if t.size else 0
    return n_chunks * chunk + 4 * n_chunks


def allreduce_int8(x: jax.Array, axis_name: str,
                   cfg: Optional[CompressionConfig] = None) -> jax.Array:
    """int8 all-reduce over ``axis_name`` — call inside ``shard_map``.

    The wire protocol, per chunk of ``cfg.chunk_size`` elements:

    1. every device computes its local max-abs scale, then the group
       reconciles on the **largest** via ``lax.pmax`` — all devices must
       quantize against the same scale or the summed int8 payloads are
       meaningless;
    2. quantize locally against the shared scale (each payload is a real
       ``int8`` array — the bytes on the wire);
    3. ``lax.psum`` the payloads widened to int32 (ndev · 127 per lane,
       nowhere near overflow), one cheap integer collective;
    4. dequantize the summed payload once with the shared scale.

    Error bound: each device rounds to its nearest int8 level, at most
    scale/2 per element, so ``|int8_sum - exact_sum| ≤ ndev · scale/2``
    per element (scale = chunk max-abs / levels).  Error feedback in
    :func:`compress_grads` carries exactly this residual forward.
    """
    cfg = cfg or CompressionConfig()
    flat = x.astype(jnp.float32).ravel()
    chunk = cfg.chunk_size or flat.size
    pad = (-flat.size) % chunk
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, chunk)
    local = jnp.max(jnp.abs(blocks), axis=1) / cfg.levels       # (n_chunks,)
    scales = jax.lax.pmax(local, axis_name)
    safe = jnp.where(scales > 0, scales, 1.0)
    payload = jnp.clip(jnp.round(blocks / safe[:, None]),
                       -cfg.levels, cfg.levels).astype(jnp.int8)
    total = jax.lax.psum(payload.astype(jnp.int32), axis_name)
    vals = total.astype(jnp.float32) * jnp.where(scales > 0, scales,
                                                 0.0)[:, None]
    return vals.ravel()[:x.size].reshape(x.shape).astype(x.dtype)


def sharded_allreduce_int8(stacked: jax.Array, mesh,
                           axis: str = "data",
                           cfg: Optional[CompressionConfig] = None,
                           ) -> jax.Array:
    """All-reduce per-learner contributions over a real device mesh.

    ``stacked`` is ``(ndev, *shape)`` — row i is learner i's tensor,
    sharded one row per device along mesh axis ``axis`` by ``in_specs``.
    Each device runs :func:`allreduce_int8` on its row; the result (the
    int8-wire sum, identical on every device by construction — psum
    output is replicated) comes back unsharded as ``shape``.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    cfg = cfg or CompressionConfig()

    def body(row: jax.Array) -> jax.Array:
        return allreduce_int8(row[0], axis, cfg)

    fn = shard_map(body, mesh=mesh,
                   in_specs=P(axis), out_specs=P(),
                   check_rep=False)
    return fn(stacked)


def _int8_leaf(t: jax.Array, cfg: CompressionConfig) -> jax.Array:
    # the values path IS the wire path: quantize to the packed int8
    # payload + per-chunk scales, then decode what the wire delivers
    payload, scales = pack_int8(t, cfg)
    return unpack_int8(payload, scales, t.shape, t.dtype)


def _topk_leaf(t: jax.Array, cfg: CompressionConfig) -> jax.Array:
    """Keep (at most) the k largest-magnitude entries.

    Selecting by index — not by thresholding ``|t| >= top_k(...)[-1]`` —
    matters twice over: a threshold of 0 (any tensor whose (1-ratio)
    quantile is exactly 0, common for sparse gradients) would degenerate
    top-k into the identity with zero residual, and magnitude ties at the
    threshold would send more than k entries.  Zero entries are excluded
    even when selected: sending a zero is sending nothing.
    """
    k = max(1, int(round(t.size * cfg.topk_ratio)))
    flat = t.ravel()
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    keep = jnp.zeros(flat.shape, bool).at[idx].set(vals > 0)
    return jnp.where(keep.reshape(t.shape), t, jnp.zeros_like(t))


def compress_grads(
    grads: Tree,
    err: Tree,
    cfg: Optional[CompressionConfig] = None,
) -> Tuple[Tree, Tree]:
    """(grads, err) -> (dequantized grads, new err).

    The returned gradients are what the wire would deliver after the
    all-reduce; the residual ``(grad + err) - sent`` is carried forward.
    """
    cfg = cfg or CompressionConfig()
    if cfg.kind == "none":
        return grads, err

    leaf = _int8_leaf if cfg.kind == "int8" else _topk_leaf

    def one(g: jax.Array, e: jax.Array) -> Tuple[jax.Array, jax.Array]:
        target = g.astype(jnp.float32) + e
        sent = leaf(target, cfg)
        return sent.astype(g.dtype), target - sent

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    deq = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    new_err = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return deq, new_err
