"""Logical-axis sharding: one rule table maps model-space axis names to
mesh axes; everything else (specs, NamedShardings, per-shard memory) is
derived mechanically from it.

Model code never mentions mesh axes.  Parameters and activations carry
*logical* axis names (``"embed"``, ``"heads"``, ``"batch"`` …); the rule
table decides which mesh axes each logical axis shards over.  Three
well-formedness guarantees are enforced at spec-construction time:

* **auto-drop (absent)**    — a rule may name mesh axes that the current
  mesh does not have (``"pod"`` on a single-pod mesh, ``"fsdp"`` on the
  2-axis production mesh).  Absent axes are silently skipped, so one
  table serves every mesh.
* **auto-drop (indivisible)** — a mesh axis whose size does not divide
  the dimension is skipped rather than producing an XLA error (e.g.
  ``kv_heads=2`` over ``model=16`` replicates instead of splitting
  ``head_dim``).
* **use-once**              — a mesh axis already consumed by an earlier
  dimension of the same spec is skipped (PartitionSpecs must not repeat
  mesh axes).

``DEFAULT_RULES`` is the production table; per-cell overrides (the §Perf
hillclimbing knob, e.g. sequence-parallel residuals) go through
:meth:`ShardingRules.override`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

AxisName = Optional[str]
MeshAxes = Tuple[str, ...]


def _normalize(axes: Union[None, str, Sequence[str]]) -> MeshAxes:
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


@dataclass(frozen=True)
class ShardingRules:
    """Immutable logical-axis → mesh-axes table.

    Stored as a tuple of pairs so rule sets are hashable (they ride on
    :class:`repro.models.layers.Ctx`, a frozen dataclass).  Mesh axes are
    tried in rule order; see the module docstring for the drop rules.
    """

    rules: Tuple[Tuple[str, MeshAxes], ...] = ()

    def as_dict(self) -> Dict[str, MeshAxes]:
        return dict(self.rules)

    def axes_for(self, logical: str) -> MeshAxes:
        table = self.as_dict()
        if logical not in table:
            raise KeyError(
                f"no sharding rule for logical axis {logical!r}; "
                f"known: {sorted(table)}")
        return table[logical]

    def override(self, **kw: Union[None, str, Sequence[str]]) -> "ShardingRules":
        """New table with the given logical axes remapped (or added).
        ``axis=()`` / ``axis=None`` replicates; ``axis="model"`` or
        ``axis=("model", "pod")`` shards."""
        table = self.as_dict()
        table.update({k: _normalize(v) for k, v in kw.items()})
        return ShardingRules(tuple(sorted(table.items())))


def _mesh_sizes(mesh) -> Dict[str, int]:
    # ``axis_sizes`` covers both concrete Mesh and AbstractMesh — the latter
    # lets placement analytics price a 256-chip mesh on a 1-CPU test host.
    if hasattr(mesh, "axis_sizes"):
        return dict(zip(mesh.axis_names, mesh.axis_sizes))
    return dict(zip(mesh.axis_names, mesh.devices.shape))


# ---------------------------------------------------------------------------
# The production rule table.
#
# Convention (documented in README §Sharding):
#   * ``*_act`` names are activation axes; bare names are parameter axes.
#   * Parameters: FSDP over the data-parallel axis on the "embed" dim,
#     tensor parallelism over the model axis on heads/ffn/vocab/experts.
#   * Activations: batch over data, embed replicated (gathered at the
#     norm), logits vocab-sharded, residual sequence replicated unless
#     the sequence-parallel override flips ``resid_seq`` on.
#   * Each rule lists alternatives for BOTH mesh vocabularies — the
#     production ("pod", "data", "model") meshes and the generic
#     ("data", "fsdp", "tensor") meshes of repro.dist.mesh — absent
#     names auto-drop.
# ---------------------------------------------------------------------------
DEFAULT_RULES = ShardingRules().override(
    # activation axes
    batch=("pod", "data"),
    cache_batch=("pod", "data"),
    seq=(),
    resid_seq=(),            # override to ("model",) for Megatron-SP residuals
    # KV-cache placement (README §Serving cache placement):
    #   * ``kv_seq``      — sequence dim of *global* position-indexed caches.
    #     Sharded over the tensor axis; when ``kv_heads`` already consumed it
    #     (divisible head count) the use-once rule drops it and the cache is
    #     head-sharded instead.  Either way the 32k decode cache stops being
    #     replicated over the model axis.
    #   * ``window_seq``  — slot dim of ring-buffer (sliding-window) caches.
    #     NEVER sharded: the ``pos % window`` scatter wraps around, so a
    #     sharded ring would scatter across devices every step.  Ring buffers
    #     are batch-sharded through ``cache_batch`` only.
    #   * ``cache_pages`` — physical-page dim of the paged pool.  Pages have
    #     no batch dim (the pool is shared), so they shard over batch-ish
    #     axes AND the tensor axis; the serving allocator keeps a sequence's
    #     pages inside its own data shard (launch.executor.PagePool partitions
    #     its free lists per shard — spec-level invariants are checked by
    #     check_cache_locality).
    kv_seq=("tensor", "model"),
    window_seq=(),
    cache_pages=("pod", "data", "tensor", "model"),
    embed_act=(),
    vocab_act=("tensor", "model"),
    # parameter axes
    embed=("fsdp", "data"),
    vocab=("tensor", "model"),
    heads=("tensor", "model"),
    kv_heads=("tensor", "model"),
    head_dim=(),
    ffn=("tensor", "model"),
    experts=("tensor", "model"),
    expert_ffn=(),
    capacity=(),
    rnn=("tensor", "model"),
    lora=(),
    conv=(),
    layers=(),               # the scan dim is never sharded
)


def logical_to_spec(
    logical_axes: Sequence[AxisName],
    shape: Sequence[int],
    mesh: Mesh,
    rules: ShardingRules = DEFAULT_RULES,
) -> PartitionSpec:
    """Map per-dimension logical axis names to a valid ``PartitionSpec``.

    ``None`` entries replicate that dimension.  Unknown logical names
    raise ``KeyError`` (a typo must fail loudly, not silently replicate).
    Trailing replicated dims are trimmed from the spec.
    """
    assert len(logical_axes) == len(shape), (logical_axes, shape)
    sizes = _mesh_sizes(mesh)
    used: set = set()
    entries: list = []
    for name, dim in zip(logical_axes, shape):
        if name is None:
            entries.append(None)
            continue
        chosen: list = []
        prod = 1
        for ax in rules.axes_for(name):
            if ax not in sizes or ax in used:
                continue
            if dim % (prod * sizes[ax]) != 0:
                continue
            chosen.append(ax)
            prod *= sizes[ax]
        used.update(chosen)
        if not chosen:
            entries.append(None)
        elif len(chosen) == 1:
            entries.append(chosen[0])
        else:
            entries.append(tuple(chosen))
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def make_named_sharding(
    logical_axes: Sequence[AxisName],
    shape: Sequence[int],
    mesh: Mesh,
    rules: ShardingRules = DEFAULT_RULES,
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical_axes, shape, mesh, rules))


# ---------------------------------------------------------------------------
# Pytree-wide inference over abstract leaves.
#
# An "abstract leaf" is anything carrying ``.shape`` and ``.logical_axes``
# (repro.models.params.ParamAb and the abstract cache reuse of it) — the
# tree is evaluated without allocating a single array.
# ---------------------------------------------------------------------------
def is_abstract_leaf(x) -> bool:
    return hasattr(x, "logical_axes") and hasattr(x, "shape")


def tree_shardings(tree, mesh: Mesh, rules: ShardingRules = DEFAULT_RULES):
    """NamedSharding for every abstract leaf of ``tree``."""
    return jax.tree.map(
        lambda ab: make_named_sharding(ab.logical_axes, ab.shape, mesh, rules),
        tree, is_leaf=is_abstract_leaf)


def _shard_factor(spec: PartitionSpec, sizes: Dict[str, int]) -> int:
    f = 1
    for entry in spec:
        if entry is None:
            continue
        for ax in (entry if isinstance(entry, tuple) else (entry,)):
            f *= sizes[ax]
    return f


def _spec_entries(spec: PartitionSpec, ndim: int) -> Tuple:
    entries = tuple(spec) + (None,) * (ndim - len(tuple(spec)))
    return entries


def check_cache_locality(tree, mesh, rules: ShardingRules = DEFAULT_RULES) -> Dict[str, PartitionSpec]:
    """Well-formedness of a KV-cache sharding: decode gather/scatter must
    stay shard-local.

    Enforced invariants, per abstract cache leaf:

    * ``window_seq`` dims are replicated — the ring buffer's ``pos % window``
      scatter wraps, so a sharded ring would cross shards every decode step;
    * unnamed (``None``) dims — per-slot position metadata, page tables'
      page-index dim, the within-page token dim of a page pool — are
      replicated: they are read in full every step.

    These are *spec-level* invariants.  Which physical page a sequence's
    table points at is runtime data, so page→shard locality is enforced by
    the serving allocator instead (``launch.executor.PagePool`` partitions its
    free lists per data shard).

    Returns ``{leaf_path: spec}`` for introspection; raises ``ValueError``
    on the first violation.
    """
    leaves, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=is_abstract_leaf)
    out: Dict[str, PartitionSpec] = {}
    for path, ab in leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        spec = logical_to_spec(ab.logical_axes, ab.shape, mesh, rules)
        entries = _spec_entries(spec, len(ab.shape))
        for lax_name, entry in zip(ab.logical_axes, entries):
            axes = () if entry is None else (
                entry if isinstance(entry, tuple) else (entry,))
            if lax_name == "window_seq" and axes:
                raise ValueError(
                    f"cache leaf {name!r}: ring-buffer slot dim is sharded "
                    f"over {axes} — the pos%window scatter would cross "
                    f"shards every decode step; map 'window_seq' to ()")
            if lax_name is None and axes:
                raise ValueError(
                    f"cache leaf {name!r}: metadata dim sharded over {axes} "
                    f"— pos/page-table metadata must be replicated")
        out[name] = spec
    return out


def tree_shard_bytes(
    tree,
    mesh: Mesh,
    rules: ShardingRules = DEFAULT_RULES,
    dtype_override=None,
) -> int:
    """Analytic per-device bytes of the sharded tree (placement planning:
    no compile needed).  Divisibility is exact — auto-drop guarantees every
    kept mesh axis divides its dimension."""
    import jax.numpy as jnp

    sizes = _mesh_sizes(mesh)
    total = 0
    for ab in jax.tree.leaves(tree, is_leaf=is_abstract_leaf):
        spec = logical_to_spec(ab.logical_axes, ab.shape, mesh, rules)
        dt = jnp.dtype(dtype_override if dtype_override is not None
                       else getattr(ab, "dtype", "float32"))
        n = int(np.prod(ab.shape, dtype=np.int64)) if ab.shape else 1
        total += n * dt.itemsize // _shard_factor(spec, sizes)
    return total
