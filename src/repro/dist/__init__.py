"""``repro.dist`` — the sharded-execution subsystem.

The paper's platform (and its successor FfDL) treats the distribution
layer as an explicit, swappable subsystem under the learner payload.
This package is that layer for the JAX substrate:

* :mod:`repro.dist.sharding`    — logical-axis → ``PartitionSpec`` rules
  (one table, overridable per cell) + pytree-wide sharding inference.
* :mod:`repro.dist.compression` — gradient compression with error
  feedback (the paper's efficiency-vs-dependability tradeoff knob).
* :mod:`repro.dist.mesh`        — device-mesh construction (production
  pod meshes, data/fsdp/tensor meshes, single-host fallback).
"""
from repro.dist.compression import (  # noqa: F401
    CompressionConfig,
    compress_grads,
    init_error_buffers,
    pack_int8,
    resolve_compression,
    unpack_int8,
    wire_bytes_int8,
)
from repro.dist.mesh import (  # noqa: F401
    axis_sizes,
    make_device_mesh,
    make_host_mesh,
    make_production_mesh,
)
from repro.dist.sharding import (  # noqa: F401
    DEFAULT_RULES,
    ShardingRules,
    logical_to_spec,
    make_named_sharding,
    tree_shard_bytes,
    tree_shardings,
)
