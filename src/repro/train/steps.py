"""Train / serve step functions (the "learner" compute of the platform).

``make_train_step`` builds a pure (state, batch) -> (state, metrics) function:
grad accumulation over microbatches (scan), optional int8 gradient
compression with error feedback, global-norm clip, AdamW.  It is jit-able
and pjit-able; shardings come from the abstract param tree + rule table.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.dist.compression import (
    CompressionConfig,
    compress_grads,
    init_error_buffers,
    resolve_compression,
)
from repro.models.layers import Ctx
from repro.models.model import forward
from repro.models.params import init_params
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

Tree = Dict[str, Any]
TrainState = Dict[str, Any]       # {params, opt, step, [err]}


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def loss_fn(
    cfg: ModelConfig,
    params: Tree,
    batch: Tree,
    ctx: Ctx,
    remat_policy: str = "none",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, _, aux = forward(cfg, params, batch, ctx, mode="train",
                             remat_policy=remat_policy)
    labels = batch["labels"]
    valid = labels >= 0
    lab = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    ce = -(ll * valid).sum() / jnp.maximum(valid.sum(), 1)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------
def _compression_for(run: RunConfig, flag) -> Optional[CompressionConfig]:
    """The explicit argument wins (None = unspecified, fall back to the
    run config's knob; False/"none" = explicit opt-out)."""
    if flag is None:
        return resolve_compression(run.grad_compression)
    return resolve_compression(flag)


def init_train_state(cfg: ModelConfig, key: jax.Array,
                     run: Optional[RunConfig] = None,
                     grad_compression=None) -> TrainState:
    run = run or RunConfig()
    params = init_params(cfg, key)
    if run.master_dtype != "float32":
        params = jax.tree.map(
            lambda p: p.astype(run.master_dtype) if p.ndim >= 2 else p, params)
    state: TrainState = {
        "params": params,
        "opt": adamw_init(params, jnp.dtype(run.opt_dtype)),
        "step": jnp.zeros((), jnp.int32),
    }
    if _compression_for(run, grad_compression) is not None:
        state["err"] = init_error_buffers(params)
    return state


def make_train_step(
    cfg: ModelConfig,
    ctx: Ctx,
    run: RunConfig,
    opt_cfg: Optional[AdamWConfig] = None,
    grad_compression=None,
) -> Callable[[TrainState, Tree], Tuple[TrainState, Dict[str, jax.Array]]]:
    opt_cfg = opt_cfg or AdamWConfig(
        learning_rate=run.learning_rate, weight_decay=run.weight_decay,
        grad_clip_norm=run.grad_clip_norm, warmup_steps=run.warmup_steps,
        total_steps=run.total_steps)
    n_mb = run.num_microbatches
    comp = _compression_for(run, grad_compression)

    def loss_for_grad(params, mb):
        return loss_fn(cfg, params, mb, ctx, run.remat_policy)

    grad_fn = jax.value_and_grad(loss_for_grad, has_aux=True)

    def split_mb(batch):
        def r(x):
            B = x.shape[0]
            assert B % n_mb == 0, (B, n_mb)
            return x.reshape(n_mb, B // n_mb, *x.shape[1:])
        return jax.tree.map(r, batch)

    def train_step(state: TrainState, batch: Tree):
        params = state["params"]
        if n_mb == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mbs = split_mb(batch)

            def acc_body(carry, mb):
                (loss, metrics), g = grad_fn(params, mb)
                gsum, lsum = carry
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), ms = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / n_mb, gsum)
            loss = lsum / n_mb
            metrics = jax.tree.map(lambda m: m.mean(), ms)

        new_state = dict(state)
        if comp is not None:
            # The all-reduced gradient is what the wire delivers: quantize
            # (+ carried error) here, before clip/optimizer, so the update
            # math sees exactly the transported values.
            grads, new_state["err"] = compress_grads(grads, state["err"], comp)
        new_p, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, params, state["opt"])
        new_state.update(params=new_p, opt=new_opt, step=state["step"] + 1)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Serve steps
#
# Both steps are cache-layout agnostic: the layout (dense fallback vs the
# paged pool + page tables, selected by ``cfg.cache_layout`` /
# ``init_cache(layout=...)``) rides in the cache pytree itself and the
# model dispatches on it.  Decode ``pos`` is a scalar for lockstep batches
# or a per-sequence (B,) vector for continuous batching (paged layout;
# inactive slots carry -1 and their logits are garbage to be ignored).
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, ctx: Ctx):
    """(params, batch, cache, lengths=None) -> (last_logits, filled_cache).

    ``lengths`` (B,) switches to the *ragged* prefill path: prompts padded
    to the batch max, per-row last-valid logits, per-row masked cache
    writes (length-0 rows untouched — see models.model.forward).
    ``starts`` (B,) additionally makes it *chunked* (prefix caching): row
    ``b``'s tokens are the uncached tail of its prompt, opening at
    absolute position ``starts[b]``."""
    def prefill_step(params, batch, cache, lengths=None, starts=None):
        logits, new_cache, _ = forward(cfg, params, batch, ctx,
                                       mode="prefill", cache=cache,
                                       lengths=lengths, starts=starts)
        return logits, new_cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, ctx: Ctx):
    """(params, tokens (B,1), cache, pos scalar|(B,)) -> (logits, cache)."""
    def decode_step(params, batch, cache, pos):
        logits, new_cache, _ = forward(cfg, params, batch, ctx,
                                       mode="decode", cache=cache, pos=pos)
        return logits, new_cache
    return decode_step


def make_serve_steps(cfg: ModelConfig, ctx: Ctx, *, donate_cache: bool = True):
    """Jitted (prefill, decode) pair for the serving driver.  The decode
    cache argument is donated so the page pool / dense buffer is updated
    in place across the token loop."""
    prefill = jax.jit(make_prefill_step(cfg, ctx))
    decode = jax.jit(make_decode_step(cfg, ctx),
                     donate_argnums=(2,) if donate_cache else ())
    return prefill, decode
