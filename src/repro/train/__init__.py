from repro.train.steps import (  # noqa: F401
    TrainState,
    init_train_state,
    loss_fn,
    make_train_step,
    make_prefill_step,
    make_decode_step,
)
