"""Deterministic, resumable synthetic data pipeline.

DL jobs in the paper stream data each epoch from object storage; failures
must resume mid-epoch without replaying or skipping data.  We get exact
resumability *by construction*: ``batch_at(step)`` is a pure function of
(seed, step), so a learner restored from a step-``k`` checkpoint continues
with batch ``k+1`` bit-identically — no iterator state to persist.

The stream is an order-2 noisy Markov chain over the vocabulary, so it has
learnable structure (cross-entropy decreases) while needing no files.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1            # fraction of purely-random tokens

    def batch_at(self, step: int | jax.Array):
        """{tokens, labels}: labels[t] = tokens[t+1] (next-token LM)."""
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        start = jax.random.randint(k1, (B,), 0, V)
        noise_tok = jax.random.randint(k2, (B, S + 1), 0, V)
        is_noise = jax.random.bernoulli(k3, self.noise, (B, S + 1))

        # x_{t+1} = (a·x_t + b) mod V, resampled uniformly with prob `noise`
        a, b = 31, 17

        def step_fn(x, xs):
            nz, nt = xs
            x = jnp.where(nz, nt, (a * x + b) % V)
            return x, x

        _, seq = jax.lax.scan(
            step_fn, start, (is_noise.T, noise_tok.T))
        seq = seq.T.astype(jnp.int32)                      # (B, S+1)
        return {"tokens": seq[:, :S], "labels": seq[:, 1:S + 1]}
