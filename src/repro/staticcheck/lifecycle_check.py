"""SC301 — model-checked job/pod lifecycle.

Verifies the declared state machines in ``core/states.py`` and the code
that uses them, in three layers:

1. **Graph model check** (pure, on the declared tables): every state is
   reachable from the initial state, every non-terminal state has a path
   to a terminal, declared terminals are absorbing (no out-edges), and
   every sink is a declared terminal.

2. **Write-site routing** (AST over ``core/``): every ``{"state": ...}``
   literal and every ``pod.status = ...`` assignment outside
   ``states.py`` is a finding, unless it is one of two sanctioned
   idioms — the API entry point inserting at ``states.JOB.initial``
   (attribute reference, not a string), or a read-side echo whose value
   is a ``doc["state"]`` subscript.  Constant state strings are also
   checked against the declared vocabulary.

3. **Terminal settlement** (CFG dominance): every call site that routes
   a possibly-terminal state through the transition helper (a constant
   terminal, or a non-constant state expression — conservatively
   possibly-terminal) must sit in a function where a metering settle
   (``.job_stopped(...)``) and a resource release (``_teardown`` /
   ``_rollback`` / ``.release_gang(...)``) each either dominate or
   post-dominate the transition: on every completed run of that
   function the books balance.  Post-dominance is w.r.t. normal exit —
   exceptional exits are the restart path, settled by the next guardian
   incarnation (see ``cfg.py``).

Like ``drift_check``, ``check()`` takes an optional ``root`` (and here
``machines``) so tests can aim it at synthetic trees and mutated graphs.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.staticcheck import cfg as cfglib
from repro.staticcheck.engine import Finding

RULE_ID = "SC301"

SETTLE_ATTRS = ("job_stopped",)
RELEASE_NAMES = ("_teardown", "_rollback", "release_gang")


def _core_dir(root: Optional[Path]) -> Tuple[Path, str]:
    if root is not None:
        return Path(root) / "src" / "repro" / "core", "src/repro/core"
    import repro.core
    return Path(repro.core.__file__).parent, "src/repro/core"


def _machines():
    from repro.core import states
    return (states.JOB, states.POD)


# -- layer 1: graph model check -----------------------------------------


def _check_machine(m, path: str) -> List[Finding]:
    out: List[Finding] = []

    def f(msg: str) -> None:
        out.append(Finding(RULE_ID, path, 1, f"{m.name}: {msg}"))

    states = set(m.states)
    succ: Dict[str, set] = {s: set() for s in states}
    for frm, to in m.transitions:
        if frm is not None:
            succ[frm].add(to)

    for t in m.terminal:
        if t not in states:
            f(f"declared terminal {t!r} not in the state vocabulary")
    if m.initial not in states:
        f(f"initial state {m.initial!r} not in the state vocabulary")
        return out

    # reachability from initial
    seen = {m.initial}
    frontier = [m.initial]
    while frontier:
        s = frontier.pop()
        for n in succ[s]:
            if n not in seen:
                seen.add(n)
                frontier.append(n)
    for s in sorted(states - seen):
        f(f"state {s!r} unreachable from {m.initial!r}")

    # terminals absorb
    for frm, to in m.transitions:
        if frm in m.terminal:
            f(f"terminal state {frm!r} has out-edge to {to!r} "
              f"(terminals must be absorbing)")

    # sinks are declared terminals
    for s in sorted(states):
        if not succ[s] and s not in m.terminal:
            f(f"state {s!r} is a sink but not a declared terminal")

    # co-reachability: every state reaches some terminal
    coreach = set(m.terminal) & states
    changed = True
    while changed:
        changed = False
        for s in states - coreach:
            if succ[s] & coreach:
                coreach.add(s)
                changed = True
    for s in sorted(states - coreach):
        f(f"state {s!r} has no path to any terminal state")
    return out


# -- layer 2 + 3: AST write sites and settlement ------------------------


def _dotted(node: ast.expr) -> str:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _is_state_echo(value: ast.expr) -> bool:
    """``doc["state"]`` — copying an existing state, not writing one."""
    return (isinstance(value, ast.Subscript)
            and isinstance(value.slice, ast.Constant)
            and value.slice.value == "state")


def _is_initial_ref(value: ast.expr) -> bool:
    """``states.JOB.initial`` — the sanctioned entry-point insert."""
    return isinstance(value, ast.Attribute) and value.attr == "initial"


def _transition_state_arg(call: ast.Call) -> Optional[ast.expr]:
    """The state argument of a transition-helper call, if this is one.

    Recognizes ``[states.]job_transition(metadata, now, job_id, state,
    ...)`` and any call carrying a ``state=`` keyword (the guardians'
    ``update_job(fields, event, state=...)`` wrapper).
    """
    name = call.func.attr if isinstance(call.func, ast.Attribute) else (
        call.func.id if isinstance(call.func, ast.Name) else "")
    for kw in call.keywords:
        if kw.arg == "state":
            return kw.value
    if name == "job_transition" and len(call.args) >= 4:
        return call.args[3]
    return None


def _check_file(tree: ast.Module, rel: str, machines) -> List[Finding]:
    job, pod = machines[0], machines[1]
    out: List[Finding] = []
    vocab = set(job.states) | set(pod.states)
    from repro.core.states import LEARNER_STATES
    vocab |= set(LEARNER_STATES)

    # module-level string constants (cluster.py's PENDING/RUNNING/... )
    consts: Dict[str, str] = {}
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) and isinstance(stmt.value, ast.Tuple):
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Tuple) and \
                        len(tgt.elts) == len(stmt.value.elts):
                    for t, v in zip(tgt.elts, stmt.value.elts):
                        if isinstance(t, ast.Name) and \
                                isinstance(v, ast.Constant):
                            consts[t.id] = v.value

    for node in ast.walk(tree):
        # {"state": ...} literals
        if isinstance(node, ast.Dict):
            for k, v in zip(node.keys, node.values):
                if isinstance(k, ast.Constant) and k.value == "state":
                    if isinstance(v, ast.Constant):
                        if v.value not in vocab:
                            out.append(Finding(
                                RULE_ID, rel, node.lineno,
                                f"state {v.value!r} not in the declared "
                                f"vocabulary"))
                        out.append(Finding(
                            RULE_ID, rel, node.lineno,
                            "raw {'state': ...} write bypasses "
                            "states.job_transition"))
                    elif not (_is_initial_ref(v) or _is_state_echo(v)):
                        out.append(Finding(
                            RULE_ID, rel, node.lineno,
                            "raw {'state': ...} write bypasses "
                            "states.job_transition"))
        # pod.status = ... assignments
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Attribute) and tgt.attr == "status":
                    out.append(Finding(
                        RULE_ID, rel, node.lineno,
                        "raw .status assignment bypasses "
                        "states.pod_transition"))
        # pod_transition(pod, STATUS) vocabulary via module constants
        if isinstance(node, ast.Call):
            name = node.func.attr if isinstance(node.func, ast.Attribute) \
                else (node.func.id if isinstance(node.func, ast.Name) else "")
            if name == "pod_transition" and len(node.args) >= 2:
                arg = node.args[1]
                val = arg.value if isinstance(arg, ast.Constant) else \
                    consts.get(arg.id) if isinstance(arg, ast.Name) else None
                if val is not None and val not in pod.states:
                    out.append(Finding(
                        RULE_ID, rel, node.lineno,
                        f"pod status {val!r} not in the declared "
                        f"vocabulary"))
            if name == "learner_status" and node.args and \
                    isinstance(node.args[0], ast.Constant):
                if node.args[0].value not in LEARNER_STATES:
                    out.append(Finding(
                        RULE_ID, rel, node.lineno,
                        f"learner status {node.args[0].value!r} not in "
                        f"the declared vocabulary"))

    # settlement: per-function CFG dominance for possibly-terminal writes
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        out.extend(_check_settlement(fn, rel, job))
    return out


def _stmt_has_call(stmt: ast.stmt, pred) -> bool:
    for tree in cfglib.own_subtrees(stmt):
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and pred(node):
                return True
    return False


def _check_settlement(fn, rel: str, job) -> List[Finding]:
    # transition sites directly in this function (not in nested defs)
    sites: List[Tuple[ast.stmt, ast.expr]] = []

    COMPOUND = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef,
                ast.If, ast.While, ast.For, ast.AsyncFor, ast.Try, ast.With,
                ast.AsyncWith)

    def scan(stmts: Sequence[ast.stmt]):
        for stmt in stmts:
            if isinstance(stmt, COMPOUND):
                continue        # bodies are visited as their own statements
            for node in ast.walk(stmt):
                if isinstance(node, ast.Call):
                    arg = _transition_state_arg(node)
                    if arg is not None:
                        sites.append((stmt, arg))

    # walk only this function's own statements
    def own_stmts(node):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                continue
            if isinstance(child, ast.stmt):
                yield child
                yield from own_stmts(child)
            elif hasattr(child, "body"):
                yield from own_stmts(child)

    stmts = list(own_stmts(fn))
    scan(stmts)
    params = {a.arg for a in fn.args.args + fn.args.kwonlyargs}
    sites = [(s, a) for s, a in sites
             # a constant non-terminal state needs no settlement
             if not (isinstance(a, ast.Constant)
                     and a.value not in job.terminal)
             # a state forwarded from the function's own parameter is a
             # wrapper (update_job); settlement is checked at call sites,
             # which pass the state as a constant or local
             and not (isinstance(a, ast.Name) and a.id in params)]
    if not sites:
        return []

    graph = cfglib.CFG(fn)
    dom = cfglib.dominators(graph)
    pdom = cfglib.postdominators(graph)
    settle_nodes = set(graph.nodes_for(lambda s: _stmt_has_call(
        s, lambda c: isinstance(c.func, ast.Attribute)
        and c.func.attr in SETTLE_ATTRS)))
    release_nodes = set(graph.nodes_for(lambda s: _stmt_has_call(
        s, lambda c: _dotted(c.func).split(".")[-1] in RELEASE_NAMES)))

    out: List[Finding] = []
    for stmt, arg in sites:
        ids = [i for i, s in enumerate(graph.stmts) if s is stmt]
        if not ids:
            continue
        t = ids[0]
        covered = dom[t] | pdom[t]
        label = arg.value if isinstance(arg, ast.Constant) else "<dynamic>"
        if not (settle_nodes & covered):
            out.append(Finding(
                RULE_ID, rel, stmt.lineno,
                f"terminal transition to {label} in {fn.name}() is not "
                f"covered by a metering settle (job_stopped)"))
        if not (release_nodes & covered):
            out.append(Finding(
                RULE_ID, rel, stmt.lineno,
                f"terminal transition to {label} in {fn.name}() is not "
                f"covered by a resource release "
                f"(_teardown/_rollback/release_gang)"))
    return out


def check(root: Optional[Path] = None, machines=None) -> List[Finding]:
    if machines is None:
        machines = _machines()
    findings: List[Finding] = []
    states_path = "src/repro/core/states.py"
    for m in machines:
        findings.extend(_check_machine(m, states_path))
    core, rel_base = _core_dir(root)
    if core.is_dir():
        for py in sorted(core.glob("*.py")):
            if py.name == "states.py":
                continue
            rel = f"{rel_base}/{py.name}"
            try:
                tree = ast.parse(py.read_text(), filename=str(py))
            except SyntaxError:
                continue        # SC100 owns parseability
            findings.extend(_check_file(tree, rel, machines))
    return findings
