"""Tiny intraprocedural control-flow graph over Python AST statements.

Shared substrate for the SC3xx lifecycle/resource checkers.  Nodes are
individual ``ast.stmt`` objects plus three virtual nodes: ENTRY, EXIT
(normal return or fall-off-the-end) and RAISE (an exception leaves the
function).  Edge construction:

* sequential statement flow; ``if`` branches carry an optional
  ``(var, "is_none" | "not_none")`` annotation when the test is a
  ``X is None`` / ``X is not None`` comparison, so clients can be
  lightly path-sensitive about None-guarded acquisitions;
* ``while`` / ``for`` model zero or one-plus iterations (body loops
  back to the header; the ``else`` clause runs on normal exhaustion);
* every statement inside a ``try`` body also edges to the try's
  handler-dispatch node — any statement may raise mid-way.  Exception
  edges are marked ``exc=True`` so clients can propagate the
  *pre-statement* state along them (if the statement raised, its own
  acquisitions never happened);
* an explicit ``raise`` edges to the innermost enclosing dispatch node,
  or to RAISE when uncaught.  Implicit exceptions from calls *outside*
  any try are not modeled — documented under-approximation; explicit
  raises and in-try statements are the checked class;
* ``finally`` bodies run on the normal path only (good enough for this
  repo's idiom, which has no try/finally around resource acquisition).

Also provides dominator and post-dominator sets.  Post-dominance is
computed w.r.t. normal exit only (EXIT, not RAISE): SC301 uses it to ask
"does every *completed* run of this function settle?" — exceptional
exits are the restart path, settled by the next guardian incarnation.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

ENTRY, EXIT, RAISE = 0, 1, 2

Cond = Optional[Tuple[str, str]]        # (var, "is_none" | "not_none")


@dataclass(frozen=True)
class Edge:
    dst: int
    cond: Cond = None
    exc: bool = False


def _none_test(test: ast.expr) -> Tuple[Cond, Cond]:
    """Return (true-branch cond, false-branch cond) for ``X is None``-style
    tests, or (None, None) when the test is anything else."""
    if (isinstance(test, ast.Compare) and len(test.ops) == 1
            and isinstance(test.left, ast.Name)
            and isinstance(test.comparators[0], ast.Constant)
            and test.comparators[0].value is None):
        var = test.left.id
        if isinstance(test.ops[0], ast.Is):
            return (var, "is_none"), (var, "not_none")
        if isinstance(test.ops[0], ast.IsNot):
            return (var, "not_none"), (var, "is_none")
    return None, None


def own_subtrees(stmt: ast.AST) -> List[ast.AST]:
    """The parts of a statement that belong to its CFG node itself.

    Compound statements contribute only their header expressions — their
    bodies are separate CFG nodes, and scanning the whole subtree would
    double-count body events at the header.
    """
    if isinstance(stmt, (ast.If, ast.While)):
        return [stmt.test]
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return [stmt.target, stmt.iter]
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        return [i.context_expr for i in stmt.items]
    if isinstance(stmt, (ast.Try, ast.ExceptHandler, ast.FunctionDef,
                         ast.AsyncFunctionDef, ast.ClassDef)):
        return []
    return [stmt]


class CFG:
    def __init__(self, fn: ast.AST):
        self.fn = fn
        # index-parallel arrays; 0..2 are the virtual nodes
        self.stmts: List[Optional[ast.stmt]] = [None, None, None]
        self.edges: List[List[Edge]] = [[], [], []]
        exits = self._seq(fn.body, [(ENTRY, None)], {"handlers": []})
        self._link(exits, EXIT)

    # -- construction ---------------------------------------------------
    def _new(self, stmt: Optional[ast.stmt]) -> int:
        self.stmts.append(stmt)
        self.edges.append([])
        return len(self.stmts) - 1

    def _link(self, pending: List[Tuple[int, Cond]], dst: int,
              exc: bool = False) -> None:
        for src, cond in pending:
            self.edges[src].append(Edge(dst, cond, exc))

    def _seq(self, body: List[ast.stmt], pending, ctx):
        for stmt in body:
            pending = self._stmt(stmt, pending, ctx)
        return pending

    def _stmt(self, stmt: ast.stmt, pending, ctx):
        n = self._new(stmt)
        self._link(pending, n)
        # any statement inside a try body may raise into its handlers
        for dispatch in ctx["handlers"]:
            self.edges[n].append(Edge(dispatch, None, exc=True))

        if isinstance(stmt, ast.Return):
            self.edges[n].append(Edge(EXIT))
            return []
        if isinstance(stmt, ast.Raise):
            target = ctx["handlers"][-1] if ctx["handlers"] else RAISE
            self.edges[n].append(Edge(target, None, exc=True))
            return []
        if isinstance(stmt, ast.Break):
            ctx["break"].append((n, None))
            return []
        if isinstance(stmt, ast.Continue):
            self.edges[n].append(Edge(ctx["continue"]))
            return []
        if isinstance(stmt, ast.If):
            t_cond, f_cond = _none_test(stmt.test)
            t_exit = self._seq(stmt.body, [(n, t_cond)], ctx)
            f_exit = self._seq(stmt.orelse, [(n, f_cond)], ctx)
            return t_exit + f_exit
        if isinstance(stmt, (ast.While, ast.For)):
            t_cond, f_cond = (None, None)
            if isinstance(stmt, ast.While):
                t_cond, f_cond = _none_test(stmt.test)
            loop_ctx = dict(ctx)
            loop_ctx["break"] = []
            loop_ctx["continue"] = n
            body_exit = self._seq(stmt.body, [(n, t_cond)], loop_ctx)
            self._link(body_exit, n)                    # back-edge
            out = self._seq(stmt.orelse, [(n, f_cond)], ctx)
            return out + loop_ctx["break"]
        if isinstance(stmt, ast.Try):
            dispatch = self._new(None)                  # handler dispatch
            body_ctx = dict(ctx)
            body_ctx["handlers"] = ctx["handlers"] + [dispatch]
            body_exit = self._seq(stmt.body, [(n, None)], body_ctx)
            body_exit = self._seq(stmt.orelse, body_exit, ctx)
            out = list(body_exit)
            for handler in stmt.handlers:
                h = self._new(handler)                  # `except X as e:`
                self.edges[dispatch].append(Edge(h, None, exc=True))
                out += self._seq(handler.body, [(h, None)], ctx)
            if not stmt.handlers:                       # try/finally only
                target = ctx["handlers"][-1] if ctx["handlers"] else RAISE
                self.edges[dispatch].append(Edge(target, None, exc=True))
            out = self._seq(stmt.finalbody, out, ctx)
            return out
        if isinstance(stmt, ast.With):
            return self._seq(stmt.body, [(n, None)], ctx)
        # FunctionDef/ClassDef/simple statements: opaque single node
        return [(n, None)]

    # -- queries --------------------------------------------------------
    def succs(self, i: int) -> List[Edge]:
        return self.edges[i]

    def preds(self) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {i: [] for i in range(len(self.stmts))}
        for src, es in enumerate(self.edges):
            for e in es:
                out[e.dst].append(src)
        return out

    def nodes_for(self, pred) -> List[int]:
        """Node ids whose statement satisfies ``pred(stmt)``."""
        return [i for i, s in enumerate(self.stmts)
                if s is not None and pred(s)]


def _dom(n_nodes: int, roots: Set[int],
         preds: Dict[int, List[int]]) -> Dict[int, Set[int]]:
    """Generic dominator solve: node d dominates n iff every path from a
    root to n passes through d.  Pass reversed edges for post-dominators."""
    full = set(range(n_nodes))
    dom = {i: ({i} if i in roots else set(full)) for i in range(n_nodes)}
    changed = True
    while changed:
        changed = False
        for i in range(n_nodes):
            if i in roots:
                continue
            ps = preds[i]
            new = set(full)
            for p in ps:
                new &= dom[p]
            if not ps:
                new = set()             # unreachable from the roots
            new |= {i}
            if new != dom[i]:
                dom[i] = new
                changed = True
    return dom


def dominators(cfg: CFG) -> Dict[int, Set[int]]:
    return _dom(len(cfg.stmts), {ENTRY}, cfg.preds())


def postdominators(cfg: CFG) -> Dict[int, Set[int]]:
    """Post-dominators w.r.t. normal exit (EXIT only, not RAISE)."""
    succs = {i: [e.dst for e in es] for i, es in enumerate(cfg.edges)}
    return _dom(len(cfg.stmts), {EXIT}, succs)
