"""SC202 — abstract evaluation of the Pallas kernel grid layouts.

Every kernel module exposes a ``*_layout(...)`` returning the
:class:`repro.kernels.layout.KernelLayout` its ``pallas_call`` is built
from, so checking the layout checks the shipped kernel.  For
representative (small, structure-preserving) sizes the checker walks
every grid point and proves, per layout:

* each index map returns one block index per block dimension, in bounds
  for the operand's logical block grid;
* every output block is written, and two grid points mapping to the same
  output block differ only in ``"arbitrary"`` (sequential) grid dims —
  the exactly-once / accumulate-in-scratch discipline;
* accumulator scratch buffers are float32 (online-softmax / state carry
  precision);
* the paged-decode page walk — for the GQA pool layouts (grouped
  head-tile grid and ungrouped, across head-tile shapes including
  G > 4) and the MLA latent-pool layout alike — evaluated against
  adversarial page tables (contiguous, mostly-empty, holes inside the
  live prefix, inactive rows): block indices stay inside the physical
  pool, ``-1`` holes borrow an already-live page of the *same row*
  (never physical page 0's bandwidth), and every dead-tail step repeats
  the previous page so the pipeline issues no new DMA (the NaN-gather /
  wasted-bandwidth class the flash-decode PR fixed by hand).
"""
from __future__ import annotations

import itertools
from typing import List

from repro.staticcheck.engine import Finding

RULE_ID = "SC202"


def _blocks(shape, block):
    """Logical block-grid extent per dimension (ceil division)."""
    return tuple(-(-s // b) for s, b in zip(shape, block))


def _check_layout(layout, path: str,
                  grid_args=None) -> List[Finding]:
    """Walk every grid point of ``layout``; ``grid_args`` maps a grid
    point to the full index_map argument tuple (identity when None —
    used by scalar-prefetch layouts to append the prefetched operands)."""
    out: List[Finding] = []

    def fail(msg: str) -> None:
        out.append(Finding(RULE_ID, path, 0, f"{layout.name}: {msg}"))

    if len(layout.dimension_semantics) != len(layout.grid):
        fail(f"dimension_semantics arity {layout.dimension_semantics} != "
             f"grid arity {layout.grid}")
        return out
    for shape, dtype in layout.scratch:
        import jax.numpy as jnp
        if jnp.dtype(dtype) != jnp.float32:
            fail(f"scratch {shape} is {jnp.dtype(dtype)}; accumulators "
                 "must be float32")

    points = list(itertools.product(*(range(g) for g in layout.grid)))
    arb = [d for d, s in enumerate(layout.dimension_semantics)
           if s == "arbitrary"]

    for spec in tuple(layout.in_specs) + tuple(layout.out_specs):
        grid_of = _blocks(spec.shape, spec.block)
        for pt in points:
            args = grid_args(pt) if grid_args is not None else pt
            idx = tuple(int(v) for v in spec.index_map(*args))
            if len(idx) != len(spec.block):
                fail(f"{spec.name}: index map returned {len(idx)} indices "
                     f"for a {len(spec.block)}-dim block")
                break
            for d, (i, n) in enumerate(zip(idx, grid_of)):
                if not 0 <= i < n:
                    fail(f"{spec.name}: grid point {pt} maps dim {d} to "
                         f"block {i}, outside [0, {n})")
                    break
            else:
                continue
            break

    for spec in layout.out_specs:
        grid_of = _blocks(spec.shape, spec.block)
        writers: dict = {}
        for pt in points:
            args = grid_args(pt) if grid_args is not None else pt
            idx = tuple(int(v) for v in spec.index_map(*args))
            writers.setdefault(idx, []).append(pt)
        expected = set(itertools.product(*(range(n) for n in grid_of)))
        missing = expected - set(writers)
        if missing:
            fail(f"{spec.name}: {len(missing)} output block(s) never "
                 f"written, e.g. {sorted(missing)[0]}")
        for idx, pts in writers.items():
            base = pts[0]
            for p in pts[1:]:
                diff = [d for d in range(len(p)) if p[d] != base[d]]
                bad = [d for d in diff if d not in arb]
                if bad:
                    fail(f"{spec.name}: output block {idx} written from "
                         f"grid points {base} and {p}, which differ in "
                         f"non-arbitrary dim(s) {bad} — same block would "
                         "be computed twice in parallel")
                    break
    return out


def _check_simple_layouts() -> List[Finding]:
    from repro.kernels.flash_attention import flash_layout
    from repro.kernels.rglru_scan import rglru_layout
    from repro.kernels.rwkv6_wkv import wkv_layout

    out: List[Finding] = []
    out += _check_layout(
        flash_layout(BH=4, Sq=256, Sk=256, hd=8, q_blk=128, kv_blk=128,
                     group=2),
        "src/repro/kernels/flash_attention.py")
    out += _check_layout(
        wkv_layout(BH=2, S=64, N=16, chunk=32),
        "src/repro/kernels/rwkv6_wkv.py")
    out += _check_layout(
        rglru_layout(B=2, S=32, R=64, t_blk=16, r_blk=32),
        "src/repro/kernels/rglru_scan.py")
    return out


def _paged_tables():
    """Adversarial (page_table, pos_q) pairs: contiguous prefix, nearly
    empty, -1 hole inside the live prefix, inactive row."""
    import numpy as np
    pt = np.array([
        [2, 3, 4, 5],      # fully allocated, live through page 3 (pos 13)
        [6, -1, -1, -1],   # one live page (pos 1); dead tail
        [7, -1, 5, -1],    # hole at slot 1 inside the live prefix (pos 9)
        [-1, -1, -1, -1],  # inactive row
    ], dtype=np.int32)
    pos = np.array([13, 1, 9, -1], dtype=np.int32)
    return pt, pos


def _walk_page_specs(layout, path, pt_np, pos_np, pt, pos, ps, n_pool,
                     points_for) -> List[Finding]:
    """Adversarial page walk over every ``*_pages`` operand of ``layout``.

    ``points_for(b, i)`` yields the grid point(s) covering row ``b`` at
    page-table step ``i`` (several for head-tiled grids).  The physical
    page is the first block coordinate the index map returns."""
    out: List[Finding] = []

    def fail(msg: str) -> None:
        out.append(Finding(RULE_ID, path, 0, f"{layout.name}: {msg}"))

    B, pps = pt_np.shape
    kv = [s for s in layout.in_specs if s.name.endswith("_pages")]
    if not kv:
        fail("no *_pages operand found — page walk unchecked")
        return out
    for spec in kv:
        for b in range(B):
            live = {int(e) for e in pt_np[b] if e >= 0}
            last_live = max(int(pos_np[b]), 0) // ps
            prev: dict = {}
            for i in range(pps):
                for point in points_for(b, i):
                    page = int(spec.index_map(*point, pt, pos)[0])
                    if not 0 <= page < n_pool:
                        fail(f"{spec.name}: row {b} step {i} fetches "
                             f"physical page {page}, outside the pool "
                             f"[0, {n_pool})")
                    if pos_np[b] >= 0 and i <= last_live \
                            and pt_np[b, i] < 0 and page not in live:
                        fail(f"{spec.name}: row {b} has a -1 hole at slot "
                             f"{i} but fetches page {page}, not an "
                             f"already-live page of that row {sorted(live)}"
                             " — holes must cost no extra bandwidth")
                    key = point[:-1]       # pipeline: page dim is last
                    if i > last_live and key in prev \
                            and page != prev[key]:
                        fail(f"{spec.name}: dead-tail step {i} of row {b} "
                             f"fetches page {page} != previous {prev[key]} "
                             "— the tail must repeat its block index so no "
                             "new DMA is issued")
                    prev[key] = page
    return out


def _check_paged() -> List[Finding]:
    import jax.numpy as jnp
    from repro.kernels.paged_attention import group_tile, paged_layout

    path = "src/repro/kernels/paged_attention.py"
    out: List[Finding] = []
    pt_np, pos_np = _paged_tables()
    pt, pos = jnp.asarray(pt_np), jnp.asarray(pos_np)
    B, pps = pt_np.shape
    ps, n_pool = 4, 8

    # (K, G) sweeps the head-tile grid: kt = K (one tile), kt < K
    # (several tiles per row), and the large-G regime the tiler exists
    # for (G > 4, kt clamps to 1)
    for K, G, grouped in ((2, 2, True), (4, 2, True), (4, 8, True),
                          (2, 2, False)):
        layout = paged_layout(B=B, K=K, G=G, hd=8, ps=ps, pps=pps,
                              n_pool=n_pool, grouped=grouped)
        # structural walk: index maps see the prefetched (pt, pos) operands
        out += _check_layout(layout, path,
                             grid_args=lambda p: p + (pt, pos))
        # both the grouped head-tile grid (B, K//kt, pps) and the
        # ungrouped grid (B, K, pps) iterate heads in dim 1
        n_t = K // group_tile(K, G) if grouped else K
        out += _walk_page_specs(
            layout, path, pt_np, pos_np, pt, pos, ps, n_pool,
            lambda b, i: [(b, t, i) for t in range(n_t)])
    return out


def _check_mla_paged() -> List[Finding]:
    import jax.numpy as jnp
    from repro.kernels.paged_attention import mla_paged_layout

    path = "src/repro/kernels/paged_attention.py"
    out: List[Finding] = []
    pt_np, pos_np = _paged_tables()
    pt, pos = jnp.asarray(pt_np), jnp.asarray(pos_np)
    B, pps = pt_np.shape
    ps, n_pool = 4, 8

    layout = mla_paged_layout(B=B, H=2, lora=8, rd=4, ps=ps, pps=pps,
                              n_pool=n_pool)
    out += _check_layout(layout, path, grid_args=lambda p: p + (pt, pos))
    # latent grid is (B, pps): one fused block walks both latent pools
    out += _walk_page_specs(
        layout, path, pt_np, pos_np, pt, pos, ps, n_pool,
        lambda b, i: [(b, i)])
    return out


def check() -> List[Finding]:
    return _check_simple_layouts() + _check_paged() + _check_mla_paged()
