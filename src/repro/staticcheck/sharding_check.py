"""SC201 — every registered config must shard cleanly on BOTH production
meshes.

For each ``repro.configs`` architecture the checker builds the *abstract*
parameter and KV-cache trees (no allocation) and maps every leaf through
the ``dist.sharding`` rule table on the single-pod (data=16, model=16)
and multi-pod (pod=2, data=16, model=16) abstract meshes.  Each resulting
PartitionSpec is then re-validated by an **independent** walker (not the
code under test):

* every mesh axis the spec names exists on the mesh;
* no mesh axis is consumed twice within one spec (use-once);
* the product of axis sizes on each dimension divides that dimension;
* ``check_cache_locality`` accepts the cache tree (ring-buffer slot dims
  and metadata dims replicated);
* the rule table itself only names known mesh-axis vocabulary
  ({pod, data, model, fsdp, tensor}).

This turns "does a new arch config shard?" from a dry-run compile into a
static check that runs on a 1-CPU host in seconds.
"""
from __future__ import annotations

from typing import List

from repro.staticcheck.engine import Finding

RULE_ID = "SC201"
PATH = "src/repro/dist/sharding.py"

#: Every mesh-axis name either production-mesh vocabulary may use.
MESH_VOCAB = frozenset({"pod", "data", "model", "fsdp", "tensor"})


def _spec_axes(entry):
    if entry is None:
        return ()
    return entry if isinstance(entry, tuple) else (entry,)


def _validate_spec(name: str, spec, shape, sizes) -> List[str]:
    """Independent well-formedness walk of one PartitionSpec."""
    problems: List[str] = []
    entries = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    seen: set = set()
    for d, (entry, dim) in enumerate(zip(entries, shape)):
        axes = _spec_axes(entry)
        prod = 1
        for ax in axes:
            if ax not in sizes:
                problems.append(
                    f"{name}: dim {d} sharded over {ax!r} which is not a "
                    f"mesh axis of {sorted(sizes)}")
                continue
            if ax in seen:
                problems.append(
                    f"{name}: mesh axis {ax!r} used twice in one spec")
            seen.add(ax)
            prod *= sizes[ax]
        if prod and dim % prod != 0:
            problems.append(
                f"{name}: dim {d} of size {dim} not divisible by shard "
                f"factor {prod} ({axes})")
    return problems


def check() -> List[Finding]:
    from repro.configs.base import SHAPES, get_config, list_configs
    from repro.dist.mesh import make_abstract_production_mesh
    from repro.dist.sharding import (
        DEFAULT_RULES, _mesh_sizes, check_cache_locality, logical_to_spec)
    from repro.launch.specs import _cache_ab
    from repro.models import params as MP
    import jax

    findings: List[Finding] = []

    # the rule table may only name production/generic mesh vocabulary
    for logical, axes in DEFAULT_RULES.rules:
        unknown = [a for a in axes if a not in MESH_VOCAB]
        if unknown:
            findings.append(Finding(
                RULE_ID, PATH, 0,
                f"rule table maps {logical!r} to unknown mesh axes "
                f"{unknown}; vocabulary is {sorted(MESH_VOCAB)}"))

    decode_shape = SHAPES["decode_32k"]
    meshes = [("prod", make_abstract_production_mesh()),
              ("multipod", make_abstract_production_mesh(multi_pod=True))]

    import dataclasses

    for cfg_name in list_configs():
        cfg = get_config(cfg_name)
        params_ab = MP.abstract_params(cfg)
        cache_ab = _cache_ab(cfg, decode_shape)
        # the serving fast path runs every config paged regardless of its
        # default layout — the page pools (including MLA latent pools)
        # must shard on both meshes too
        paged_ab = _cache_ab(
            dataclasses.replace(cfg, cache_layout="paged"), decode_shape)
        # optimizer (Adam m/v) and gradient-compression error-feedback
        # state mirror the params tree leaf-for-leaf with replicated
        # scalar counters — the same shapes launch.specs.state_specs
        # materializes, so a params leaf that shards is not enough: its
        # optimizer mirrors must go through the rule table too.
        scalar_ab = MP.ParamAb(shape=(), logical_axes=())
        opt_ab = {"m": params_ab, "v": params_ab, "count": scalar_ab}
        err_ab = {"err": params_ab}
        for mesh_name, mesh in meshes:
            sizes = _mesh_sizes(mesh)
            for tree_name, tree in (("params", params_ab),
                                    ("cache", cache_ab),
                                    ("cache_paged", paged_ab),
                                    ("opt", opt_ab),
                                    ("err", err_ab)):
                leaves, _ = jax.tree_util.tree_flatten_with_path(
                    tree, is_leaf=lambda x: hasattr(x, "logical_axes"))
                for path, ab in leaves:
                    leaf = "/".join(
                        str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
                    where = f"{cfg_name}@{mesh_name}:{tree_name}/{leaf}"
                    try:
                        spec = logical_to_spec(
                            ab.logical_axes, ab.shape, mesh)
                    except KeyError as e:
                        findings.append(Finding(
                            RULE_ID, PATH, 0,
                            f"{where}: no sharding rule — {e}"))
                        continue
                    for msg in _validate_spec(where, spec, ab.shape, sizes):
                        findings.append(Finding(RULE_ID, PATH, 0, msg))
            for lay_name, tree in (("cache", cache_ab),
                                   ("cache_paged", paged_ab)):
                try:
                    check_cache_locality(tree, mesh)
                except ValueError as e:
                    findings.append(Finding(
                        RULE_ID, PATH, 0,
                        f"{cfg_name}@{mesh_name}: {lay_name} locality — {e}"))
    return findings
