"""SC203 — snapshot/restore/journal field-drift checker.

``ServingEngine.snapshot()`` / ``restore()`` and the server's snapshot
envelope evolve together; a field added to one side but not the other
silently loses state across a pod restart (exactly the failure the
resumable-engine PR guards with runtime tests — this checker catches the
drift at lint time, before any engine is built).  All checks are AST
reflection over ``launch/engine.py`` and ``core/server.py``:

* every key ``snapshot`` emits is read back by ``restore`` (keys proven
  snapshot-only — today ``journal_len``, asserted by the engine tests —
  live on an explicit allowlist), and restore reads nothing snapshot
  doesn't emit;
* the per-slot ``rec_doc`` document carries every ``SeqRecord`` field
  (the ``request`` object is flattened to ``req``/``tokens``/``gen_len``)
  and restore's ``SeqRecord`` reconstruction reads exactly those keys;
* the ``stats`` sub-dict round-trips key-for-key;
* every journal event appended (engine ``self.journal.append`` and the
  server's volume journal) carries at least ``ev`` and ``req`` — replay
  dispatches on those two;
* every key the server adds to the snapshot envelope (``snap_doc[...] =``)
  is read somewhere (server or engine restore).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Set

from repro.staticcheck.engine import Finding

RULE_ID = "SC203"
ENGINE = "src/repro/launch/engine.py"
SERVER = "src/repro/core/server.py"

#: snapshot keys intentionally not read by restore (each must be asserted
#: snapshot-only by a runtime test; see tests/test_engine.py).
SNAPSHOT_ONLY: Set[str] = {"journal_len"}
#: SeqRecord.request is flattened into these rec_doc keys.
REQUEST_KEYS: Set[str] = {"req", "tokens", "gen_len"}


def _const_keys(d: ast.Dict) -> Set[str]:
    return {k.value for k in d.keys
            if isinstance(k, ast.Constant) and isinstance(k.value, str)}


def _sub_reads(node: ast.AST, name: str) -> Set[str]:
    """String keys read from ``name`` via ``name["k"]`` or ``name.get("k"``."""
    out: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Subscript) and isinstance(n.value, ast.Name) \
                and n.value.id == name \
                and isinstance(n.slice, ast.Constant) \
                and isinstance(n.slice.value, str):
            out.add(n.slice.value)
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute) \
                and n.func.attr == "get" \
                and isinstance(n.func.value, ast.Name) \
                and n.func.value.id == name and n.args \
                and isinstance(n.args[0], ast.Constant) \
                and isinstance(n.args[0].value, str):
            out.add(n.args[0].value)
    return out


def _find(tree: ast.AST, kind, name: str):
    for n in ast.walk(tree):
        if isinstance(n, kind) and n.name == name:
            return n
    return None


def _return_dict(fn: ast.FunctionDef) -> Optional[ast.Dict]:
    for n in ast.walk(fn):
        if isinstance(n, ast.Return) and isinstance(n.value, ast.Dict):
            return n.value
    return None


def _parse(root: Optional[Path], rel: str):
    """Parse ``rel`` under ``root``; with no root, resolve via the live
    module's ``__file__`` so the checker works from any cwd."""
    if root is not None:
        p = root / rel
    else:
        import importlib
        mod = "repro." + rel.split("repro/", 1)[1][:-3].replace("/", ".")
        p = Path(importlib.import_module(mod).__file__)
    return ast.parse(p.read_text(), filename=rel) if p.exists() else None


def check(root: Optional[Path] = None) -> List[Finding]:
    findings: List[Finding] = []

    def fail(path: str, node, msg: str) -> None:
        findings.append(
            Finding(RULE_ID, path, getattr(node, "lineno", 0), msg))

    eng = _parse(root, ENGINE)
    if eng is None:
        return [Finding(RULE_ID, ENGINE, 0, "engine.py not found under "
                        f"{root} — drift check could not run")]

    snapshot = _find(eng, ast.FunctionDef, "snapshot")
    restore = _find(eng, ast.FunctionDef, "restore")
    rec_doc = _find(eng, ast.FunctionDef, "rec_doc")
    seqrec = _find(eng, ast.ClassDef, "SeqRecord")
    if not all((snapshot, restore, rec_doc, seqrec)):
        return [Finding(RULE_ID, ENGINE, 0,
                        "snapshot/restore/rec_doc/SeqRecord not found — "
                        "drift check could not run")]

    # 1. top-level snapshot keys ↔ restore reads of ``snap``
    snap_dict = _return_dict(snapshot)
    if snap_dict is None:
        fail(ENGINE, snapshot, "snapshot() does not return a dict literal")
        return findings
    snap_keys = _const_keys(snap_dict)
    restore_reads = _sub_reads(restore, "snap")
    for k in sorted(snap_keys - restore_reads - SNAPSHOT_ONLY):
        fail(ENGINE, snapshot, f"snapshot emits {k!r} but restore never "
             "reads it — state lost across pod restart")
    for k in sorted(restore_reads - snap_keys):
        fail(ENGINE, restore, f"restore reads snap[{k!r}] which snapshot "
             "never emits")
    for k in sorted(SNAPSHOT_ONLY - snap_keys):
        fail(ENGINE, snapshot, f"SNAPSHOT_ONLY lists {k!r} but snapshot "
             "no longer emits it — prune the allowlist")

    # 2. rec_doc keys ↔ SeqRecord fields ↔ restore's doc[...] reads
    rec_keys = set()
    doc = _return_dict(rec_doc)
    if doc is not None:
        rec_keys = _const_keys(doc)
    fields = {s.target.id for s in seqrec.body
              if isinstance(s, ast.AnnAssign)
              and isinstance(s.target, ast.Name)}
    expected = (fields - {"request"}) | REQUEST_KEYS
    for k in sorted(expected - rec_keys):
        fail(ENGINE, rec_doc, f"SeqRecord field {k!r} missing from "
             "rec_doc — slot state lost across restore")
    for k in sorted(rec_keys - expected):
        fail(ENGINE, rec_doc, f"rec_doc emits {k!r} which is not a "
             "SeqRecord field — restore cannot place it")
    doc_reads = _sub_reads(restore, "doc")
    for k in sorted(rec_keys - doc_reads):
        fail(ENGINE, restore, f"rec_doc emits {k!r} but restore never "
             f"reads doc[{k!r}]")

    # 3. stats sub-dict round-trip
    stats_dict = None
    for k, v in zip(snap_dict.keys, snap_dict.values):
        if isinstance(k, ast.Constant) and k.value == "stats" \
                and isinstance(v, ast.Dict):
            stats_dict = v
    if stats_dict is None:
        fail(ENGINE, snapshot, "snapshot has no literal 'stats' dict")
    else:
        stats_keys = _const_keys(stats_dict)
        st_reads = _sub_reads(restore, "st")
        for k in sorted(stats_keys - st_reads):
            fail(ENGINE, restore, f"stats key {k!r} never restored")
        for k in sorted(st_reads - stats_keys):
            fail(ENGINE, restore, f"restore reads stats key {k!r} which "
                 "snapshot never emits")

    # 4. journal events carry ev + req (replay dispatches on these)
    def journal_dicts(tree: ast.AST, attr: str):
        for n in ast.walk(tree):
            if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute) \
                    and n.func.attr == "append":
                recv = n.func.value
                is_journal = (isinstance(recv, ast.Attribute)
                              and recv.attr == attr)
                is_vol = (isinstance(recv, ast.Name) and attr == "vol"
                          and recv.id == "vol")
                if not (is_journal or is_vol):
                    continue
                for arg in n.args:
                    if isinstance(arg, ast.Dict):
                        yield n, arg

    for path, tree, attr in ((ENGINE, eng, "journal"),):
        for call, d in journal_dicts(tree, attr):
            missing = {"ev", "req"} - _const_keys(d)
            if missing:
                fail(path, call, f"journal event missing key(s) "
                     f"{sorted(missing)} — replay dispatches on ev/req")

    # 5. server snapshot envelope: every snap_doc write is read somewhere
    srv = _parse(root, SERVER)
    if srv is None:
        findings.append(Finding(RULE_ID, SERVER, 0,
                                "server.py not found — envelope unchecked"))
        return findings
    writes: Dict[str, ast.AST] = {}
    for n in ast.walk(srv):
        if isinstance(n, ast.Assign):
            for t in n.targets:
                if isinstance(t, ast.Subscript) \
                        and isinstance(t.value, ast.Name) \
                        and t.value.id == "snap_doc" \
                        and isinstance(t.slice, ast.Constant) \
                        and isinstance(t.slice.value, str):
                    writes[t.slice.value] = n
    env_reads = _sub_reads(srv, "snap") | restore_reads
    for k, node in sorted(writes.items()):
        if k not in env_reads:
            fail(SERVER, node, f"snapshot envelope key {k!r} written but "
                 "never read — dead recovery state")
    for call, d in journal_dicts(srv, "vol"):
        missing = {"ev", "req"} - _const_keys(d)
        if missing:
            fail(SERVER, call, f"volume journal event missing key(s) "
                 f"{sorted(missing)} — replay dispatches on ev/req")
    return findings
