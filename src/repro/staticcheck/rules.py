"""The dependability AST rules — each one generalizes a bug class a
previous PR fixed by hand (ids and history in README §Static
dependability checks).

Scope convention: pod/payload code paths are everything under
``repro/core/`` plus ``repro/launch/engine.py`` — code a platform workload
pod executes under the sim's ``except Exception`` sandbox.  The launch
CLIs (``train``/``serve``/``dryrun``/``perf``/``analysis``/``executor``)
are process entry points where ``SystemExit`` is the *correct* failure
mode, so SC101 excludes them; wall-clock (SC105) is banned across all of
``core/`` and ``launch/`` because artifacts and journals from either tree
feed deterministic-replay tests (monotonic interval clocks —
``time.perf_counter``/``time.monotonic`` — stay legal).
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Tuple

from repro.staticcheck.engine import Finding, Rule

#: Code reachable from inside a platform workload pod.
POD_SCOPES: Tuple[str, ...] = ("repro/core/", "repro/launch/engine.py")
#: Sim-driven + artifact-producing trees (deterministic replay).
SIM_SCOPES: Tuple[str, ...] = ("repro/core/", "repro/launch/")


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression (``a.b.c`` → "a.b.c")."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


class ExitInPodRule(Rule):
    """SC101 — no ``SystemExit``/``sys.exit``/``os._exit`` in pod code.

    The sim drives pod generators under ``except Exception``; SystemExit
    derives from BaseException, so a pod raising it escapes the sandbox
    and kills every co-tenant job with the simulator (the PR 5 post-review
    class: engine-constructor errors must be ValueError; the CLI maps them
    back to SystemExit at the process boundary)."""

    id = "SC101"
    title = "SystemExit reachable from pod/payload code"
    rationale = ("SystemExit escapes the sim's except Exception and kills "
                 "co-tenant jobs; raise ValueError/RuntimeError instead")
    scopes = POD_SCOPES

    def check(self, tree, lines, path) -> Iterable[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.Raise) and node.exc is not None:
                target = node.exc
                if isinstance(target, ast.Call):
                    target = target.func
                if isinstance(target, ast.Name) \
                        and target.id == "SystemExit":
                    yield self.finding(
                        path, node, "raise SystemExit in pod-reachable "
                        "code; use ValueError (CLI maps it at the "
                        "process boundary)")
            if isinstance(node, ast.Call):
                name = _dotted(node.func)
                if name in ("sys.exit", "os._exit"):
                    yield self.finding(
                        path, node, f"{name}() in pod-reachable code; "
                        "pods must fail their own job only")


class BuiltinHashRule(Rule):
    """SC102 — no builtin ``hash()`` on values that can reach persisted
    state.  Python hashes are salted per process (PYTHONHASHSEED), so a
    snapshot/journal/statestore entry keyed by ``hash()`` never matches
    after a restart — the prefix index uses chained blake2b for exactly
    this reason.  Scoped to the whole package: content addressing must be
    process-stable everywhere."""

    id = "SC102"
    title = "builtin hash() in persistence-adjacent code"
    rationale = ("builtin hash is salted per process; snapshots/journals "
                 "keyed by it break across restarts — use hashlib.blake2b")
    scopes = ("repro/",)

    def check(self, tree, lines, path) -> Iterable[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id == "hash":
                yield self.finding(
                    path, node, "builtin hash() is salted per process; "
                    "use hashlib.blake2b for anything that may be "
                    "persisted or compared across restarts")


class ObjectStoreRMWRule(Rule):
    """SC103 — no get+put read-modify-write on the same key.  Shipping n
    log lines by ``put(k, get(k) + line)`` writes O(n²) bytes (the PR 5
    ``_ship_log`` bug); ``ObjectStore.append`` grows the blob in place.
    Flags a ``.put`` whose arguments re-read the same receiver via
    ``.get``, and loops that both ``.get(k)`` and ``.put(k, ...)`` the
    same receiver+key."""

    id = "SC103"
    title = "ObjectStore read-modify-write (get+put) loop"
    rationale = ("put(k, get(k)+delta) is O(n^2) over n updates and races "
                 "concurrent writers; use ObjectStore.append")
    scopes = ("repro/",)

    @staticmethod
    def _calls(node: ast.AST, method: str) -> List[ast.Call]:
        out = []
        for n in ast.walk(node):
            if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute) \
                    and n.func.attr == method:
                out.append(n)
        return out

    def check(self, tree, lines, path) -> Iterable[Finding]:
        for node in ast.walk(tree):
            # direct RMW: x.put(k, ... x.get(k) ...)
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "put":
                recv = ast.dump(node.func.value)
                for arg in node.args + [kw.value for kw in node.keywords]:
                    for g in self._calls(arg, "get"):
                        if ast.dump(g.func.value) == recv:
                            yield self.finding(
                                path, node, "put() rebuilt from get() on "
                                "the same store — read-modify-write; use "
                                "append()")
            # loop-carried RMW: for/while body gets and puts the same key
            if isinstance(node, (ast.For, ast.While)):
                gets = {(ast.dump(g.func.value), ast.dump(g.args[0]))
                        for g in self._calls(node, "get") if g.args}
                for p in self._calls(node, "put"):
                    if p.args and (ast.dump(p.func.value),
                                   ast.dump(p.args[0])) in gets:
                        yield self.finding(
                            path, p, "get()+put() of the same key inside "
                            "a loop — read-modify-write; use append()")


class GlobalCounterRule(Rule):
    """SC104 — no module-global mutable counters in ``core/``.  A
    module-global id counter resets on process restart and bleeds across
    platform instances in one test process (the PR 3 job-id class);
    durable ids must go through ``MetadataStore.bump_counter``."""

    id = "SC104"
    title = "module-global mutable counter in core/"
    rationale = ("module globals reset on restart and bleed across "
                 "platform instances; durable ids go through "
                 "MetadataStore.bump_counter")
    scopes = ("repro/core/",)

    def check(self, tree, lines, path) -> Iterable[Finding]:
        module_ints = set()
        for node in tree.body if isinstance(tree, ast.Module) else []:
            if isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Constant) \
                    and isinstance(node.value.value, int):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        module_ints.add(t.id)
        if not module_ints:
            return
        for fn in ast.walk(tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            declared = {n for g in ast.walk(fn)
                        if isinstance(g, ast.Global) for n in g.names}
            mutated = declared & module_ints
            if not mutated:
                continue
            for n in ast.walk(fn):
                wrote = None
                if isinstance(n, ast.AugAssign) \
                        and isinstance(n.target, ast.Name) \
                        and n.target.id in mutated:
                    wrote = n
                elif isinstance(n, ast.Assign):
                    for t in n.targets:
                        if isinstance(t, ast.Name) and t.id in mutated:
                            wrote = n
                if wrote is not None:
                    yield self.finding(
                        path, wrote, "module-global counter mutation; "
                        "durable ids must use MetadataStore.bump_counter")


class WallClockRule(Rule):
    """SC105 — no wall-clock reads in sim-driven code.  The platform runs
    on virtual time (``sim.now``); ``time.time()``/``datetime.now()``
    values leaking into journals, snapshots, or artifacts make replay
    non-deterministic.  Monotonic *interval* clocks
    (``time.perf_counter``/``time.monotonic``) remain legal for CLI
    benchmark timing."""

    id = "SC105"
    title = "wall-clock read in sim-driven code"
    rationale = ("virtual-time code reading the wall clock breaks "
                 "deterministic replay; use sim.now (durations: "
                 "time.perf_counter)")
    # benchmarks mix sim-driven runs with CLI timing: the sanctioned
    # interval clocks stay legal, wall-clock timestamps do not
    scopes = SIM_SCOPES + ("benchmarks/",)

    BANNED = {
        "time.time", "time.time_ns", "time.localtime", "time.gmtime",
        "datetime.now", "datetime.utcnow", "datetime.today",
        "datetime.datetime.now", "datetime.datetime.utcnow",
        "datetime.datetime.today", "date.today", "datetime.date.today",
    }

    def check(self, tree, lines, path) -> Iterable[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                name = _dotted(node.func)
                if name in self.BANNED:
                    yield self.finding(
                        path, node, f"{name}() reads the wall clock; "
                        "sim-driven code uses sim.now, interval timing "
                        "uses time.perf_counter()")


class BroadExceptRule(Rule):
    """SC106 — no silent broad excepts in pod/sim code.  A bare
    ``except:`` or ``except BaseException`` swallows SystemExit and
    KeyboardInterrupt; an ``except Exception`` that neither re-raises nor
    binds-and-uses the exception turns any co-tenant-relevant bug into an
    invisible retry loop (the poisoned-pod class).  A broad handler must
    either ``raise`` or capture the exception (``as e``) and actually use
    it."""

    id = "SC106"
    title = "broad except swallows failures in pod/sim code"
    rationale = ("bare/BaseException excepts eat SystemExit; except "
                 "Exception without re-raise or use of the exception "
                 "hides poisoned-pod failures — narrow the type")
    scopes = SIM_SCOPES

    BROAD = ("Exception", "BaseException")

    @staticmethod
    def _is_broad(handler: ast.ExceptHandler) -> str:
        if handler.type is None:
            return "bare except"
        names = []
        t = handler.type
        elts = t.elts if isinstance(t, ast.Tuple) else [t]
        for e in elts:
            if isinstance(e, ast.Name):
                names.append(e.id)
        for n in names:
            if n in BroadExceptRule.BROAD:
                return f"except {n}"
        return ""

    def check(self, tree, lines, path) -> Iterable[Finding]:
        for node in ast.walk(tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            what = self._is_broad(node)
            if not what:
                continue
            if what in ("bare except", "except BaseException"):
                yield self.finding(
                    path, node, f"{what} also catches SystemExit/"
                    "KeyboardInterrupt; catch Exception at the very "
                    "widest")
                continue
            reraises = any(isinstance(n, ast.Raise)
                           for n in ast.walk(node))
            uses_exc = node.name is not None and any(
                isinstance(n, ast.Name) and n.id == node.name
                for b in node.body for n in ast.walk(b))
            if not reraises and not uses_exc:
                yield self.finding(
                    path, node, "except Exception that neither re-raises "
                    "nor uses the exception — narrow to the expected "
                    "failure type")


RULES = (
    ExitInPodRule,
    BuiltinHashRule,
    ObjectStoreRMWRule,
    GlobalCounterRule,
    WallClockRule,
    BroadExceptRule,
)
