"""SC302 — path-sensitive acquire/release pairing for declared resources.

For each declared :class:`ResourcePair`, every function in the pair's
scope is explored path-by-path over the little CFG in ``cfg.py``,
tracking the set of held acquisitions.  A path leaks when it:

* reaches normal exit still holding (unless the function is a declared
  *provider* — e.g. ``admit_gang`` exists to return holding quota);
* reaches an exceptional exit still holding (an explicit ``raise`` or a
  statement inside a ``try`` body) — the classic dropped-release-on-
  error-path bug;
* crosses a ``yield`` while holding a non-``crash_safe`` pair.  Pods in
  this platform crash *only at yields* (the sim checks the guard per
  step), so an acquisition held across a yield before it is recorded
  durably is exactly the crash window a restarted incarnation cannot
  roll back.

Holding stops when the path releases (``releases``), records ownership
durably (``transfers``, e.g. the guardian's ETCD ``record()``), or
stores the handle where teardown can find it (``escape_stores``, e.g.
``platform.gang_sizes[...] = n`` / ``self.slots[b] = ...``).  Pairs with
``none_guard`` may return None from their acquire; an ``if x is None``
branch cancels the acquisition bound to ``x`` on the None arm.

Soundness tradeoffs (documented, deliberate):

* implicit exceptions from calls outside any ``try`` are not modeled
  (see ``cfg.py``) — explicit raises and in-``try`` statements are the
  checked class;
* escapes/transfers/releases match by method-name + receiver-substring,
  not alias analysis;
* a release clears *all* held entries of its pair (batch semantics:
  ``pool.free(pages)`` frees a list).

``check(root=..., pairs=...)`` follows the drift_check pattern so tests
can aim it at synthetic trees and mutated pair tables.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.staticcheck import cfg as cfglib
from repro.staticcheck.engine import Finding

RULE_ID = "SC302"


@dataclass(frozen=True)
class ResourcePair:
    name: str
    acquires: Tuple[str, ...] = ()
    releases: Tuple[str, ...] = ()
    acquire_recv: str = ""          # substring of the dotted receiver
    release_recv: str = ""
    providers: Tuple[str, ...] = () # functions allowed to exit holding
    transfers: Tuple[str, ...] = ()
    escape_stores: Tuple[str, ...] = ()
    none_guard: bool = False
    crash_safe: bool = False        # may be held across yields
    structural: str = ""            # "" | "save_lease"
    paths: Tuple[str, ...] = ()


PAIRS: Tuple[ResourcePair, ...] = (
    ResourcePair(
        name="quota",
        acquires=("reserve",), acquire_recv="tenancy",
        releases=("release",), release_recv="tenancy",
        providers=("admit_gang",),
        paths=("core/scheduler.py",),
    ),
    ResourcePair(
        name="gang",
        acquires=("admit_gang",),
        releases=("release_gang",),
        escape_stores=("gang_sizes",),
        paths=("core/guardian.py", "core/lcm.py"),
    ),
    ResourcePair(
        name="volume",
        acquires=("provision",), acquire_recv="volumes",
        releases=("release",), release_recv="volumes",
        transfers=("record",),
        paths=("core/guardian.py",),
    ),
    ResourcePair(
        name="pages",
        acquires=("alloc", "attach"), acquire_recv="pool",
        releases=("free",), release_recv="pool",
        escape_stores=("slots", "pages.extend"),
        none_guard=True,
        paths=("launch/engine.py",),
    ),
    ResourcePair(
        name="save_lease",
        structural="save_lease",
        crash_safe=True,            # time-bounded: stale leases expire
        paths=("core/learner.py",),
    ),
    # per-job scheduler node exclusions (POISONED_NODE self-healing
    # repair): acquired only inside the `_repair_exclude_node` provider —
    # synchronous, so a Guardian crash cannot strand a half-applied
    # exclusion — and swept by `_rollback`/`_teardown` via
    # `clear_exclusions`.  The scheduler's own `_excluded` dict is the
    # durable store teardown reads (escape).
    ResourcePair(
        name="node_exclusion",
        acquires=("exclude_node",),
        releases=("clear_exclusions",),
        escape_stores=("_excluded",),
        providers=("_repair_exclude_node",),
        paths=("core/scheduler.py", "core/guardian.py"),
    ),
)


# -- event extraction ---------------------------------------------------


def _dotted(node: ast.expr) -> str:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _call_name(call: ast.Call) -> str:
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    if isinstance(call.func, ast.Name):
        return call.func.id
    return ""


def _call_recv(call: ast.Call) -> str:
    if isinstance(call.func, ast.Attribute):
        return _dotted(call.func.value)
    return ""


def _dict_keys(node: ast.expr) -> Tuple[str, ...]:
    if not isinstance(node, ast.Dict):
        return ()
    return tuple(k.value for k in node.keys
                 if isinstance(k, ast.Constant) and isinstance(k.value, str))


def _assign_var(stmt: ast.stmt) -> Optional[str]:
    if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
            and isinstance(stmt.targets[0], ast.Name):
        return stmt.targets[0].id
    return None


def _stmt_events(stmt: ast.stmt, pairs):
    """(clears, has_yield, acquires) for one statement.

    ``clears`` are pair names whose held entries this statement ends
    (release/transfer/escape); ``acquires`` are (pair, var) tuples.
    Clears apply before the yield-crossing check and before acquires:
    within one statement a release precedes an acquire
    (``pages = shared + pool.alloc(...)`` idioms), and exception edges
    out of the statement carry the pre-acquire state.
    """
    clears: List[str] = []
    acquires: List[Tuple[ResourcePair, Optional[str]]] = []
    sub = [n for tree in cfglib.own_subtrees(stmt) for n in ast.walk(tree)]
    has_yield = any(isinstance(n, (ast.Yield, ast.YieldFrom)) for n in sub)
    var = _assign_var(stmt)

    # escape via store: `x.y[k] = v` / `x.y.attr = v`
    store_targets: List[str] = []
    if isinstance(stmt, ast.Assign):
        for tgt in stmt.targets:
            if isinstance(tgt, ast.Subscript):
                store_targets.append(_dotted(tgt.value))
            elif isinstance(tgt, ast.Attribute):
                store_targets.append(_dotted(tgt))

    for node in sub:
        if not isinstance(node, ast.Call):
            continue
        name, recv = _call_name(node), _call_recv(node)
        for pair in pairs:
            if pair.structural == "save_lease":
                if name == "write" and len(node.args) >= 2:
                    keys = _dict_keys(node.args[1])
                    if "saving" in keys:
                        acquires.append((pair, None))
                    elif "t" in keys:
                        clears.append(pair.name)
                continue
            if name in pair.releases and pair.release_recv in recv:
                clears.append(pair.name)
            if name in pair.transfers:
                clears.append(pair.name)
            if any(p in f"{recv}.{name}" for p in pair.escape_stores):
                clears.append(pair.name)
            if name in pair.acquires and pair.acquire_recv in recv:
                acquires.append((pair, var))
    for pair in pairs:
        if any(p in t for p in pair.escape_stores for t in store_targets):
            clears.append(pair.name)
    return clears, has_yield, acquires


# -- path exploration ---------------------------------------------------


def _analyze_fn(fn, pairs, rel: str) -> List[Finding]:
    pair_by_name: Dict[str, ResourcePair] = {p.name: p for p in pairs}
    graph = cfglib.CFG(fn)
    events = [
        _stmt_events(s, pairs) if s is not None and not isinstance(
            s, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef))
        else ([], False, [])
        for s in graph.stmts
    ]
    found = set()       # (line, pair, kind) dedupe
    out: List[Finding] = []

    def leak(line: int, pname: str, acq_line: int, kind: str, msg: str):
        key = (line, pname, acq_line, kind)
        if key not in found:
            found.add(key)
            out.append(Finding(RULE_ID, rel, acq_line, msg))

    seen = set()
    stack: List[Tuple[int, frozenset]] = [(cfglib.ENTRY, frozenset())]
    while stack:
        node, held = stack.pop()
        if (node, held) in seen:
            continue
        seen.add((node, held))
        if node == cfglib.EXIT:
            for pname, _, acq_line in held:
                if fn.name in pair_by_name[pname].providers:
                    continue
                leak(0, pname, acq_line, "exit",
                     f"{pname} acquired in {fn.name}() may be leaked on a "
                     f"normal exit path")
            continue
        if node == cfglib.RAISE:
            for pname, _, acq_line in held:
                leak(1, pname, acq_line, "raise",
                     f"{pname} acquired in {fn.name}() is leaked on an "
                     f"exception path")
            continue
        clears, has_yield, acquires = events[node]
        pre = frozenset(h for h in held if h[0] not in clears)
        stmt = graph.stmts[node]
        if has_yield:
            for pname, _, acq_line in pre:
                if not pair_by_name[pname].crash_safe:
                    leak(stmt.lineno, pname, acq_line, "yield",
                         f"{pname} acquired in {fn.name}() is held across "
                         f"a yield at line {stmt.lineno} before being "
                         f"recorded — a crash there strands it")
        post = set(pre)
        for pair, var in acquires:
            post.add((pair.name, var, stmt.lineno))
        post = frozenset(post)
        for edge in graph.succs(node):
            st = pre if edge.exc else post
            if edge.cond is not None:
                cvar, ckind = edge.cond
                if ckind == "is_none":
                    st = frozenset(
                        h for h in st
                        if not (pair_by_name[h[0]].none_guard
                                and h[1] == cvar))
            stack.append((edge.dst, st))
    return out


# -- entry point --------------------------------------------------------


def _iter_files(root: Optional[Path], pairs):
    rels = sorted({p for pair in pairs for p in pair.paths})
    for rel_tail in rels:
        rel = f"src/repro/{rel_tail}"
        if root is not None:
            path = Path(root) / rel
        else:
            import importlib
            mod = "repro." + rel_tail[:-3].replace("/", ".")
            try:
                path = Path(importlib.import_module(mod).__file__)
            except ImportError:
                continue
        if path.is_file():
            yield rel, rel_tail, path


def check(root: Optional[Path] = None, pairs=None) -> List[Finding]:
    if pairs is None:
        pairs = PAIRS
    findings: List[Finding] = []
    for rel, rel_tail, path in _iter_files(root, pairs):
        in_scope = [p for p in pairs
                    if any(t in rel for t in p.paths)]
        try:
            tree = ast.parse(path.read_text(), filename=str(path))
        except SyntaxError:
            continue            # SC100 owns parseability
        for fn in ast.walk(tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                findings.extend(_analyze_fn(fn, in_scope, rel))
    return findings
