"""``repro.staticcheck`` — the dependability static-analysis pass.

Three layers (see README §Static dependability checks):

1. an AST lint engine (``engine`` + ``rules``): a rule registry, a file
   walker with per-line ``# staticcheck: ignore[RULE]`` suppressions, and
   ~6 rules encoding the invariant-violation classes previous PRs fixed
   one at a time (SystemExit escaping pod sandboxes, salted builtin
   ``hash()`` in persisted state, ObjectStore read-modify-write loops,
   module-global durable counters, wall-clock in sim-driven code, broad
   exception swallows in pod loops);
2. semantic cross-file checkers that verify platform invariants without
   executing a job: ``sharding_check`` (every config × both production
   meshes against the ``dist.sharding`` rule table), ``kernel_check``
   (abstract evaluation of Pallas BlockSpec index maps over symbolic grid
   points), ``drift_check`` (ServingEngine snapshot/restore/journal ↔
   SeqRecord field coherence);
3. a checked-in baseline (``staticcheck_baseline.json``) for grandfathered
   findings — empty for ``core/`` and ``launch/`` by construction.

CLI: ``python -m repro.staticcheck src/`` exits nonzero on any finding not
in the baseline; wired into ``make verify`` and CI.
"""
from repro.staticcheck.engine import (
    Baseline,
    Finding,
    Rule,
    all_rules,
    render_json,
    render_text,
    run_files,
)

__all__ = [
    "Baseline",
    "Finding",
    "Rule",
    "all_rules",
    "render_json",
    "render_text",
    "run_files",
]
