"""AST lint engine: rule registry, file walker, suppressions, baseline.

A :class:`Rule` owns an id (``SC1xx`` for AST rules, ``SC2xx`` for the
semantic checkers), a path scope, and a ``check`` over one parsed module.
The walker parses each file once and feeds it to every in-scope rule.

Suppressions are per line: a finding whose source line (or the line above
it) carries ``# staticcheck: ignore[SC101]`` (comma-separated ids, or a
bare ``ignore`` for all rules) is dropped.  Suppressions are for code that
*looks* like a violation but is proven safe — real findings get fixed or,
transitionally, grandfathered in the baseline file.

The baseline (:class:`Baseline`) is a checked-in JSON multiset of finding
fingerprints.  Fingerprints exclude the line number so unrelated edits
don't invalidate the baseline; each baseline entry absorbs at most one
live finding (a *second* occurrence of a grandfathered pattern is new).
"""
from __future__ import annotations

import ast
import io
import json
import re
import tokenize
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

_SUPPRESS_RE = re.compile(
    r"#\s*staticcheck:\s*ignore(?:\[(?P<ids>[A-Z0-9,\s]+)\])?")


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str                 # "SC101"
    path: str                 # posix path as scanned (repo-relative)
    line: int                 # 1-based; 0 for file-level findings
    message: str

    def fingerprint(self) -> str:
        """Line-free identity used for baseline matching."""
        return f"{self.rule}::{self.path}::{self.message}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


class Rule:
    """Base class: subclasses set ``id``/``title``/``rationale``/``scopes``
    and implement :meth:`check`.  ``scopes`` are posix path *segments* —
    a rule applies to a file iff any scope is a substring of its posix
    path (empty scopes = applies everywhere under the scanned roots)."""

    id: str = "SC000"
    title: str = ""
    rationale: str = ""
    scopes: Tuple[str, ...] = ()

    def applies_to(self, posix_path: str) -> bool:
        if not self.scopes:
            return True
        return any(s in posix_path for s in self.scopes)

    def check(self, tree: ast.AST, lines: Sequence[str],
              path: str) -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, path: str, node: ast.AST, message: str) -> Finding:
        return Finding(self.id, path, getattr(node, "lineno", 0), message)


def all_rules() -> List[Rule]:
    """The registered AST rules (semantic checkers register separately —
    they need imports heavier than ``ast``)."""
    from repro.staticcheck import rules as _rules
    return [cls() for cls in _rules.RULES]


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------
def suppressed_ids(line: str) -> Optional[set]:
    """The rule ids a source line suppresses: a set of ids, the empty set
    for a bare ``ignore`` (= all rules), or None if no marker."""
    m = _SUPPRESS_RE.search(line)
    if m is None:
        return None
    ids = m.group("ids")
    if ids is None:
        return set()
    return {s.strip() for s in ids.split(",") if s.strip()}


def is_suppressed(f: Finding, lines: Sequence[str]) -> bool:
    """A finding is suppressed by a marker on its own line or on the line
    directly above (for lines that have no room for a trailing comment)."""
    for ln in (f.line, f.line - 1):
        if 1 <= ln <= len(lines):
            ids = suppressed_ids(lines[ln - 1])
            if ids is not None and (not ids or f.rule in ids):
                return True
    return False


@dataclass(frozen=True)
class Marker:
    """One ``# staticcheck: ignore[...]`` comment in a file.

    Found by *tokenizing* (COMMENT tokens only), so marker text inside
    string literals — e.g. test fixtures embedding sample sources —
    never counts as a live suppression.
    """

    path: str
    line: int
    ids: frozenset          # empty = bare ignore (all rules)

    def render(self) -> str:
        which = ",".join(sorted(self.ids)) if self.ids else "all rules"
        return (f"{self.path}:{self.line}: stale suppression ({which}) — "
                f"no finding suppressed")


def scan_markers(src: str, posix: str) -> List[Marker]:
    out: List[Marker] = []
    try:
        for tok in tokenize.generate_tokens(io.StringIO(src).readline):
            if tok.type == tokenize.COMMENT:
                ids = suppressed_ids(tok.string)
                if ids is not None:
                    out.append(Marker(posix, tok.start[0], frozenset(ids)))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        pass                    # SC100 owns unparseable files
    return out


# ---------------------------------------------------------------------------
# Walker
# ---------------------------------------------------------------------------
def iter_py_files(paths: Sequence[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            out.append(path)
    # dedup, keep order
    seen: set = set()
    uniq = []
    for f in out:
        if f not in seen:
            seen.add(f)
            uniq.append(f)
    return uniq


def check_file(path: Path, rules: Sequence[Rule],
               stale_out: Optional[List[Marker]] = None) -> List[Finding]:
    posix = path.as_posix()
    applicable = [r for r in rules if r.applies_to(posix)]
    try:
        src = path.read_text()
        tree = ast.parse(src, filename=posix)
    except (SyntaxError, UnicodeDecodeError) as e:
        return [Finding("SC100", posix, getattr(e, "lineno", 0) or 0,
                        f"unparseable file: {e.__class__.__name__}")]
    markers = scan_markers(src, posix)
    by_line = {m.line: m for m in markers}
    used: set = set()
    lines = src.splitlines()
    found: List[Finding] = []
    for rule in applicable:
        for f in rule.check(tree, lines, posix):
            m = _matching_marker(f, by_line)
            if m is not None:
                used.add(m)
            else:
                found.append(f)
    if stale_out is not None:
        stale_out.extend(m for m in markers if m not in used)
    return found


def _matching_marker(f: Finding, by_line: Dict[int, "Marker"]
                     ) -> Optional["Marker"]:
    for ln in (f.line, f.line - 1):
        m = by_line.get(ln)
        if m is not None and (not m.ids or f.rule in m.ids):
            return m
    return None


def run_files(paths: Sequence[str],
              rules: Optional[Sequence[Rule]] = None,
              stale_out: Optional[List[Marker]] = None) -> List[Finding]:
    """Run the AST rules over every ``.py`` under ``paths``.  When
    ``stale_out`` is given, markers that suppressed nothing are
    collected into it (the suppression ratchet)."""
    rules = list(rules) if rules is not None else all_rules()
    findings: List[Finding] = []
    for f in iter_py_files(paths):
        findings.extend(check_file(f, rules, stale_out))
    return findings


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------
class Baseline:
    """Checked-in multiset of grandfathered finding fingerprints.

    ``apply`` partitions findings into (new, grandfathered); each baseline
    entry absorbs at most one live finding.  ``stale`` reports entries
    that no longer fire — they should be deleted, the burn-down ratchet.
    """

    def __init__(self, fingerprints: Sequence[str] = ()):
        self.counts: Dict[str, int] = {}
        for fp in fingerprints:
            self.counts[fp] = self.counts.get(fp, 0) + 1

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        if not path.exists():
            return cls()
        doc = json.loads(path.read_text())
        return cls(doc.get("findings", []))

    @staticmethod
    def save(path: Path, findings: Sequence[Finding]) -> None:
        doc = {"comment": "grandfathered staticcheck findings; entries may "
                          "only be removed (CI guards growth)",
               "findings": sorted(f.fingerprint() for f in findings)}
        path.write_text(json.dumps(doc, indent=2) + "\n")

    def __len__(self) -> int:
        return sum(self.counts.values())

    def apply(self, findings: Sequence[Finding]
              ) -> Tuple[List[Finding], List[Finding]]:
        remaining = dict(self.counts)
        new: List[Finding] = []
        old: List[Finding] = []
        for f in findings:
            fp = f.fingerprint()
            if remaining.get(fp, 0) > 0:
                remaining[fp] -= 1
                old.append(f)
            else:
                new.append(f)
        return new, old

    def stale(self, findings: Sequence[Finding]) -> List[str]:
        live: Dict[str, int] = {}
        for f in findings:
            fp = f.fingerprint()
            live[fp] = live.get(fp, 0) + 1
        out: List[str] = []
        for fp, n in sorted(self.counts.items()):
            extra = n - live.get(fp, 0)
            out.extend([fp] * max(extra, 0))
        return out


# ---------------------------------------------------------------------------
# Reporters
# ---------------------------------------------------------------------------
def render_text(findings: Sequence[Finding]) -> str:
    lines = [f.render() for f in sorted(
        findings, key=lambda f: (f.path, f.line, f.rule))]
    lines.append(f"{len(findings)} finding(s)")
    return "\n".join(lines)


def render_json(findings: Sequence[Finding]) -> str:
    return json.dumps(
        [{"rule": f.rule, "path": f.path, "line": f.line,
          "message": f.message} for f in sorted(
              findings, key=lambda f: (f.path, f.line, f.rule))],
        indent=2)
