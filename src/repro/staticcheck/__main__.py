"""CLI: ``python -m repro.staticcheck [paths...]``.

Runs the AST rules over the given paths (default ``src/``) plus the
semantic cross-file checkers, subtracts the checked-in baseline, and
exits nonzero on anything new.  Exit codes: 0 clean, 1 findings, 2 the
checker itself failed.

Flags:
  --json             machine-readable findings
  --baseline PATH    baseline file (default: staticcheck_baseline.json
                     next to the repo's pyproject, or cwd)
  --write-baseline   grandfather all current findings into the baseline
  --check-baseline   also fail if baseline entries went stale or a
                     suppression comment no longer suppresses anything
                     (the burn-down ratchets: fixed findings must shed
                     their baseline entries and ignore markers)
  --report PATH      write a full JSON report (all findings, new vs
                     grandfathered, stale entries/markers) — uploaded
                     as a CI build artifact
  --ast-only         skip the semantic checkers (fast pre-commit loop)
  --semantic-only    skip the AST rules
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List

from repro.staticcheck.engine import (
    Baseline, Finding, Marker, render_json, render_text, run_files)


def _default_baseline() -> Path:
    here = Path.cwd()
    for d in (here, *here.parents):
        if (d / "pyproject.toml").exists():
            return d / "staticcheck_baseline.json"
    return here / "staticcheck_baseline.json"


def semantic_findings() -> List[Finding]:
    from repro.staticcheck import (drift_check, kernel_check,
                                   lifecycle_check, resource_check,
                                   sharding_check)
    out: List[Finding] = []
    out.extend(sharding_check.check())
    out.extend(kernel_check.check())
    out.extend(drift_check.check())
    out.extend(lifecycle_check.check())
    out.extend(resource_check.check())
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.staticcheck")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to scan (default: src/)")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--baseline", type=Path, default=None)
    ap.add_argument("--write-baseline", action="store_true")
    ap.add_argument("--check-baseline", action="store_true")
    ap.add_argument("--report", type=Path, default=None)
    ap.add_argument("--ast-only", action="store_true")
    ap.add_argument("--semantic-only", action="store_true")
    args = ap.parse_args(argv)

    paths = args.paths or ["src"]
    findings: List[Finding] = []
    stale_markers: List[Marker] = []
    if not args.semantic_only:
        findings.extend(run_files(paths, stale_out=stale_markers))
    if not args.ast_only:
        findings.extend(semantic_findings())

    bl_path = args.baseline or _default_baseline()
    if args.write_baseline:
        Baseline.save(bl_path, findings)
        print(f"wrote {len(findings)} finding(s) to {bl_path}")
        return 0

    baseline = Baseline.load(bl_path)
    new, old = baseline.apply(findings)
    stale = baseline.stale(findings)

    if args.report:
        _write_report(args.report, findings, new, old, stale, stale_markers)

    if args.json:
        print(render_json(new))
    else:
        if new:
            print(render_text(new))
        if old:
            print(f"({len(old)} grandfathered finding(s) in baseline)")
        if not new:
            print(f"staticcheck: clean "
                  f"({len(findings)} finding(s), all baselined)"
                  if findings else "staticcheck: clean")
    rc = 1 if new else 0
    if args.check_baseline and stale:
        print(f"baseline ratchet: {len(stale)} entr(ies) no longer fire "
              "and must be removed:")
        for fp in stale:
            print(f"  {fp}")
        rc = 1
    if args.check_baseline and stale_markers:
        print(f"suppression ratchet: {len(stale_markers)} ignore "
              "marker(s) no longer suppress anything and must be removed:")
        for m in stale_markers:
            print(f"  {m.render()}")
        rc = 1
    return rc


def _write_report(path: Path, findings, new, old, stale,
                  stale_markers) -> None:
    def as_doc(f: Finding):
        return {"rule": f.rule, "path": f.path, "line": f.line,
                "message": f.message}

    doc = {
        "findings": [as_doc(f) for f in findings],
        "new": [as_doc(f) for f in new],
        "grandfathered": len(old),
        "stale_baseline": list(stale),
        "stale_suppressions": [
            {"path": m.path, "line": m.line, "ids": sorted(m.ids)}
            for m in stale_markers],
    }
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(doc, indent=2) + "\n")


if __name__ == "__main__":
    sys.exit(main())
