"""Qwen3-0.6B: dense GQA with per-head qk RMSNorm. [hf:Qwen/Qwen3-0.6B]"""
from repro.configs.base import (
    GLOBAL_ATTN, ModelConfig, RunConfig, register, register_run,
)

CONFIG = register(ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151_936,
    block_pattern=(GLOBAL_ATTN,),
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
))

register_run("qwen3-0.6b", "train_4k",
             RunConfig(num_microbatches=2, remat_policy="full"))
