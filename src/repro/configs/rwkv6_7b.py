"""RWKV6-7B ("Finch"): attention-free, data-dependent decay. [arXiv:2404.05892]"""
from repro.configs.base import RWKV, ModelConfig, RunConfig, register, register_run

CONFIG = register(ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,                 # = d_model / rwkv_head_dim
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65_536,
    block_pattern=(RWKV,),
    rwkv_head_dim=64,
    rwkv_ddlerp_rank=32,
    rwkv_decay_rank=64,
))

register_run("rwkv6-7b", "train_4k",
             RunConfig(num_microbatches=2, remat_policy="full",
                       sharding_overrides=(("resid_seq", ("model",)),)))
