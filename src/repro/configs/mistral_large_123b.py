"""Mistral-Large-2407 (123B): dense GQA.
[hf:mistralai/Mistral-Large-Instruct-2407]"""
from repro.configs.base import GLOBAL_ATTN, ModelConfig, RunConfig, register, register_run

CONFIG = register(ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32_768,
    block_pattern=(GLOBAL_ATTN,),
    rope_theta=1_000_000.0,
))

# §Perf-adopted: sequence-parallel residuals (58.6 -> 11.9 GB/device);
# weight-stationary decode (collective 579 -> 14 ms/token).  Baselines in
# EXPERIMENTS.md §Perf.
register_run("mistral-large-123b", "train_4k",
             RunConfig(num_microbatches=8, remat_policy="full",
                       sharding_overrides=(("resid_seq", ("model",)),)))
register_run("mistral-large-123b", "decode_32k",
             RunConfig(sharding_overrides=(("batch", ()),
                                           ("embed_act", ("data",)))))
