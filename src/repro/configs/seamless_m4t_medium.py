"""SeamlessM4T-medium: encoder-decoder, audio frontend STUB (precomputed
frame embeddings via input_specs). [arXiv:2308.11596]"""
from repro.configs.base import (
    GLOBAL_ATTN, ModelConfig, RunConfig, register, register_run,
)

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,                # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256_206,
    block_pattern=(GLOBAL_ATTN,),
    is_encoder_decoder=True,
    num_encoder_layers=12,
    frontend="audio",
))

register_run("seamless-m4t-medium", "train_4k",
             RunConfig(num_microbatches=2, remat_policy="full"))
