"""Model / shape / run configuration for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig`.  The
platform layer (``repro.core``) treats an architecture the way the paper's
DLaaS treats a *framework*: an opaque, selectable learner payload.  The
training substrate (``repro.models`` / ``repro.train``) consumes the config
directly.

Design notes
------------
* Configs are frozen dataclasses — hashable, usable as jit static args.
* ``reduced()`` returns a tiny config of the *same family* for CPU smoke
  tests (same code paths: same block pattern, MoE/MLA/recurrence flags).
* ``padded_vocab`` rounds the vocabulary up to a multiple of
  ``pad_vocab_multiple`` so the embedding/vocab dims shard cleanly over the
  fixed production mesh (and align with the 128-lane MXU).  Logits for the
  padding ids are masked to ``-inf`` in the loss/serve paths.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds — the per-layer pattern vocabulary.
# ---------------------------------------------------------------------------
GLOBAL_ATTN = "global"      # full causal attention
LOCAL_ATTN = "local"        # sliding-window causal attention
RECURRENT = "recurrent"     # RG-LRU recurrent block (Griffin/RecurrentGemma)
RWKV = "rwkv"               # RWKV6 time-mix block (attention-free)

BLOCK_KINDS = (GLOBAL_ATTN, LOCAL_ATTN, RECURRENT, RWKV)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters for one model family instance."""

    name: str
    family: str                       # dense | hybrid | ssm | moe | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- block pattern ----------------------------------------------------
    # The repeating tuple of block kinds; tiled (and truncated) to
    # ``num_layers``.  E.g. gemma-2 = ("local", "global"),
    # recurrentgemma = ("recurrent", "recurrent", "local").
    block_pattern: Tuple[str, ...] = (GLOBAL_ATTN,)
    window_size: int = 0              # local-attention window (tokens)

    # --- attention features -------------------------------------------------
    qk_norm: bool = False             # per-head RMSNorm on q,k (qwen3)
    qkv_bias: bool = False            # bias on q,k,v projections (qwen2.5)
    attn_logit_softcap: float = 0.0   # tanh softcap on attention logits (gemma2)
    final_logit_softcap: float = 0.0  # tanh softcap on output logits (gemma2)
    query_pre_attn_scalar: float = 0.0  # overrides 1/sqrt(head_dim) when > 0
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # --- MLA (DeepSeek-V2 multi-head latent attention) ----------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0              # routed experts (0 = dense FFN)
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                 # per-expert hidden dim
    first_k_dense: int = 0            # leading layers that keep a dense FFN
    capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.001
    moe_group_size: int = 1024        # tokens per dispatch group (§Perf knob)

    # --- recurrence (RG-LRU) ------------------------------------------------
    rnn_width: int = 0
    conv1d_width: int = 4

    # --- RWKV6 ---------------------------------------------------------------
    rwkv_head_dim: int = 64
    rwkv_ddlerp_rank: int = 32        # token-shift LoRA rank
    rwkv_decay_rank: int = 64         # data-dependent decay LoRA rank

    # --- encoder/decoder -----------------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0

    # --- modality frontend (STUB per the brief) ------------------------------
    frontend: str = "none"            # none | audio | vision
    frontend_tokens: int = 0          # precomputed embeddings prepended (vision)

    # --- serving KV-cache layout ---------------------------------------------
    # ``dense``: one (B, K, S_max, hd) buffer per layer (the fallback).
    # ``paged``: global-attention layers keep a shared pool of fixed-size
    # pages plus per-sequence page tables (vLLM-style); ring-buffer (local)
    # and MLA-latent caches stay dense — they are already bounded.  Decode
    # logits are identical between the two layouts (tested).
    cache_layout: str = "dense"       # dense | paged
    page_size: int = 128              # tokens per KV page (paged layout)

    # --- numerics / misc ------------------------------------------------------
    norm_eps: float = 1e-6
    act: str = "silu"                 # silu | gelu_tanh
    embed_scale_by_sqrt_dim: bool = False  # gemma-style sqrt(d) input scaling
    use_post_block_norm: bool = False      # gemma2 sandwich norms
    pad_vocab_multiple: int = 128
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"      # storage dtype (cast to `dtype` in fwd)

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def uses_attention(self) -> bool:
        return any(k in (GLOBAL_ATTN, LOCAL_ATTN) for k in self.layer_kinds())

    @property
    def sub_quadratic(self) -> bool:
        """True when no layer does *full* (global) attention — required for
        the ``long_500k`` shape."""
        return GLOBAL_ATTN not in self.layer_kinds()

    def layer_kinds(self, num_layers: Optional[int] = None) -> Tuple[str, ...]:
        """The per-layer block kinds, pattern tiled to ``num_layers``."""
        n = self.num_layers if num_layers is None else num_layers
        pat = self.block_pattern
        reps = (n + len(pat) - 1) // len(pat)
        return tuple((pat * reps)[:n])

    def scan_groups(self) -> Tuple[int, Tuple[str, ...], int]:
        """Decompose the layer stack into (n_full_groups, pattern, n_tail).

        The model scans ``n_full_groups`` repetitions of ``pattern`` with
        stacked weights and applies the remaining ``n_tail`` layers
        (``pattern[:n_tail]``) unrolled.  MoE first-k-dense layers are also
        peeled off into the tail-equivalent prefix (handled in the model).
        """
        pat = self.block_pattern
        n = self.num_layers - self.first_k_dense
        groups, tail = divmod(n, len(pat))
        return groups, pat, tail

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests.

        Preserves: block pattern, MoE/MLA/recurrence/enc-dec flags, GQA
        ratio feel, activation/norm choices.  Shrinks: layers, widths,
        expert count, vocab.
        """
        few_layers = max(len(self.block_pattern) + 1, 3)
        if self.first_k_dense:
            few_layers = max(few_layers, self.first_k_dense + 2)
        kv = min(self.num_kv_heads, 2) or 1
        heads = max(4, kv * 2)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=few_layers,
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16,
            d_ff=128,
            vocab_size=503,                   # deliberately odd: exercises padding
            window_size=min(self.window_size, 16) if self.window_size else 0,
            q_lora_rank=24 if self.q_lora_rank else 0,
            kv_lora_rank=16 if self.kv_lora_rank else 0,
            qk_nope_head_dim=16 if self.qk_nope_head_dim else 0,
            qk_rope_head_dim=8 if self.qk_rope_head_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            num_experts=4 if self.num_experts else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            num_shared_experts=min(self.num_shared_experts, 1),
            moe_d_ff=32 if self.moe_d_ff else 0,
            first_k_dense=min(self.first_k_dense, 1),
            rnn_width=64 if self.rnn_width else 0,
            rwkv_ddlerp_rank=8,
            rwkv_decay_rank=8,
            num_encoder_layers=2 if self.is_encoder_decoder else 0,
            frontend_tokens=min(self.frontend_tokens, 4),
            pad_vocab_multiple=32,
            page_size=8,                      # page on tiny CPU sequences too
        )

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND model-FLOPs)."""
        from repro.models.model import count_params  # local import: avoid cycle
        return count_params(self)

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top-k routed only)."""
        from repro.models.model import count_params
        return count_params(self, active_only=True)


# ---------------------------------------------------------------------------
# Input shapes — each architecture is paired with the same 4-shape grid; the
# launcher skips cells per the applicability rules (DESIGN.md §4).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(applicable?, reason).  Mirrors DESIGN.md §4."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k requires sub-quadratic attention; " \
                      f"{cfg.name} has full global attention"
    return True, ""


# ---------------------------------------------------------------------------
# Per-(arch, shape) run knobs: grad-accum microbatches and remat policy are a
# memory-fit decision, recorded here so the dry-run is reproducible.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class RunConfig:
    num_microbatches: int = 1
    remat_policy: str = "none"       # none | dots | full
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    # Memory/precision knobs for the largest models (e.g. 236B on 256 chips):
    # bf16 master + bf16 moments is the documented trade-off in DESIGN.md.
    master_dtype: str = "float32"
    opt_dtype: str = "float32"
    # Per-cell sharding-rule overrides: ((logical_axis, (mesh_axes...)), ...)
    # — the §Perf hillclimbing knob (e.g. (("resid_seq", ("model",)),) turns
    # on sequence-parallel residuals for this arch × shape).
    sharding_overrides: Tuple[Tuple[str, Tuple[str, ...]], ...] = ()
    # Gradient compression on the learner all-reduce ("none" | "int8" |
    # "topk") — the paper's efficiency-vs-dependability tradeoff, resolved
    # by repro.dist.compression.resolve_compression.
    grad_compression: str = "none"
    # Expected mean KV-cache occupancy for *paged* decode cells.  Continuous
    # batching keeps the pool near a target utilization instead of reserving
    # worst-case S for every sequence; the scheduler admits a cell by this
    # allocated-page budget (launch.specs.decode_page_budget), not by S_max.
    page_occupancy: float = 1.0
    # Expected fraction of each sequence's resident pages that are prefix
    # pages SHARED across the batch (system prompts / few-shot templates,
    # deduplicated by the engine's hash-addressed prefix cache).  Shared
    # pages are physically resident once, so bandwidth and admission
    # pricing count them once (launch.specs "kernel_unique" path).
    prefix_share_frac: float = 0.0


# Registry -------------------------------------------------------------------
_REGISTRY: Dict[str, ModelConfig] = {}
_RUN_OVERRIDES: Dict[Tuple[str, str], RunConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate config {cfg.name!r}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def register_run(arch: str, shape: str, run: RunConfig) -> None:
    _RUN_OVERRIDES[(arch, shape)] = run


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown architecture {name!r}; known: {sorted(_REGISTRY)}"
        ) from None


def get_run_config(arch: str, shape: str) -> RunConfig:
    _ensure_loaded()
    if (arch, shape) in _RUN_OVERRIDES:
        return _RUN_OVERRIDES[(arch, shape)]
    # training at production shapes always activation-checkpoints by default
    if SHAPES.get(shape) is not None and SHAPES[shape].kind == "train":
        return RunConfig(remat_policy="full")
    return RunConfig()


def list_configs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


_LOADED = False


def _ensure_loaded() -> None:
    """Import every config module exactly once (they self-register)."""
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from repro.configs import (  # noqa: F401
        recurrentgemma_9b,
        rwkv6_7b,
        qwen3_0_6b,
        gemma2_9b,
        mistral_large_123b,
        qwen2_5_32b,
        seamless_m4t_medium,
        internvl2_76b,
        deepseek_v2_236b,
        granite_moe_1b_a400m,
        paper_overhead,
    )
