"""InternVL2-Llama3-76B: vision frontend STUB (precomputed patch embeddings)
+ Llama-3-70B-class dense LLM backbone. [arXiv:2404.16821]"""
from repro.configs.base import GLOBAL_ATTN, ModelConfig, RunConfig, register, register_run

CONFIG = register(ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128_256,
    block_pattern=(GLOBAL_ATTN,),
    frontend="vision",
    frontend_tokens=256,          # 448px / patch14 pixel-unshuffle x4
    rope_theta=500_000.0,
))

register_run("internvl2-76b", "train_4k",
             RunConfig(num_microbatches=16, remat_policy="full",
                       sharding_overrides=(("resid_seq", ("model",)),)))
register_run("internvl2-76b", "decode_32k",
             RunConfig(sharding_overrides=(("batch", ()),
                                           ("embed_act", ("data",)))))
