"""The paper's own experiments use ~100M-class vision models (VGG/ResNet/
Inception).  Our LM-substrate equivalent for the Fig-2/3/4 benchmarks: a
~100M dense transformer trained under the platform vs bare (raw jit loop).
"""
from repro.configs.base import GLOBAL_ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="paper-overhead-100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    head_dim=64,
    d_ff=3072,
    vocab_size=32_768,
    block_pattern=(GLOBAL_ATTN,),
))
