"""Qwen2.5-32B: dense GQA with QKV bias. [hf:Qwen/Qwen2.5-32B]"""
from repro.configs.base import GLOBAL_ATTN, ModelConfig, RunConfig, register, register_run

CONFIG = register(ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152_064,
    block_pattern=(GLOBAL_ATTN,),
    qkv_bias=True,
    rope_theta=1_000_000.0,
))

# §Perf-adopted: 40 Q-heads don't divide the 16-way model axis, so TP
# replicates attention; context-parallel attention (seq -> model) shards it
# instead: compute -70%, memory-term -89% (EXPERIMENTS.md §Perf).
register_run("qwen2.5-32b", "train_4k",
             RunConfig(num_microbatches=16, remat_policy="full",
                       sharding_overrides=(("seq", ("model",)),
                                           ("resid_seq", ("model",)))))
register_run("qwen2.5-32b", "prefill_32k",
             RunConfig(sharding_overrides=(("seq", ("model",)),
                                           ("resid_seq", ("model",)))))
register_run("qwen2.5-32b", "decode_32k",
             RunConfig(sharding_overrides=(("batch", ()),
                                           ("embed_act", ("data",)))))
