from repro.configs.base import (  # noqa: F401
    ModelConfig,
    RunConfig,
    ShapeConfig,
    SHAPES,
    get_config,
    get_run_config,
    list_configs,
    register,
    register_run,
    shape_applicable,
)
