"""Gemma-2 9B: alternating local/global attention, logit softcaps, sandwich
norms. [arXiv:2408.00118]"""
from repro.configs.base import (
    GLOBAL_ATTN, LOCAL_ATTN, ModelConfig, RunConfig, register, register_run,
)

CONFIG = register(ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256_000,
    block_pattern=(LOCAL_ATTN, GLOBAL_ATTN),
    window_size=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_pre_attn_scalar=256.0,
    use_post_block_norm=True,
    act="gelu_tanh",
    embed_scale_by_sqrt_dim=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
))

register_run("gemma2-9b", "train_4k",
             RunConfig(num_microbatches=4, remat_policy="full"))
