"""RecurrentGemma-9B (Griffin): RG-LRU + local attention, 1 attn : 2 recurrent.
[arXiv:2402.19427]"""
from repro.configs.base import (
    LOCAL_ATTN, RECURRENT, ModelConfig, RunConfig, register, register_run,
)

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,               # MQA on the local-attention layers
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    block_pattern=(RECURRENT, RECURRENT, LOCAL_ATTN),
    window_size=2048,
    rnn_width=4096,
    conv1d_width=4,
    act="gelu_tanh",
    embed_scale_by_sqrt_dim=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
))

register_run("recurrentgemma-9b", "train_4k",
             RunConfig(num_microbatches=2, remat_policy="full",
                       sharding_overrides=(("resid_seq", ("model",)),)))
