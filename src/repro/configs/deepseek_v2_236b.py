"""DeepSeek-V2 (236B, 21B active): MLA (kv_lora=512) + MoE 160 routed top-6
with 2 shared experts; first layer dense. [arXiv:2405.04434]"""
from repro.configs.base import GLOBAL_ATTN, ModelConfig, RunConfig, register, register_run

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,             # MLA: per-head K/V expanded from the latent
    head_dim=128,
    d_ff=12288,                   # dense FFN of the first layer
    vocab_size=102_400,
    block_pattern=(GLOBAL_ATTN,),
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=160,
    num_experts_per_tok=6,
    num_shared_experts=2,
    moe_d_ff=1536,
    first_k_dense=1,
    rope_theta=10_000.0,
))

# 236B params on 256 × 16 GB chips: fp32 master + fp32 moments alone would be
# 11 GB/chip.  bf16 master + bf16 moments is the deployable configuration
# (DESIGN.md §memory); fp32 is restored when running on a larger mesh.
register_run("deepseek-v2-236b", "train_4k",
             RunConfig(num_microbatches=16, remat_policy="full",
                       master_dtype="bfloat16", opt_dtype="bfloat16",
                       sharding_overrides=(("resid_seq", ("model",)),)))
