"""Shared numeric primitives + the forward-pass context object."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.dist.sharding import DEFAULT_RULES, ShardingRules, logical_to_spec


@dataclass(frozen=True)
class Ctx:
    """Threading object for the forward pass.

    ``mesh=None`` (CPU smoke tests) turns sharding constraints into no-ops.
    ``use_pallas`` switches attention / RG-LRU / WKV to the Pallas TPU
    kernels (validated on CPU via interpret mode; the dry-run uses jnp).
    """

    mesh: Optional[Mesh] = None
    rules: ShardingRules = DEFAULT_RULES
    use_pallas: bool = False
    attn_q_block: int = 1024     # flash-style kv-chunked attention block sizes
    attn_kv_block: int = 1024
    rwkv_chunk: int = 32
    dtype: jnp.dtype = jnp.bfloat16
    # Unroll the layer scan: used by the roofline analysis variants, where
    # XLA's cost model needs loop-free HLO to count FLOPs exactly.
    scan_unroll: bool = False
    # Re-constrain scanned weight slices inside the loop body (perf A/B knob;
    # measured neutral on CPU-XLA — see models/model.py comment).
    constrain_scan_weights: bool = False

    def constrain(self, x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
        if self.mesh is None:
            return x
        spec = logical_to_spec(logical_axes, x.shape, self.mesh, self.rules)
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm in fp32, cast back.  ``scale`` is the learned gain."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(dt)


def activation(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu_tanh":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(kind)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 tanh soft-capping; identity when cap == 0."""
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies, fp32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate ``x (..., seq, heads, head_dim)`` at absolute ``positions (seq,)``
    (or broadcastable ``(..., seq)``)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., seq, hd/2)
    ang = ang[..., None, :]                             # broadcast over heads
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_ffn(p, x: jax.Array, act: str, ctx: Ctx) -> jax.Array:
    """SwiGLU MLP: wd( act(x wg) * (x wu) )."""
    h = activation(x @ p["wg"], act) * (x @ p["wu"])
    h = ctx.constrain(h, ("batch", "seq", "ffn"))
    return h @ p["wd"]
