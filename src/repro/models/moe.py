"""Mixture-of-Experts FFN: capacity-based dispatch (GShard-style).

Baseline dispatch is the one-hot-einsum formulation — it SPMD-partitions
cleanly (XLA inserts the all-to-all-equivalent collectives when the expert
dim of the dispatched activations is constrained to the ``model`` axis).
Tokens are processed in groups so the (S, E, C) dispatch tensor stays small;
capacity per group C = ceil(Sg * top_k / E * capacity_factor).

Returns (out, aux_loss).  Aux loss is the standard load-balancing loss
(Switch/GShard): E * Σ_e f_e · p_e over routed probability mass.

Serving (``dropless=True``) bypasses the capacity queue entirely: a
served token's routing must depend on that token alone — capacity drops
would make a request's logits a function of its co-batched neighbors and
of ragged padding, breaking per-request determinism under continuous
batching.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Ctx, activation

MOE_GROUP_SIZE = 1024     # tokens per dispatch group


def moe_ffn(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,                 # (B, S, D)
    ctx: Ctx,
    *,
    dropless: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    # groups never span rows: capacity contention across sequences would
    # couple co-batched serving requests (a neighbor's routing could drop
    # YOUR tokens), and ragged/continuous batching needs per-row prefill
    # to be batch-composition-independent
    Sg = min(cfg.moe_group_size or MOE_GROUP_SIZE, S)
    assert S % Sg == 0, f"row length {S} not divisible by group size {Sg}"
    G = T // Sg
    C = max(1, int(Sg * k / E * cfg.capacity_factor))

    xt = x.reshape(G, Sg, D)
    logits = (xt @ p["router"]).astype(jnp.float32)        # (G,Sg,E)
    probs = jax.nn.softmax(logits, axis=-1)

    # --- top-k routing ----------------------------------------------------
    topk_p, topk_e = jax.lax.top_k(probs, k)               # (G,Sg,k)
    # DeepSeek-V2 normalizes the top-k weights to sum to 1
    topk_p = topk_p / jnp.maximum(topk_p.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(topk_e, E, dtype=jnp.float32)  # (G,Sg,k,E)

    dt = x.dtype
    if dropless:
        # serving path: capacity dropping is a training-throughput
        # artifact — a served token's output must depend on that token
        # alone (never on its queue position behind co-batched or padded
        # tokens), so route exactly what top-k chose via a dense
        # per-expert sweep.  E× FLOPs at the reduced scales that actually
        # execute; production placement is priced analytically.
        w = (onehot * topk_p[..., None]).sum(-2)           # (G,Sg,E)
        h = activation(jnp.einsum("gsd,edf->gsef", xt, p["we_g"]),
                       cfg.act) \
            * jnp.einsum("gsd,edf->gsef", xt, p["we_u"])
        h = ctx.constrain(h, ("batch", None, "experts", "expert_ffn"))
        ye = jnp.einsum("gsef,efd->gsed", h, p["we_d"])
        out = jnp.einsum("gsed,gse->gsd", ye,
                         w.astype(jnp.float32)).astype(dt).reshape(B, S, D)
    else:
        # --- per-expert capacity dispatch (GShard) ------------------------
        # position of each (token, choice) within its expert queue,
        # priority by token order then choice order (GShard convention)
        flat = onehot.reshape(G, Sg * k, E)
        pos_in_e = (jnp.cumsum(flat, axis=1) - flat).reshape(G, Sg, k, E)
        pos = (pos_in_e * onehot).sum(-1)                  # (G,Sg,k)
        keep = pos < C
        gates = topk_p * keep

        # dispatch/combine tensors (G, Sg, E, C)
        pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]
        disp = jnp.einsum("gske,gskc->gsec", onehot, pos_oh)
        comb = jnp.einsum("gsk,gske,gskc->gsec", gates, onehot, pos_oh)

        xd = jnp.einsum("gsd,gsec->gecd", xt, disp.astype(dt))  # (G,E,C,D)
        xd = ctx.constrain(xd, ("batch", "experts", None, None))
        h = activation(jnp.einsum("gecd,edf->gecf", xd, p["we_g"]), cfg.act) \
            * jnp.einsum("gecd,edf->gecf", xd, p["we_u"])
        h = ctx.constrain(h, ("batch", "experts", None, "expert_ffn"))
        ye = jnp.einsum("gecf,efd->gecd", h, p["we_d"])
        ye = ctx.constrain(ye, ("batch", "experts", None, None))
        out = jnp.einsum("gecd,gsec->gsd", ye,
                         comb.astype(dt)).reshape(B, S, D)

    # --- shared experts (always-on dense path) ----------------------------
    if cfg.num_shared_experts:
        hs = activation(x @ p["ws_g"], cfg.act) * (x @ p["ws_u"])
        hs = ctx.constrain(hs, ("batch", "seq", "ffn"))
        out = out + hs @ p["ws_d"]

    # --- load-balancing aux loss ------------------------------------------
    me = probs.mean(axis=(0, 1))                            # mean prob per e
    ce = onehot.sum(2).mean(axis=(0, 1)) / k                # frac tokens per e
    aux = E * jnp.sum(me * ce) * cfg.router_aux_loss_coef
    return out, aux
