"""Attention: GQA (global / sliding-window) and DeepSeek-V2 MLA.

Two execution modes:

* ``full``   — train and prefill.  Flash-style **kv-chunked online-softmax**
  (never materializes the (S, S) score matrix; block sizes from Ctx).  The
  same math as ``kernels/flash_attention`` — the Pallas kernel replaces it
  when ``ctx.use_pallas`` on TPU.
* ``decode`` — one new token against a cache.  Global attention uses a
  positionally-indexed cache; local attention a ring buffer of ``window``
  slots; MLA uses the **latent cache + weight absorption** (the memory win
  that motivates MLA — expanding per-head K/V for 32k cached tokens would be
  O(S·H·hd)).

Keys are RoPE-rotated at *write* time, so cached keys never re-rotate.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LOCAL_ATTN, ModelConfig
from repro.models.layers import Ctx, apply_rope, rms_norm, softcap

Cache = Dict[str, jax.Array]
NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Core: blocked online-softmax attention (full mode)
# ---------------------------------------------------------------------------
def flash_attention_jnp(
    q: jax.Array,          # (B, Sq, H, hd)   positions 0..Sq-1
    k: jax.Array,          # (B, Sk, K, hd)   positions 0..Sk-1
    v: jax.Array,          # (B, Sk, K, vd)
    *,
    scale: float,
    causal: bool = True,
    window: int = 0,       # 0 = unlimited
    logit_cap: float = 0.0,
    q_block: int = 1024,
    kv_block: int = 1024,
) -> jax.Array:
    """Blocked flash attention with *static* block skipping.

    Positions are arange on both sides (full/prefill self-attention; for
    non-causal cross-attention every block is live).  The (q, kv) block loop
    is a python double loop, NOT lax.scan, intentionally:

    * blocks dead under the causal/window mask are skipped at trace time —
      causal costs ~S²/2, sliding-window costs O(S·W) instead of O(S²);
    * XLA's cost model counts while-bodies once; inline blocks keep the
      dry-run roofline FLOPs exact.

    This mirrors the grid of kernels/flash_attention.  fp32 accumulation.
    """
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    vd = v.shape[-1]
    qg = q.reshape(B, Sq, K, G, hd).astype(jnp.float32) * scale

    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    outs = []
    for q0 in range(0, Sq, q_block):
        q1 = min(q0 + q_block, Sq)
        nq = q1 - q0
        m = jnp.full((B, nq, K, G), NEG_INF, jnp.float32)
        l = jnp.zeros((B, nq, K, G), jnp.float32)
        acc = jnp.zeros((B, nq, K, G, vd), jnp.float32)
        qc = qg[:, q0:q1]
        for t0 in range(0, Sk, kv_block):
            t1 = min(t0 + kv_block, Sk)
            if causal and t0 > q1 - 1:
                continue                       # entirely in the future
            if window and t1 - 1 < q0 - window + 1:
                continue                       # entirely before the window
            kc = k[:, t0:t1].astype(jnp.float32)
            vc = v[:, t0:t1].astype(jnp.float32)
            s = jnp.einsum("bskgd,btkd->bskgt", qc, kc)
            s = softcap(s, logit_cap)
            need_mask = (causal and t1 - 1 > q0) or \
                        (window and t0 < q1 - 1 - window + 1)
            if need_mask:
                pq = q0 + jnp.arange(nq)
                pk = t0 + jnp.arange(t1 - t0)
                valid = jnp.ones((nq, t1 - t0), bool)
                if causal:
                    valid &= pk[None, :] <= pq[:, None]
                if window:
                    valid &= pq[:, None] - pk[None, :] < window
                s = jnp.where(valid[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bskgt,btkd->bskgd", p, vc)
            m = m_new
        outs.append(acc / jnp.maximum(l, 1e-37)[..., None])
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out.reshape(B, Sq, H, vd).astype(q.dtype)


def decode_attention_jnp(
    q: jax.Array,          # (B, 1, H, hd)
    k: jax.Array,          # (B, K, Skv, hd)  cache layout, already rotated
    v: jax.Array,          # (B, K, Skv, vd)
    pos_k: jax.Array,      # (Skv,) or (B, Skv) absolute positions; -1 = invalid
    pos_q: jax.Array,      # scalar, or (B,) per-sequence positions
    *,
    scale: float,
    window: int = 0,
    logit_cap: float = 0.0,
) -> jax.Array:
    """One-token attention against a cache.  ``pos_k``/``pos_q`` may carry a
    leading batch dim (continuous batching decodes sequences at different
    positions); 1-D / scalar forms broadcast — the lockstep fast path."""
    B, _, H, hd = q.shape
    K = k.shape[1]
    G = H // K
    vd = v.shape[-1]
    qg = q.reshape(B, K, G, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bktd->bkgt", qg, k.astype(jnp.float32))
    s = softcap(s, logit_cap)
    pk = pos_k if pos_k.ndim == 2 else pos_k[None, :]          # (B|1, Skv)
    pq = jnp.reshape(jnp.asarray(pos_q, jnp.int32), (-1, 1))   # (B|1, 1)
    valid = (pk >= 0) & (pk <= pq)
    if window:
        valid = valid & (pq - pk < window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, 1, H, vd).astype(q.dtype)


def prefill_attention_paged(
    q: jax.Array,            # (B, S0, H, hd) chunk queries, rotated
    k_pages: jax.Array,      # (P, K, page_size, hd) shared physical pool
    v_pages: jax.Array,      # (P, K, page_size, vd)
    page_table: jax.Array,   # (B, pages_per_seq) int32; -1 = unallocated
    pos_q: jax.Array,        # (B, S0) absolute positions of the chunk queries
    lengths: jax.Array,      # (B,) valid chunk tokens; 0 = inactive row
    *,
    scale: float,
    logit_cap: float = 0.0,
) -> jax.Array:
    """Chunked-prefill attention over the page table (prefix caching).

    The chunk's own K/V were already written into the pool
    (``_write_prefill_paged_offset`` — write-then-read), so one masked walk
    serves both the *cached prefix* (shared, possibly aliased pages holding
    positions ``< pos_q``) and within-chunk causality: a key at slot ``t``
    of an allocated page is live iff ``t <= pos_q[b, s]``.  Rows with
    ``lengths == 0`` (slots mid-decode in a continuous batch) return zero
    rows the caller ignores.

    Like ``decode_attention_paged`` this is the reference-grade walk: the
    gather materializes the table-bounded (B, pps·ps, K, hd) view.  Tail
    chunks are short under prefix caching (the whole point), so the
    transient (B, S0, K, G, T) score block stays small; a Pallas chunk
    kernel is future work."""
    B, S0, H, hd = q.shape
    _, K, ps, _ = k_pages.shape
    G = H // K
    pps = page_table.shape[1]
    T = pps * ps
    kb = jnp.take(k_pages, page_table, axis=0, mode="fill",
                  fill_value=0)                      # (B, pps, K, ps, hd)
    vb = jnp.take(v_pages, page_table, axis=0, mode="fill", fill_value=0)
    kb = kb.transpose(0, 2, 1, 3, 4).reshape(B, K, T, kb.shape[-1])
    vb = vb.transpose(0, 2, 1, 3, 4).reshape(B, K, T, vb.shape[-1])
    pos_k = jnp.where(jnp.repeat(page_table >= 0, ps, axis=1),
                      jnp.arange(T, dtype=jnp.int32)[None, :], -1)   # (B, T)
    qg = q.reshape(B, S0, K, G, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bskgd,bktd->bskgt", qg, kb.astype(jnp.float32))
    s = softcap(s, logit_cap)
    valid = (pos_k[:, None, :] >= 0) \
        & (pos_k[:, None, :] <= pos_q[:, :, None]) \
        & (jnp.arange(S0, dtype=jnp.int32)[None, :, None]
           < lengths.astype(jnp.int32)[:, None, None])               # (B,S0,T)
    vm = valid[:, :, None, None, :]
    s = jnp.where(vm, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    # mask p explicitly: fully-dead rows (inactive slots) would otherwise
    # see exp(NEG_INF - NEG_INF) == 1 (NEG_INF is a finite sentinel)
    p = jnp.where(vm, jnp.exp(s - m), 0.0)
    l = p.sum(axis=-1)
    out = jnp.einsum("bskgt,bktd->bskgd", p, vb.astype(jnp.float32))
    out = out / jnp.maximum(l, 1e-37)[..., None]
    return out.reshape(B, S0, H, vb.shape[-1]).astype(q.dtype)


def decode_attention_paged(
    q: jax.Array,            # (B, 1, H, hd)
    k_pages: jax.Array,      # (P, K, page_size, hd) shared physical pool
    v_pages: jax.Array,      # (P, K, page_size, vd)
    page_table: jax.Array,   # (B, pages_per_seq) int32; -1 = unallocated
    pos_q: jax.Array,        # scalar or (B,) current position per sequence
    *,
    scale: float,
    logit_cap: float = 0.0,
) -> jax.Array:
    """Paged decode attention: walk each sequence's page table, gather its
    pages from the pool, and run the same masked one-token softmax as the
    dense path.  Slot ``t`` of a sequence holds position ``t`` (global
    caches are position-indexed), so validity is ``t <= pos_q`` AND the
    page being allocated — identical math to the dense layout, which is
    what makes the paged/dense equivalence test exact.

    This is the *reference* walk: the gather materializes the table-bounded
    (B, pps·ps, K, hd) view, so per-step transient memory is bounded by the
    page-table length, not by what's resident.  The serving hot path uses
    ``kernels.paged_attention`` instead (Pallas flash-decode over the page
    table, or the O(pages) ``lax.scan`` fallback); this walk stays as the
    equivalence oracle and the benchmark baseline."""
    B = q.shape[0]
    _, K, ps, hd = k_pages.shape
    pps = page_table.shape[1]
    # fill-mode gather: -1 entries are out of bounds and fill with zeros —
    # the old clamp-to-0 gathered (and paid the bandwidth of) page 0 for
    # every unallocated entry
    kb = jnp.take(k_pages, page_table, axis=0, mode="fill",
                  fill_value=0)                      # (B, pps, K, ps, hd)
    vb = jnp.take(v_pages, page_table, axis=0, mode="fill", fill_value=0)
    T = pps * ps
    kb = kb.transpose(0, 2, 1, 3, 4).reshape(B, K, T, kb.shape[-1])
    vb = vb.transpose(0, 2, 1, 3, 4).reshape(B, K, T, vb.shape[-1])
    pos_k = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    alloc = jnp.repeat(page_table >= 0, ps, axis=1)  # (B, T)
    pos_k = jnp.where(alloc, pos_k, -1)
    return decode_attention_jnp(q, kb, vb, pos_k, pos_q, scale=scale,
                                logit_cap=logit_cap)


def mla_prefill_attention_paged(
    q_eff: jax.Array,        # (B, S0, H, lora) — W_kc-absorbed queries
    q_rope: jax.Array,       # (B, S0, H, rd)   — rotated rope queries
    ckv_pages: jax.Array,    # (P, page_size, lora) shared latent pool
    krope_pages: jax.Array,  # (P, page_size, rd)
    page_table: jax.Array,   # (B, pages_per_seq) int32; -1 = unallocated
    pos_q: jax.Array,        # (B, S0) absolute positions of the chunk queries
    lengths: jax.Array,      # (B,) valid chunk tokens; 0 = inactive row
    *,
    scale: float,
) -> jax.Array:
    """Chunked/ragged MLA prefill over the latent page table.

    The latent cache is MQA-shaped — ONE shared latent "kv head" serves
    all H query heads; scores are ``q_eff·ckv + q_rope·krope`` and the
    value read is the latent itself (``W_vc`` is applied outside).  Same
    write-then-read contract as :func:`prefill_attention_paged`: the
    chunk's latents were already scattered into the pool, so one masked
    walk covers the cached prefix and within-chunk causality.  Returns
    the latent context (B, S0, H, lora)."""
    B, S0, H, lora = q_eff.shape
    ps = ckv_pages.shape[1]
    pps = page_table.shape[1]
    T = pps * ps
    cb = jnp.take(ckv_pages, page_table, axis=0, mode="fill",
                  fill_value=0)                      # (B, pps, ps, lora)
    rb = jnp.take(krope_pages, page_table, axis=0, mode="fill", fill_value=0)
    cb = cb.reshape(B, T, lora)
    rb = rb.reshape(B, T, rb.shape[-1])
    pos_k = jnp.where(jnp.repeat(page_table >= 0, ps, axis=1),
                      jnp.arange(T, dtype=jnp.int32)[None, :], -1)   # (B, T)
    s = jnp.einsum("bshl,btl->bsht", q_eff.astype(jnp.float32),
                   cb.astype(jnp.float32))
    s = s + jnp.einsum("bshr,btr->bsht", q_rope.astype(jnp.float32),
                       rb.astype(jnp.float32))
    s = s * scale
    valid = (pos_k[:, None, :] >= 0) \
        & (pos_k[:, None, :] <= pos_q[:, :, None]) \
        & (jnp.arange(S0, dtype=jnp.int32)[None, :, None]
           < lengths.astype(jnp.int32)[:, None, None])               # (B,S0,T)
    vm = valid[:, :, None, :]
    s = jnp.where(vm, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    # explicit p-masking: fully-dead rows would see exp(NEG_INF-NEG_INF)==1
    p = jnp.where(vm, jnp.exp(s - m), 0.0)
    l = p.sum(axis=-1)
    ctx_lat = jnp.einsum("bsht,btl->bshl", p, cb.astype(jnp.float32))
    return (ctx_lat / jnp.maximum(l, 1e-37)[..., None]).astype(q_eff.dtype)


def mla_decode_attention_paged(
    q_eff: jax.Array,        # (B, H, lora)
    q_rope: jax.Array,       # (B, H, rd)
    ckv_pages: jax.Array,    # (P, page_size, lora)
    krope_pages: jax.Array,  # (P, page_size, rd)
    page_table: jax.Array,   # (B, pages_per_seq)
    pos_q: jax.Array,        # scalar or (B,)
    *,
    scale: float,
) -> jax.Array:
    """Reference paged MLA decode walk (gather + dense softmax) — the
    equivalence oracle for the Pallas kernel / scan fallback.  Returns the
    latent context (B, H, lora); rows with ``pos_q < 0`` return zeros."""
    B, H, lora = q_eff.shape
    ps = ckv_pages.shape[1]
    pps = page_table.shape[1]
    T = pps * ps
    cb = jnp.take(ckv_pages, page_table, axis=0, mode="fill",
                  fill_value=0).reshape(B, T, lora)
    rb = jnp.take(krope_pages, page_table, axis=0, mode="fill",
                  fill_value=0).reshape(B, T, krope_pages.shape[-1])
    pos_k = jnp.where(jnp.repeat(page_table >= 0, ps, axis=1),
                      jnp.arange(T, dtype=jnp.int32)[None, :], -1)   # (B, T)
    s = jnp.einsum("bhl,btl->bht", q_eff.astype(jnp.float32),
                   cb.astype(jnp.float32))
    s = s + jnp.einsum("bhr,btr->bht", q_rope.astype(jnp.float32),
                       rb.astype(jnp.float32))
    s = s * scale
    pq = jnp.reshape(jnp.broadcast_to(jnp.asarray(pos_q, jnp.int32), (B,)),
                     (B, 1))
    valid = (pos_k >= 0) & (pos_k <= pq)                             # (B, T)
    vm = valid[:, None, :]
    s = jnp.where(vm, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.where(vm, jnp.exp(s - m), 0.0)
    l = p.sum(axis=-1)
    ctx_lat = jnp.einsum("bht,btl->bhl", p, cb.astype(jnp.float32))
    return (ctx_lat / jnp.maximum(l, 1e-37)[..., None]).astype(q_eff.dtype)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------
def _attn_scale(cfg: ModelConfig) -> float:
    if cfg.query_pre_attn_scalar > 0:
        return cfg.query_pre_attn_scalar ** -0.5
    return cfg.head_dim ** -0.5


def gqa_attention(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,
    ctx: Ctx,
    *,
    kind: str,
    mode: str,                      # full | decode
    cache: Optional[Cache],
    pos: jax.Array,                 # full: (S,) positions; decode: scalar
    cross_kv: Optional[jax.Array] = None,   # encoder output for cross-attn
    is_cross: bool = False,
    causal: bool = True,
    lengths: Optional[jax.Array] = None,    # ragged prefill: (B,) true lens
) -> Tuple[jax.Array, Optional[Cache]]:
    B = x.shape[0]
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    window = cfg.window_size if kind == LOCAL_ATTN else 0
    scale = _attn_scale(cfg)

    q = jnp.einsum("bsd,dhk->bshk", x, p["q"].astype(x.dtype))
    if "qb" in p:
        q = q + p["qb"].astype(q.dtype)

    is_cross = is_cross or cross_kv is not None
    kv_src = cross_kv if cross_kv is not None else x
    if mode == "decode" and is_cross and cache is not None:
        # encoder K/V precomputed at prefill; cache layout (B, K, S, hd)
        k, v = cache["k"], cache["v"]
        new_cache = cache
    else:
        k = jnp.einsum("bsd,dhk->bshk", kv_src, p["k"].astype(kv_src.dtype))
        v = jnp.einsum("bsd,dhk->bshk", kv_src, p["v"].astype(kv_src.dtype))
        if "kb" in p:
            k = k + p["kb"].astype(k.dtype)
            v = v + p["vb"].astype(v.dtype)
        new_cache = None

    fresh_kv = not (mode == "decode" and is_cross and cache is not None)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        if fresh_kv:
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    if mode == "full":
        q = ctx.constrain(q, ("batch", "seq", "heads", None))
        if not is_cross:
            k = apply_rope(k, pos, cfg.rope_theta)
        q = apply_rope(q, pos, cfg.rope_theta) if not is_cross else q
        if is_cross:
            out = flash_attention_jnp(
                q, k, v, scale=scale, causal=False,
                logit_cap=cfg.attn_logit_softcap,
                q_block=ctx.attn_q_block, kv_block=ctx.attn_kv_block)
            if cache is not None:       # prefill: stash encoder K/V
                new_cache = {"k": k.transpose(0, 2, 1, 3).astype(cache["k"].dtype),
                             "v": v.transpose(0, 2, 1, 3).astype(cache["v"].dtype)}
        elif pos.ndim == 2:
            # chunked prefix prefill (prefix caching): per-row absolute
            # positions — the chunk opens at each row's first uncached
            # token, and the cached prefix K/V already sit in (possibly
            # aliased) pages.  Write-then-read: the chunk's K/V go into
            # the private tail pages first, then ONE masked paged walk
            # covers both the cached prefix and within-chunk causality.
            if cache is None or "k_pages" not in cache or window:
                raise NotImplementedError(
                    "chunked prefix prefill needs the paged global layout")
            assert lengths is not None, "chunked prefill is ragged-only"
            new_cache = _write_prefill_paged_offset(cache, k, v, lengths, pos)
            out = prefill_attention_paged(
                q, new_cache["k_pages"], new_cache["v_pages"],
                new_cache["page_table"], pos, lengths,
                scale=scale, logit_cap=cfg.attn_logit_softcap)
        else:
            S = q.shape[1]
            if ctx.use_pallas and S % 128 == 0:
                from repro.kernels.ops import flash_attention_bshd
                out = flash_attention_bshd(
                    q, k, v, scale=scale, causal=causal, window=window,
                    logit_cap=cfg.attn_logit_softcap)
            else:
                out = flash_attention_jnp(
                    q, k, v, scale=scale, causal=causal, window=window,
                    logit_cap=cfg.attn_logit_softcap,
                    q_block=ctx.attn_q_block, kv_block=ctx.attn_kv_block)
            if cache is not None:       # prefill: write the kv cache
                if "k_pages" in cache:
                    new_cache = _write_prefill_paged(cache, k, v,
                                                     lengths=lengths)
                elif lengths is not None:
                    if not window:
                        raise NotImplementedError(
                            "ragged prefill needs the paged layout for "
                            "global layers (dense caches are lockstep-only)")
                    # works for the true ring (W == window) and the short
                    # dense-local buffer (W == S_max < window) alike: the
                    # mod-W gather degenerates to the identity there
                    new_cache = _write_prefill_ring_ragged(
                        cache, k, v, lengths, cache["k"].shape[2])
                else:
                    new_cache = _write_full_kv(cache, k, v, pos, window)
    else:  # decode, self-attention
        # pos: scalar (lockstep batch) or (B,) per-sequence positions
        # (continuous batching; inactive slots carry -1).
        pos_r = jnp.reshape(pos, (-1, 1)) if pos.ndim else pos[None]
        q = apply_rope(q, pos_r, cfg.rope_theta)
        if not is_cross:
            k = apply_rope(k, pos_r, cfg.rope_theta)
            if "k_pages" in cache:
                assert not window, \
                    "paged layout covers global layers; local layers ring"
                new_cache = _update_decode_kv_paged(cache, k, v, pos)
                kp, vp = new_cache["k_pages"], new_cache["v_pages"]
                pt = new_cache["page_table"]
                posb = jnp.broadcast_to(
                    jnp.reshape(jnp.asarray(pos, jnp.int32), (-1,)),
                    (B,))
                if ctx.use_pallas:
                    from repro.kernels.ops import paged_decode_bhd
                    out = paged_decode_bhd(
                        q, kp, vp, pt, posb, scale=scale,
                        logit_cap=cfg.attn_logit_softcap)
                else:
                    # O(pages) lax.scan walk — same contract as the kernel
                    from repro.kernels.paged_attention import paged_decode_jnp
                    out = paged_decode_jnp(
                        q.reshape(B, K, H // K, hd), kp, vp, pt, posb,
                        scale=scale,
                        logit_cap=cfg.attn_logit_softcap).reshape(B, 1, H, hd)
            else:
                new_cache, k_all, v_all, pos_all = _update_decode_kv(
                    cache, k, v, pos, window)
                out = decode_attention_jnp(
                    q, k_all, v_all, pos_all, pos, scale=scale, window=window,
                    logit_cap=cfg.attn_logit_softcap)
        else:
            if fresh_kv:   # cross-attn decode without a prefilled cache
                k = k.transpose(0, 2, 1, 3)
                v = v.transpose(0, 2, 1, 3)
            pos_k = jnp.arange(k.shape[2], dtype=jnp.int32)
            out = decode_attention_jnp(
                q, k, v, pos_k, jnp.asarray(2**30, jnp.int32), scale=scale,
                logit_cap=cfg.attn_logit_softcap)
            new_cache = cache

    out = ctx.constrain(out, ("batch", "seq", "heads", None))
    return jnp.einsum("bshk,hkd->bsd", out, p["o"].astype(out.dtype)), new_cache


def _write_full_kv(cache: Cache, k, v, pos, window: int) -> Cache:
    """Prefill: write rotated K/V into the cache buffer.

    Cache layout (B, K, S_max, hd).  Global cache is position-indexed with a
    shared ``pos (S_max,)`` slot map (prefill is lockstep); local cache keeps
    a ring of ``window`` slots with a *per-sequence* ``pos (B, W)`` map —
    slot = pos % window."""
    S_max = cache["k"].shape[2]
    k = k.transpose(0, 2, 1, 3)      # (B,S,K,hd) -> (B,K,S,hd)
    v = v.transpose(0, 2, 1, 3)
    if window and S_max == window:
        # ring buffer: only the last `window` positions survive; slicing to
        # them first makes the scatter indices unique (well-defined).
        k, v, pos = k[:, :, -window:], v[:, :, -window:], pos[-window:]
        slots = pos % window
        ck = cache["k"].at[:, :, slots].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[:, :, slots].set(v.astype(cache["v"].dtype))
        cp = cache["pos"].at[:, slots].set(pos[None, :].astype(jnp.int32))
        return {"k": ck, "v": cv, "pos": cp}
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), pos[0], axis=2)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), pos[0], axis=2)
    cp = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], pos.astype(jnp.int32), pos[0], axis=0)
    return {"k": ck, "v": cv, "pos": cp}


def _write_prefill_paged(cache: Cache, k, v,
                         lengths: Optional[jax.Array] = None) -> Cache:
    """Prefill into the paged layout: walk logical pages 0..ceil(S0/ps)-1 of
    each sequence's page table and write the K/V chunks into the pool.
    ``k, v`` arrive as (B, S0, K, hd), rotated; prefill always starts at
    position 0, so the page loop is static.

    ``lengths`` (ragged prefill) masks the walk per row: row ``b`` writes
    only pages holding tokens ``< lengths[b]`` — rows with length 0 (slots
    mid-decode in a continuous batch) touch nothing.  Unallocated entries
    scatter out of bounds and are dropped (the old clamp wrote rows whose
    table was shorter than the padded batch onto physical page 0)."""
    kp, vp, pt = cache["k_pages"], cache["v_pages"], cache["page_table"]
    ps = kp.shape[2]
    S0 = k.shape[1]
    k = k.transpose(0, 2, 1, 3)      # (B, K, S0, hd)
    v = v.transpose(0, 2, 1, 3)
    oob = jnp.int32(kp.shape[0])     # one past the pool: mode="drop" target
    for i in range((S0 + ps - 1) // ps):
        lo, hi = i * ps, min((i + 1) * ps, S0)
        write = pt[:, i] >= 0
        if lengths is not None:
            write = write & (lo < lengths)
        phys = jnp.where(write, pt[:, i], oob)       # (B,) physical pages
        kp = kp.at[phys, :, :hi - lo].set(k[:, :, lo:hi].astype(kp.dtype),
                                          mode="drop")
        vp = vp.at[phys, :, :hi - lo].set(v[:, :, lo:hi].astype(vp.dtype),
                                          mode="drop")
    return {"k_pages": kp, "v_pages": vp, "page_table": pt}


def _write_prefill_paged_offset(cache: Cache, k, v, lengths, pos) -> Cache:
    """Offset form of :func:`_write_prefill_paged` for chunked prefix
    prefill: the chunk's token ``s`` of row ``b`` lands at absolute
    position ``pos[b, s]`` (= the row's first uncached position + s), so
    the page walk cannot be a static loop — scatter per token instead.

    Only tokens ``s < lengths[b]`` write.  The engine's CoW rule
    guarantees a chunk never writes a *shared* page (the first written
    page is always a private copy), so scatter targets are unique.
    Invalid rows / unallocated table entries redirect one past the pool
    and are dropped (``mode="drop"``)."""
    kp, vp, pt = cache["k_pages"], cache["v_pages"], cache["page_table"]
    B, S0 = k.shape[:2]
    ps = kp.shape[2]
    pps = pt.shape[1]
    pidx = pos // ps                                           # (B, S0)
    entry = jnp.take_along_axis(pt, jnp.clip(pidx, 0, pps - 1), axis=1)
    valid = (jnp.arange(S0, dtype=jnp.int32)[None, :]
             < lengths.astype(jnp.int32)[:, None]) \
        & (entry >= 0) & (pidx < pps)
    phys = jnp.where(valid, entry, jnp.int32(kp.shape[0]))     # (B, S0)
    off = pos % ps
    kp = kp.at[phys, :, off].set(k.astype(kp.dtype), mode="drop")
    vp = vp.at[phys, :, off].set(v.astype(vp.dtype), mode="drop")
    return {"k_pages": kp, "v_pages": vp, "page_table": pt}


def _write_prefill_ring_ragged(cache: Cache, k, v, lengths: jax.Array,
                               window: int) -> Cache:
    """Ragged prefill into a ring buffer: row ``b`` keeps the last
    ``min(window, lengths[b])`` of its *own* tokens (a lockstep tail slice
    would keep the tail of the padded batch, dropping short rows' real
    tokens whenever the padding exceeds the window).

    Gather formulation: for ring slot ``s``, the surviving token is the
    largest position ``t < lengths[b]`` with ``t ≡ s (mod window)`` — a
    per-row ``take_along_axis``, so indices are unique by construction.
    Slots with no surviving token (short rows) keep their previous
    contents and stay masked via the recorded ``pos`` map."""
    S0 = k.shape[1]
    W = cache["k"].shape[2]
    assert W == window, (W, window)
    k = k.transpose(0, 2, 1, 3)      # (B, K, S0, hd)
    v = v.transpose(0, 2, 1, 3)
    s = jnp.arange(W, dtype=jnp.int32)
    lm1 = lengths.astype(jnp.int32)[:, None] - 1               # (B, 1)
    t = lm1 - ((lm1 - s[None, :]) % W)                         # (B, W)
    valid = (lengths[:, None] > 0) & (t >= 0) & \
        (t >= lengths[:, None] - W)
    tc = jnp.clip(t, 0, S0 - 1)
    kg = jnp.take_along_axis(k, tc[:, None, :, None], axis=2)  # (B, K, W, hd)
    vg = jnp.take_along_axis(v, tc[:, None, :, None], axis=2)
    ck = jnp.where(valid[:, None, :, None], kg.astype(cache["k"].dtype),
                   cache["k"])
    cv = jnp.where(valid[:, None, :, None], vg.astype(cache["v"].dtype),
                   cache["v"])
    cp = jnp.where(valid, t, cache["pos"])
    return {"k": ck, "v": cv, "pos": cp}


def _update_decode_kv(cache: Cache, k, v, pos, window: int):
    """Insert one token's K/V; return (new_cache, k_all, v_all, pos_all).
    ``k, v`` arrive as (B, 1, K, hd); cache layout is (B, K, S, hd).

    ``pos`` may be per-sequence (B,) for ring buffers (continuous batching;
    inactive slots carry -1 and only dirty their own row).  Dense *global*
    caches are lockstep-only — per-sequence positions require the paged
    layout, which keeps the scatter per-row by construction."""
    ring = bool(window) and cache["k"].shape[2] == window
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    if pos.ndim == 1:
        if not ring:
            raise NotImplementedError(
                "per-sequence decode positions on a dense global cache; "
                "use cache_layout='paged' for continuous batching")
        B = k.shape[0]
        b = jnp.arange(B)
        slot = jnp.maximum(pos, 0) % window
        ck = cache["k"].at[b, :, slot].set(k[:, :, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[b, :, slot].set(v[:, :, 0].astype(cache["v"].dtype))
        cp = cache["pos"].at[b, slot].set(pos.astype(jnp.int32))
        return {"k": ck, "v": cv, "pos": cp}, ck, cv, cp
    slot = (pos % window) if ring else pos
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=2)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=2)
    if cache["pos"].ndim == 2:       # ring: per-sequence (B, W) slot map
        upd = jnp.broadcast_to(jnp.reshape(pos, (1, 1)),
                               (cache["pos"].shape[0], 1)).astype(jnp.int32)
        cp = jax.lax.dynamic_update_slice_in_dim(cache["pos"], upd, slot,
                                                 axis=1)
    else:                            # global: shared (S,) slot map
        cp = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.reshape(pos, (1,)).astype(jnp.int32), slot,
            axis=0)
    return {"k": ck, "v": cv, "pos": cp}, ck, cv, cp


def _update_decode_kv_paged(cache: Cache, k, v, pos) -> Cache:
    """Insert one token's K/V into the page pool.  ``k, v`` arrive as
    (B, 1, K, hd); ``pos`` is scalar or (B,).  Rows with pos < 0 (inactive
    slots) and unallocated page-table entries scatter out of bounds and are
    dropped — the pool needs no scratch page, so its size stays mesh-
    divisible."""
    kp, vp, pt = cache["k_pages"], cache["v_pages"], cache["page_table"]
    B = k.shape[0]
    ps = kp.shape[2]
    posb = jnp.broadcast_to(jnp.reshape(jnp.asarray(pos, jnp.int32), (-1,)),
                            (B,))
    posc = jnp.maximum(posb, 0)
    entry = jnp.take_along_axis(pt, (posc // ps)[:, None], axis=1)[:, 0]
    phys = jnp.where((posb >= 0) & (entry >= 0), entry, kp.shape[0])
    off = posc % ps
    kp = kp.at[phys, :, off].set(k[:, 0].astype(kp.dtype), mode="drop")
    vp = vp.at[phys, :, off].set(v[:, 0].astype(vp.dtype), mode="drop")
    return {"k_pages": kp, "v_pages": vp, "page_table": pt}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------
def _write_prefill_latent_paged(cache: Cache, ckv, krope, lengths,
                                pos) -> Cache:
    """Scatter a prefill chunk's latents into the paged latent pool.

    ``ckv (B, S0, lora)`` / ``krope (B, S0, rd)`` are the compressed
    latents and rotated rope keys; token ``s`` of row ``b`` lands at
    absolute position ``pos[b, s]`` (slot ``pos % ps`` of logical page
    ``pos // ps``).  Only tokens ``s < lengths[b]`` write; invalid rows
    and unallocated table entries redirect one past the pool and are
    dropped (``mode="drop"``) — same contract as the GQA writers."""
    cp, rp, pt = cache["ckv_pages"], cache["krope_pages"], cache["page_table"]
    B, S0 = ckv.shape[:2]
    ps = cp.shape[1]
    pps = pt.shape[1]
    pidx = pos // ps                                           # (B, S0)
    entry = jnp.take_along_axis(pt, jnp.clip(pidx, 0, pps - 1), axis=1)
    valid = (jnp.arange(S0, dtype=jnp.int32)[None, :]
             < lengths.astype(jnp.int32)[:, None]) \
        & (entry >= 0) & (pidx < pps)
    phys = jnp.where(valid, entry, jnp.int32(cp.shape[0]))     # (B, S0)
    off = pos % ps
    cp = cp.at[phys, off].set(ckv.astype(cp.dtype), mode="drop")
    rp = rp.at[phys, off].set(krope.astype(rp.dtype), mode="drop")
    return {"ckv_pages": cp, "krope_pages": rp, "page_table": pt}


def _update_decode_latent_paged(cache: Cache, ckv, krope, pos) -> Cache:
    """Insert one token's latent into the page pool.  ``ckv (B, lora)`` /
    ``krope (B, rd)``; ``pos`` is scalar or (B,).  Rows with pos < 0
    (inactive slots) and unallocated entries scatter out of bounds and are
    dropped."""
    cp, rp, pt = cache["ckv_pages"], cache["krope_pages"], cache["page_table"]
    B = ckv.shape[0]
    ps = cp.shape[1]
    posb = jnp.broadcast_to(jnp.reshape(jnp.asarray(pos, jnp.int32), (-1,)),
                            (B,))
    posc = jnp.maximum(posb, 0)
    entry = jnp.take_along_axis(pt, (posc // ps)[:, None], axis=1)[:, 0]
    phys = jnp.where((posb >= 0) & (entry >= 0), entry, cp.shape[0])
    off = posc % ps
    cp = cp.at[phys, off].set(ckv.astype(cp.dtype), mode="drop")
    rp = rp.at[phys, off].set(krope.astype(rp.dtype), mode="drop")
    return {"ckv_pages": cp, "krope_pages": rp, "page_table": pt}


def _mla_q(cfg: ModelConfig, p, x, pos) -> Tuple[jax.Array, jax.Array]:
    """Returns (q_nope (B,S,H,nope), q_rope (B,S,H,rd)) — rope applied."""
    nope, rd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        qa = rms_norm(x @ p["q_a"], p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsl,lhk->bshk", qa, p["q_b"].astype(qa.dtype))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["q"].astype(x.dtype))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    return q_nope, q_rope


def mla_attention(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,
    ctx: Ctx,
    *,
    mode: str,
    cache: Optional[Cache],
    pos: jax.Array,
    lengths: Optional[jax.Array] = None,   # ragged prefill: (B,) true lens
) -> Tuple[jax.Array, Optional[Cache]]:
    B = x.shape[0]
    H = cfg.num_heads
    nope, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lora = cfg.kv_lora_rank
    scale = (nope + rd) ** -0.5
    kv_b = p["kv_b"]                                      # (lora, H, nope+vd)
    w_kc = kv_b[..., :nope]                               # (lora, H, nope)
    w_vc = kv_b[..., nope:]                               # (lora, H, vd)

    kv_a = x @ p["kv_a"]                                  # (B,S,lora+rd)
    ckv = rms_norm(kv_a[..., :lora], p["kv_norm"], cfg.norm_eps)
    k_rope = kv_a[..., None, lora:]                       # (B,S,1,rd) shared head

    paged = cache is not None and "ckv_pages" in cache
    if mode == "full" and paged:
        # ---- paged latent prefill.  Writes always scatter the chunk's
        # latents into the pool (length-masked per row).  The attention
        # read splits like the GQA path: lockstep/ragged chunks opening at
        # position 0 score against the FRESH fp32 latents (matching the
        # dense oracle bit-for-bit in math — the pool stores the cache
        # dtype, and rounding keys through it would cost ~1e-3 vs dense),
        # while chunked prefix prefill (2-D pos) must read the pool — the
        # cached prefix only exists there, and both sides of a chunk split
        # see identical pool bytes, keeping replay bit-exact.
        S0 = x.shape[1]
        pos_q = pos if pos.ndim == 2 else \
            jnp.broadcast_to(pos[None, :], (B, S0))       # (B, S0) absolute
        lens = jnp.full((B,), S0, jnp.int32) if lengths is None \
            else lengths.astype(jnp.int32)
        q_nope, q_rope = _mla_q(cfg, p, x, pos)
        k_rope = apply_rope(k_rope, pos, cfg.rope_theta)
        new_cache = _write_prefill_latent_paged(
            cache, ckv, k_rope[:, :, 0], lens, pos_q)
        q_eff = jnp.einsum("bshe,lhe->bshl", q_nope, w_kc)
        if pos.ndim == 2:
            ctx_lat = mla_prefill_attention_paged(
                q_eff, q_rope, new_cache["ckv_pages"],
                new_cache["krope_pages"], new_cache["page_table"],
                pos_q, lens, scale=scale)
        else:
            # fresh-latent absorbed walk; causality isolates each row's
            # last valid query from the ragged padding keys (they sit at
            # later positions), exactly like the dense flash path
            s = jnp.einsum("bshl,btl->bsht", q_eff.astype(jnp.float32),
                           ckv.astype(jnp.float32))
            s = s + jnp.einsum("bshr,btr->bsht", q_rope.astype(jnp.float32),
                               k_rope[:, :, 0].astype(jnp.float32))
            s = s * scale
            causal = (jnp.arange(S0)[None, :, None]
                      >= jnp.arange(S0)[None, None, :])[:, :, None, :]
            s = jnp.where(causal, s, NEG_INF)
            pr = jax.nn.softmax(s, axis=-1)
            ctx_lat = jnp.einsum("bsht,btl->bshl", pr,
                                 ckv.astype(jnp.float32)).astype(x.dtype)
        out = jnp.einsum("bshl,lhe->bshe", ctx_lat.astype(x.dtype),
                         w_vc.astype(x.dtype))
    elif mode == "full":
        q_nope, q_rope = _mla_q(cfg, p, x, pos)
        k_rope = apply_rope(k_rope, pos, cfg.rope_theta)
        kv = jnp.einsum("bsl,lhe->bshe", ckv, kv_b.astype(ckv.dtype))  # expand
        k_nope, v = kv[..., :nope], kv[..., nope:]
        # fold the shared rope head into per-head keys
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (*k_rope.shape[:2], H, rd))], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)
        q = ctx.constrain(q, ("batch", "seq", "heads", None))
        out = flash_attention_jnp(
            q, k, v, scale=scale, causal=True,
            q_block=ctx.attn_q_block, kv_block=ctx.attn_kv_block)
        new_cache = None
        if cache is not None:
            c = jax.lax.dynamic_update_slice_in_dim(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), pos[0], axis=1)
            r = jax.lax.dynamic_update_slice_in_dim(
                cache["krope"], k_rope[:, :, 0].astype(cache["krope"].dtype),
                pos[0], axis=1)
            cp = jax.lax.dynamic_update_slice_in_dim(
                cache["pos"], pos.astype(jnp.int32), pos[0], axis=0)
            new_cache = {"ckv": c, "krope": r, "pos": cp}
    elif paged:
        # ---- paged latent decode: per-sequence positions (continuous
        # batching; inactive slots carry -1).  Weight absorption makes the
        # walk MQA-shaped — H query heads against ONE latent kv head of
        # width lora+rd — so bytes/step are the latent pages, not the
        # hypothetical expanded K/V.
        posb = jnp.broadcast_to(
            jnp.reshape(jnp.asarray(pos, jnp.int32), (-1,)), (B,))
        pos_r = jnp.reshape(posb, (-1, 1))                # (B, 1) for rope
        q_nope, q_rope = _mla_q(cfg, p, x, pos_r)
        k_rope = apply_rope(k_rope, pos_r, cfg.rope_theta)
        new_cache = _update_decode_latent_paged(
            cache, ckv[:, 0], k_rope[:, 0, 0], posb)
        cp_pages, rp_pages = new_cache["ckv_pages"], new_cache["krope_pages"]
        pt = new_cache["page_table"]
        q_eff = jnp.einsum("bshe,lhe->bshl", q_nope, w_kc)  # (B,1,H,lora)
        if ctx.use_pallas:
            from repro.kernels.ops import mla_paged_decode_bhd
            q_lat = jnp.concatenate([q_eff[:, 0], q_rope[:, 0]], -1)
            ctx_lat = mla_paged_decode_bhd(
                q_lat, cp_pages, rp_pages, pt, posb, scale=scale)
        else:
            from repro.kernels.paged_attention import mla_paged_decode_jnp
            q_lat = jnp.concatenate([q_eff[:, 0], q_rope[:, 0]], -1)
            ctx_lat = mla_paged_decode_jnp(
                q_lat, cp_pages, rp_pages, pt, posb, scale=scale)
        out = jnp.einsum("bshl,lhe->bshe", ctx_lat[:, None].astype(x.dtype),
                         w_vc.astype(x.dtype))
    else:
        # ---- dense decode with weight absorption: score and read in
        # latent space against the lockstep dense latent cache
        assert pos.ndim == 0, \
            "per-sequence MLA decode positions need the paged latent " \
            "cache (cache_layout='paged'); the dense cache is lockstep-only"
        q_nope, q_rope = _mla_q(cfg, p, x, pos[None] if pos.ndim == 0 else pos)
        k_rope = apply_rope(k_rope, jnp.reshape(pos, (1,)), cfg.rope_theta)
        c_new = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), pos, axis=1)
        r_new = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope[:, :, 0].astype(cache["krope"].dtype),
            pos, axis=1)
        cp = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.reshape(pos, (1,)).astype(jnp.int32), pos, axis=0)
        new_cache = {"ckv": c_new, "krope": r_new, "pos": cp}

        q_eff = jnp.einsum("bshe,lhe->bshl", q_nope, w_kc)  # absorb W_kc
        s = jnp.einsum("bshl,btl->bsht", q_eff.astype(jnp.float32),
                       c_new.astype(jnp.float32))
        s = s + jnp.einsum("bshr,btr->bsht", q_rope.astype(jnp.float32),
                           r_new.astype(jnp.float32))
        s = s * scale
        valid = (cp >= 0) & (cp <= pos)
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        ctx_lat = jnp.einsum("bsht,btl->bshl", pr, c_new.astype(jnp.float32))
        out = jnp.einsum("bshl,lhe->bshe", ctx_lat.astype(x.dtype),
                         w_vc.astype(x.dtype))

    out = ctx.constrain(out, ("batch", "seq", "heads", None))
    return jnp.einsum("bshe,hed->bsd", out, p["o"].astype(out.dtype)), new_cache
