"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block:  x-branch = conv1d(W_x · u)  →  RG-LRU  ;  y-branch = GeLU(W_y · u)
        out = W_o (y ⊙ RGLRU(x))

RG-LRU (per channel):
    r_t = σ(x_t W_r),  i_t = σ(x_t W_i)
    a_t = exp(c · r_t · log σ(Λ))        (c = -8 as in Griffin §2.4)
    h_t = a_t h_{t-1} + sqrt(1 - a_t²) · (i_t ⊙ x_t)

Full mode uses ``jax.lax.associative_scan`` over the affine maps
(h → a·h + b), which is O(S log S) elementwise work and maps well onto TPU
vector units; the Pallas kernel (kernels/rglru_scan) is a time-blocked
sequential scan with the carry in VMEM.  Decode carries (h, conv window).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Ctx

RGLRU_C = 8.0


def rglru_gates(p, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(log_a, beta·x_gated): per-step decay (log-space) and input."""
    r = jax.nn.sigmoid((x @ p["gate_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ p["gate_i"]).astype(jnp.float32))
    log_lam = -jax.nn.softplus(-p["rglru_lambda"].astype(jnp.float32))  # log σ(Λ)
    log_a = RGLRU_C * r * log_lam                          # (B,S,R), ≤ 0
    a_sq = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a_sq, 1e-12)) * i * x.astype(jnp.float32)
    return log_a, gated


def rglru_scan_assoc(log_a: jax.Array, b: jax.Array,
                     h0: Optional[jax.Array] = None) -> jax.Array:
    """h_t = exp(log_a_t)·h_{t-1} + b_t via associative scan over dim 1."""
    if h0 is not None:
        # fold the incoming state into the first step's additive term
        b = b.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)
        log_a = log_a.at[:, 0].set(0.0)

    def combine(l, r):
        (la1, b1), (la2, b2) = l, r
        return la1 + la2, b1 * jnp.exp(la2) + b2

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    return h


def conv1d_causal(p, x: jax.Array, state: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over time.  ``state`` is the trailing
    (CW-1)-step window from the previous segment (decode), zeros for full."""
    CW = p["conv_w"].shape[0]
    B, S, R = x.shape
    if state is None:
        state = jnp.zeros((B, CW - 1, R), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(CW):
        out = out + xp[:, i:i + S] * p["conv_w"][i].astype(x.dtype)
    out = out + p["conv_b"].astype(x.dtype)
    new_state = xp[:, -(CW - 1):]
    return out, new_state


def rglru_block(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    u: jax.Array,                  # (B, S, D)
    ctx: Ctx,
    *,
    mode: str,
    cache: Optional[Dict[str, jax.Array]],
    lengths: Optional[jax.Array] = None,   # ragged prefill: (B,) true lens
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, _ = u.shape
    x = u @ p["wx"]                                        # (B,S,R)
    x = ctx.constrain(x, ("batch", "seq", "rnn"))
    y = jax.nn.gelu(u @ p["wy"], approximate=True)
    y = ctx.constrain(y, ("batch", "seq", "rnn"))

    conv_state = cache["conv"] if cache is not None and mode == "decode" else None
    xc, new_conv = conv1d_causal(p, x, conv_state)

    log_a, b = rglru_gates(p, xc)
    if lengths is not None and mode != "decode":
        # ragged prefill: padding steps neither read nor write the carry —
        # decay 1 (log_a = 0) and input 0 make h coast, so the scan's LAST
        # step already holds each row's h[lengths-1]
        lens = lengths.astype(jnp.int32)
        pad_t = (jnp.arange(S, dtype=jnp.int32)[None, :]
                 >= lens[:, None])[..., None]              # (B,S,1)
        log_a = jnp.where(pad_t, 0.0, log_a)
        b = jnp.where(pad_t, 0.0, b)
    if mode == "decode":
        h_prev = cache["h"].astype(jnp.float32)
        h = jnp.exp(log_a[:, 0]) * h_prev + b[:, 0]
        h_seq = h[:, None]
        new_cache = {"h": h.astype(cache["h"].dtype), "conv": new_conv}
    else:
        if ctx.use_pallas:
            from repro.kernels.ops import rglru_scan_bsr
            h_seq = rglru_scan_bsr(log_a, b)
        else:
            h_seq = rglru_scan_assoc(log_a, b)
        new_cache = None
        if cache is not None:   # prefill: expose final state
            h_fin = h_seq[:, -1]
            conv_fin = new_conv
            if lengths is not None:
                # per-row conv window: the CW-1 pre-conv inputs ENDING at
                # each row's last valid step (lengths == S degenerates to
                # the trailing window new_conv holds)
                CW = p["conv_w"].shape[0]
                xp = jnp.concatenate(
                    [jnp.zeros((B, CW - 1, x.shape[-1]), x.dtype), x], axis=1)
                idx = lens[:, None] + jnp.arange(CW - 1, dtype=jnp.int32)
                conv_fin = jnp.take_along_axis(xp, idx[..., None], axis=1)
                # length-0 rows are active slots mid-decode: keep their state
                keep = (lens > 0)
                h_fin = jnp.where(keep[:, None], h_fin,
                                  cache["h"].astype(h_fin.dtype))
                conv_fin = jnp.where(keep[:, None, None], conv_fin,
                                     cache["conv"].astype(conv_fin.dtype))
            new_cache = {"h": h_fin.astype(cache["h"].dtype),
                         "conv": conv_fin.astype(cache["conv"].dtype)}
    h_seq = h_seq.astype(u.dtype)
    out = (y * h_seq) @ p["wo"]
    return out, new_cache
