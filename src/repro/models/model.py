"""Model assembly: embedding → scanned block stacks → logits.

One substrate serves all 10 assigned architectures; the per-layer *block
pattern* (global/local attention, RG-LRU, RWKV) plus feature flags (MLA, MoE,
enc-dec, frontend stubs) come from :class:`ModelConfig`.

Layer stacks are grouped for ``jax.lax.scan`` (compile-time & HLO size):
``num_layers`` = prefix (unrolled, e.g. DeepSeek first-k-dense) + n_groups ×
pattern (scanned, stacked weights) + tail (unrolled remainder).  KV caches
carry a matching leading group dim and are threaded through the scan as xs/ys.

Modes
-----
* ``train``   — tokens → logits for every position (loss in repro.train).
* ``prefill`` — tokens → last-position logits + a filled cache.
* ``decode``  — one token + cache + pos → next logits + updated cache.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    GLOBAL_ATTN,
    LOCAL_ATTN,
    RECURRENT,
    RWKV,
    ModelConfig,
)
from repro.models import params as P
from repro.models.attention import gqa_attention, mla_attention
from repro.models.layers import Ctx, dense_ffn, rms_norm
from repro.models.moe import moe_ffn
from repro.models.recurrent import rglru_block
from repro.models.rwkv import rwkv_channel_mix, rwkv_time_mix

Tree = Dict[str, Any]


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------
def apply_block(
    cfg: ModelConfig,
    kind: str,
    p: Tree,
    h: jax.Array,
    ctx: Ctx,
    *,
    mode: str,
    cache: Optional[Tree],
    pos: jax.Array,
    enc_out: Optional[jax.Array] = None,
    dense_only: bool = False,
    causal: bool = True,
    lengths: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Tree], jax.Array]:
    """Residual block: temporal mixer + (cross-attn) + channel mixer.

    Returns (h, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Tree = {} if cache is not None else None
    full_mode = mode != "decode"
    amode = "full" if full_mode else "decode"

    def _post(name, y):
        return rms_norm(y, p[name], cfg.norm_eps) if name in p else y

    # ---- temporal mixer ---------------------------------------------------
    # With sequence-parallel residuals, gather ONCE at the norm output (the
    # Megatron-SP transition point) instead of per consuming matmul.
    x = ctx.constrain(rms_norm(h, p["pre_norm"], cfg.norm_eps),
                      ("batch", "seq", "embed_act"))
    if kind in (GLOBAL_ATTN, LOCAL_ATTN):
        sub = cache.get("attn") if cache is not None else None
        if cfg.use_mla:
            y, nc = mla_attention(cfg, p["attn"], x, ctx, mode=amode,
                                  cache=sub, pos=pos, lengths=lengths)
        else:
            y, nc = gqa_attention(cfg, p["attn"], x, ctx, kind=kind,
                                  mode=amode, cache=sub, pos=pos,
                                  causal=causal, lengths=lengths)
        if new_cache is not None:
            new_cache["attn"] = nc
    elif kind == RECURRENT:
        sub = cache.get("rec") if cache is not None else None
        y, nc = rglru_block(cfg, p["rec"], x, ctx, mode=amode, cache=sub,
                            lengths=lengths)
        if new_cache is not None:
            new_cache["rec"] = nc
    elif kind == RWKV:
        sub = cache.get("rwkv") if cache is not None else None
        y, nc = rwkv_time_mix(cfg, p["tm"], x, ctx, mode=amode, cache=sub,
                              lengths=lengths)
        if new_cache is not None:
            new_cache["rwkv"] = nc
    else:
        raise ValueError(kind)
    # Constrain the mixer output to the sharded-residual layout BEFORE the
    # add: the TP output all-reduce then lowers to the cheaper
    # reduce-scatter (Megatron-SP's AR = AG + RS split).
    y = ctx.constrain(y, ("batch", "resid_seq", "embed_act"))
    h = h + _post("post_norm", y)
    h = ctx.constrain(h, ("batch", "resid_seq", "embed_act"))

    # ---- cross attention (enc-dec decoder) --------------------------------
    # full mode needs enc_out; decode reads the cached encoder K/V instead
    if "cross" in p and (enc_out is not None or
                         (cache is not None and "cross" in cache)):
        x = ctx.constrain(rms_norm(h, p["cross_norm"], cfg.norm_eps),
                          ("batch", "seq", "embed_act"))
        sub = cache.get("cross") if cache is not None else None
        y, nc = gqa_attention(cfg, p["cross"], x, ctx, kind=GLOBAL_ATTN,
                              mode=amode, cache=sub, pos=pos,
                              cross_kv=enc_out, is_cross=True, causal=False)
        if new_cache is not None:
            new_cache["cross"] = nc
        y = ctx.constrain(y, ("batch", "resid_seq", "embed_act"))
        h = h + _post("post_cross_norm", y)

    # ---- channel mixer ----------------------------------------------------
    if kind == RWKV:
        x = ctx.constrain(rms_norm(h, p["cm_norm"], cfg.norm_eps),
                          ("batch", "seq", "embed_act"))
        sub = new_cache.get("rwkv") if new_cache is not None else None
        y, nc = rwkv_channel_mix(cfg, p["cm"], x, ctx, mode=amode, cache=sub,
                                 lengths=lengths)
        if new_cache is not None:
            new_cache["rwkv"] = nc
    else:
        x = ctx.constrain(rms_norm(h, p["ffn_norm"], cfg.norm_eps),
                          ("batch", "seq", "embed_act"))
        if "moe" in p and not dense_only:
            y, aux = moe_ffn(cfg, p["moe"], x, ctx,
                             dropless=mode != "train")
        else:
            y = dense_ffn(p["ffn"], x, cfg.act, ctx)
    y = ctx.constrain(y, ("batch", "resid_seq", "embed_act"))
    h = h + _post("post_ffn_norm", y)
    h = ctx.constrain(h, ("batch", "resid_seq", "embed_act"))
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------
def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(policy)


def run_stack(
    cfg: ModelConfig,
    stack: Tree,                    # {"prefix": .., "groups": .., "tail": ..}
    h: jax.Array,
    ctx: Ctx,
    *,
    mode: str,
    cache: Optional[Tree],
    pos: jax.Array,
    enc_out: Optional[jax.Array] = None,
    causal: bool = True,
    stack_name: str = "decoder",
    remat_policy: str = "none",
    lengths: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Tree], jax.Array]:
    pat = cfg.block_pattern
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Tree = {} if cache is not None else None

    def run_layer(i_kind, p, h, c):
        return apply_block(cfg, i_kind, p, h, ctx, mode=mode, cache=c,
                           pos=pos, enc_out=enc_out, causal=causal,
                           dense_only=False, lengths=lengths)

    # ---- prefix (first-k-dense, unrolled) ---------------------------------
    if "prefix" in stack:
        sub_nc = {}
        for i in sorted(stack["prefix"], key=int):
            kind = cfg.layer_kinds()[int(i)]
            c = cache["prefix"][i] if cache is not None else None
            h, nc, aux = apply_block(cfg, kind, stack["prefix"][i], h, ctx,
                                     mode=mode, cache=c, pos=pos,
                                     enc_out=enc_out, causal=causal,
                                     dense_only=True, lengths=lengths)
            aux_total = aux_total + aux
            sub_nc[i] = nc
        if new_cache is not None:
            new_cache["prefix"] = sub_nc

    # ---- scanned groups ----------------------------------------------------
    if "groups" in stack:
        gcache = cache["groups"] if cache is not None else None
        # Optionally re-constrain the per-iteration weight slices to their
        # FSDP/TP shardings.  Hypothesis (perf log #A0): prevents XLA from
        # hoisting the data-axis all-gather out of the loop.  MEASURED:
        # no memory change on mistral-large train (58.6 -> 59.7 GB), i.e.
        # refuted — XLA already keeps the gather in-loop; the stacks were
        # CPU float-normalization artifacts.  Kept behind a flag, off by
        # default.
        group_axes = None
        if ctx.mesh is not None and ctx.constrain_scan_weights:
            ab_groups = P.abstract_params(cfg).get(stack_name, {}).get("groups")
            if ab_groups is not None:
                group_axes = P.tree_logical_axes(ab_groups, drop_leading=1)

        def body(carry, xs):
            h, aux = carry
            gp, gc = xs
            if group_axes is not None:
                gp = jax.tree.map(lambda w, ax: ctx.constrain(w, ax),
                                  gp, group_axes)
            nc_out = {} if gc is not None else None
            for j, kind in enumerate(pat):
                c = gc[str(j)] if gc is not None else None
                h, nc, a = run_layer(kind, gp[str(j)], h, c)
                aux = aux + a
                if nc_out is not None:
                    nc_out[str(j)] = nc
            return (h, aux), nc_out

        body = _remat(body, remat_policy)
        (h, aux_total), g_nc = jax.lax.scan(
            body, (h, aux_total), (stack["groups"], gcache),
            unroll=True if ctx.scan_unroll else 1)
        if new_cache is not None:
            new_cache["groups"] = g_nc

    # ---- tail (unrolled remainder) -----------------------------------------
    if "tail" in stack:
        sub_nc = {}
        for i in sorted(stack["tail"], key=int):
            kind = pat[int(i)]
            c = cache["tail"][i] if cache is not None else None
            h, nc, aux = run_layer(kind, stack["tail"][i], h, c)
            aux_total = aux_total + aux
            sub_nc[i] = nc
        if new_cache is not None:
            new_cache["tail"] = sub_nc

    return h, new_cache, aux_total


# ---------------------------------------------------------------------------
# Whole model
# ---------------------------------------------------------------------------
def cast_params(params: Tree, dtype) -> Tree:
    """Mixed precision: matrices (ndim≥2) compute in ``dtype`` (bf16 on TPU);
    1-D leaves (norm gains, biases, Λ) stay fp32.  Master params remain fp32
    in the train state — this cast happens inside the jitted forward."""
    def c(p):
        if p.ndim >= 2 and p.dtype == jnp.float32:
            return p.astype(dtype)
        return p
    return jax.tree.map(c, params)


def _embed(cfg: ModelConfig, params: Tree, tokens: jax.Array, ctx: Ctx) -> jax.Array:
    h = jnp.take(params["embed"], tokens, axis=0).astype(ctx.dtype)
    if cfg.embed_scale_by_sqrt_dim:
        h = h * jnp.asarray(cfg.d_model ** 0.5, ctx.dtype)
    return ctx.constrain(h, ("batch", "seq", "embed_act"))


def _unembed(cfg: ModelConfig, params: Tree, h: jax.Array, ctx: Ctx) -> jax.Array:
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    table = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (h @ table.astype(h.dtype)).astype(jnp.float32)
    logits = ctx.constrain(logits, ("batch", "seq", "vocab_act"))
    from repro.models.layers import softcap as _sc
    logits = _sc(logits, cfg.final_logit_softcap)
    # mask vocab-padding ids
    pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    return jnp.where(pad_mask, logits, -1e9)


def _encoder_out(cfg: ModelConfig, params: Tree, src_embeds: jax.Array,
                 ctx: Ctx, remat_policy: str) -> jax.Array:
    """Encoder stack over precomputed (stub) frontend embeddings."""
    h = src_embeds.astype(ctx.dtype)
    pos = jnp.arange(h.shape[1], dtype=jnp.int32)
    h, _, _ = run_stack(cfg, params["encoder"], h, ctx, mode="train",
                        cache=None, pos=pos, causal=False,
                        stack_name="encoder", remat_policy=remat_policy)
    return rms_norm(h, params["encoder_norm"], cfg.norm_eps)


def forward(
    cfg: ModelConfig,
    params: Tree,
    batch: Tree,
    ctx: Ctx,
    *,
    mode: str = "train",             # train | prefill | decode
    cache: Optional[Tree] = None,
    pos: Optional[jax.Array] = None, # decode: scalar position
    remat_policy: str = "none",
    lengths: Optional[jax.Array] = None,  # ragged prefill: (B,) prompt lens
    starts: Optional[jax.Array] = None,   # chunked prefill: (B,) first positions
) -> Tuple[jax.Array, Optional[Tree], jax.Array]:
    """Returns (logits, new_cache, aux_loss).

    train:   logits (B, S, V) for every position
    prefill: logits (B, 1, V) for the last position + filled cache
    decode:  logits (B, 1, V) + updated cache

    ``lengths`` makes prefill *ragged*: the (B, S0) token batch is padded
    to the round's max prompt length, row ``b``'s true prompt is its first
    ``lengths[b]`` tokens, and the returned logits are each row's *last
    valid* position.  Causality already isolates that query from the
    padding keys (they sit at later positions), and cache writes are
    masked per row — length-0 rows (active continuous-batching slots not
    being prefilled this round) leave the cache untouched.  Supported for
    every decoder-only stack: paged globals + ring locals mask their
    writes, paged MLA latents scatter per row, and recurrent / RWKV
    carries are length-masked (padding steps neither read nor write
    state).  Enc-dec keeps the per-slot path (cross K/V is per round).

    ``starts`` makes a ragged prefill *chunked* (prefix caching): row
    ``b``'s tokens are the uncached TAIL of its prompt, opening at
    absolute position ``starts[b]`` — the cached prefix K/V already sit
    in (possibly shared) pages its table points to, so attention walks
    the whole page table while only the chunk is computed.  Needs an
    all-global paged decoder (ring locals would have to replay the
    evicted prefix) and no frontend (frontend embeds precede position 0).
    """
    params = cast_params(params, ctx.dtype)
    tokens = batch["tokens"]
    if lengths is not None:
        if mode != "prefill":
            raise ValueError("lengths is a prefill-only argument")
        if cfg.is_encoder_decoder:
            raise NotImplementedError(
                "ragged prefill needs a decoder-only stack: the cross-"
                "attention K/V of rows not in this round would be "
                "overwritten by the new encoder output")
        if cfg.use_mla and cfg.cache_layout != "paged":
            raise NotImplementedError(
                "ragged prefill over MLA needs the paged latent cache "
                "(the dense MLA cache keeps a lockstep shared position "
                "slot)")
        lengths = jnp.asarray(lengths, jnp.int32)
    if starts is not None:
        if lengths is None:
            raise ValueError("starts requires ragged prefill (lengths)")
        if set(cfg.layer_kinds()) != {GLOBAL_ATTN} \
                or cfg.is_encoder_decoder or cfg.frontend == "vision":
            raise NotImplementedError(
                "chunked prefix prefill needs an all-global paged decoder "
                "without a frontend")
        starts = jnp.asarray(starts, jnp.int32)
    enc_out = None
    # decode reuses the cross K/V cached at prefill — no encoder re-run
    if cfg.is_encoder_decoder and mode != "decode":
        enc_out = _encoder_out(cfg, params, batch["src_embeds"], ctx,
                               remat_policy)

    h = _embed(cfg, params, tokens, ctx)
    n_front = 0
    if cfg.frontend == "vision" and "frontend_embeds" in batch:
        fe = batch["frontend_embeds"].astype(ctx.dtype)
        n_front = fe.shape[1]
        h = jnp.concatenate([fe, h], axis=1)

    if mode == "decode":
        assert pos is not None and cache is not None
        p_arr = jnp.asarray(pos, jnp.int32)
    else:
        p_arr = jnp.arange(h.shape[1], dtype=jnp.int32)
        if starts is not None:
            # chunked prefill: per-row absolute positions (B, S0)
            p_arr = starts[:, None] + p_arr[None, :]
    if lengths is not None and n_front:
        # frontend tokens are real (per-row) prefix content: fold them into
        # the valid length; length-0 rows stay untouched
        lengths = jnp.where(lengths > 0, lengths + n_front, 0)

    h, new_cache, aux = run_stack(
        cfg, params["decoder"], h, ctx, mode=mode, cache=cache, pos=p_arr,
        enc_out=enc_out, causal=True, remat_policy=remat_policy,
        lengths=lengths)

    if mode == "train":
        if n_front:
            h = h[:, n_front:]
        logits = _unembed(cfg, params, h, ctx)
    elif lengths is not None:
        # ragged prefill: each row's last *valid* position (length-0 rows
        # return garbage logits the caller ignores)
        idx = jnp.maximum(lengths, 1) - 1                      # (B,)
        hl = jnp.take_along_axis(h, idx[:, None, None], axis=1)
        logits = _unembed(cfg, params, hl, ctx)
    else:
        logits = _unembed(cfg, params, h[:, -1:], ctx)
    return logits, new_cache, aux


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------
def num_pages(seq_len: int, page_size: int) -> int:
    """Logical pages needed to hold ``seq_len`` tokens."""
    return -(-seq_len // page_size)


def _layer_cache_ab(cfg: ModelConfig, kind: str, B: int, S_max: int,
                    src_len: int, cross: bool, layout: str = "dense",
                    page_budget: Optional[int] = None) -> Tree:
    """Abstract cache (ParamAb reused as shape+axes carrier) for one layer.

    ``layout="paged"`` replaces the dense (B, K, S_max, hd) buffer of
    *global* attention layers with a shared physical page pool plus a
    per-sequence page table (vLLM-style).  ``page_budget`` is the pool size
    in pages (default: worst case, B × ceil(S_max/page_size)).  Masked
    decode writes (inactive slots) scatter out of bounds and are dropped,
    so the pool carries no scratch page — its size stays divisible by the
    mesh axes and shards cleanly over ``cache_pages``.  MLA global layers
    page their *latent* cache (compressed latents + rope keys) the same
    way; only ring-buffer (local) caches stay dense — already bounded.
    """
    K, hd = cfg.num_kv_heads, cfg.head_dim
    dt = cfg.dtype
    c: Tree = {}
    if kind in (GLOBAL_ATTN, LOCAL_ATTN):
        if cfg.use_mla and kind == GLOBAL_ATTN and layout == "paged":
            # paged MLA latent cache: pages hold compressed latents + rope
            # keys (one shared "kv head" in latent space), walked through
            # the same per-sequence page tables as the GQA pool.  A latent
            # token is (kv_lora_rank + qk_rope_head_dim) wide — ~an order
            # smaller than the expanded K/V it stands for.
            ps = cfg.page_size
            pps = num_pages(S_max, ps)
            pool = page_budget if page_budget is not None else B * pps
            c["attn"] = {
                "ckv_pages": P.ParamAb((pool, ps, cfg.kv_lora_rank),
                                       ("cache_pages", None, "lora"),
                                       "zeros", dt),
                "krope_pages": P.ParamAb((pool, ps, cfg.qk_rope_head_dim),
                                         ("cache_pages", None, None),
                                         "zeros", dt),
                "page_table": P.ParamAb((B, pps), ("cache_batch", None),
                                        "zeros", "int32"),
            }
        elif cfg.use_mla:
            c["attn"] = {
                "ckv": P.ParamAb((B, S_max, cfg.kv_lora_rank),
                                 ("cache_batch", "kv_seq", "lora"), "zeros", dt),
                "krope": P.ParamAb((B, S_max, cfg.qk_rope_head_dim),
                                   ("cache_batch", "kv_seq", None), "zeros", dt),
                "pos": P.ParamAb((S_max,), (None,), "zeros", "int32"),
            }
        elif kind == GLOBAL_ATTN and layout == "paged":
            ps = cfg.page_size
            pps = num_pages(S_max, ps)
            pool = page_budget if page_budget is not None else B * pps
            c["attn"] = {
                "k_pages": P.ParamAb((pool, K, ps, hd),
                                     ("cache_pages", "kv_heads", None,
                                      "head_dim"), "zeros", dt),
                "v_pages": P.ParamAb((pool, K, ps, hd),
                                     ("cache_pages", "kv_heads", None,
                                      "head_dim"), "zeros", dt),
                "page_table": P.ParamAb((B, pps), ("cache_batch", None),
                                        "zeros", "int32"),
            }
        elif kind == GLOBAL_ATTN:
            c["attn"] = {
                "k": P.ParamAb((B, K, S_max, hd),
                               ("cache_batch", "kv_heads", "kv_seq", "head_dim"),
                               "zeros", dt),
                "v": P.ParamAb((B, K, S_max, hd),
                               ("cache_batch", "kv_heads", "kv_seq", "head_dim"),
                               "zeros", dt),
                "pos": P.ParamAb((S_max,), (None,), "zeros", "int32"),
            }
        else:                            # local: per-sequence ring buffer
            W = min(cfg.window_size, S_max)
            c["attn"] = {
                "k": P.ParamAb((B, K, W, hd),
                               ("cache_batch", "kv_heads", "window_seq",
                                "head_dim"), "zeros", dt),
                "v": P.ParamAb((B, K, W, hd),
                               ("cache_batch", "kv_heads", "window_seq",
                                "head_dim"), "zeros", dt),
                "pos": P.ParamAb((B, W), ("cache_batch", "window_seq"),
                                 "zeros", "int32"),
            }
    elif kind == RECURRENT:
        R, CW = cfg.rnn_width, cfg.conv1d_width
        c["rec"] = {
            "h": P.ParamAb((B, R), ("cache_batch", "rnn"), "zeros", "float32"),
            "conv": P.ParamAb((B, CW - 1, R), ("cache_batch", None, "rnn"),
                              "zeros", dt),
        }
    elif kind == RWKV:
        N = cfg.rwkv_head_dim
        H = cfg.d_model // N
        c["rwkv"] = {
            "s": P.ParamAb((B, H, N, N), ("cache_batch", "heads", None, None),
                           "zeros", "float32"),
            "shift_tm": P.ParamAb((B, cfg.d_model), ("cache_batch", None),
                                  "zeros", dt),
            "shift_cm": P.ParamAb((B, cfg.d_model), ("cache_batch", None),
                                  "zeros", dt),
        }
    if cross:
        c["cross"] = {
            "k": P.ParamAb((B, K, src_len, hd),
                           ("cache_batch", "kv_heads", "kv_seq", "head_dim"),
                           "zeros", dt),
            "v": P.ParamAb((B, K, src_len, hd),
                           ("cache_batch", "kv_heads", "kv_seq", "head_dim"),
                           "zeros", dt),
        }
    return c


def abstract_cache(cfg: ModelConfig, batch_size: int, max_len: int,
                   src_len: int = 0, *, layout: Optional[str] = None,
                   page_budget: Optional[int] = None) -> Tree:
    """Abstract decode/prefill cache matching the decoder stack layout.
    ``layout`` defaults to ``cfg.cache_layout``; ``page_budget`` sizes the
    per-layer page pool (paged layout only; None = worst case)."""
    layout = cfg.cache_layout if layout is None else layout
    if layout not in ("dense", "paged"):
        raise ValueError(f"unknown cache_layout {layout!r}")
    kinds = cfg.layer_kinds()
    pat = cfg.block_pattern
    cross = cfg.is_encoder_decoder
    prefix_n = cfg.first_k_dense
    body = kinds[prefix_n:]
    n_groups, tail_n = divmod(len(body), len(pat))
    mk = lambda kind: _layer_cache_ab(cfg, kind, batch_size, max_len,
                                      src_len, cross, layout, page_budget)
    out: Tree = {}
    if prefix_n:
        out["prefix"] = {str(i): mk(kinds[i]) for i in range(prefix_n)}
    if n_groups:
        group = {str(j): mk(pat[j]) for j in range(len(pat))}
        out["groups"] = P._stack(group, n_groups)
    if tail_n:
        out["tail"] = {str(j): mk(pat[j]) for j in range(tail_n)}
    return out


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               src_len: int = 0, *, layout: Optional[str] = None,
               page_budget: Optional[int] = None,
               paged_tables: str = "identity") -> Tree:
    """Concrete cache.  For the paged layout, ``paged_tables`` selects the
    page-table init: ``"identity"`` (default; sequence ``b`` owns pages
    ``b*pps .. (b+1)*pps-1`` — lockstep serving with a worst-case pool) or
    ``"empty"`` (all -1; a host-side allocator assigns pages at admission —
    see launch.executor).  Identity requires the worst-case pool, so it is
    rejected when a smaller ``page_budget`` is given."""
    ab = abstract_cache(cfg, batch_size, max_len, src_len,
                        layout=layout, page_budget=page_budget)
    if paged_tables == "identity" and page_budget is not None and \
            page_budget < batch_size * num_pages(max_len, cfg.page_size):
        raise ValueError(
            "identity page tables need the worst-case pool; pass "
            "paged_tables='empty' with a reduced page_budget")

    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        ab, is_leaf=lambda x: isinstance(x, P.ParamAb))

    def mk(path, leaf: P.ParamAb):
        key = getattr(path[-1], "key", None)
        if key == "page_table":
            if paged_tables == "identity":
                pps = leaf.shape[-1]
                ident = jnp.arange(batch_size * pps,
                                   dtype=jnp.int32).reshape(batch_size, pps)
                return jnp.broadcast_to(ident, leaf.shape)
            return jnp.full(leaf.shape, -1, jnp.int32)
        if leaf.dtype == "int32":       # position slots start invalid
            return jnp.full(leaf.shape, -1, jnp.int32)
        return jnp.zeros(leaf.shape, jnp.dtype(leaf.dtype))

    return jax.tree.unflatten(treedef, [mk(p, l) for p, l in leaves])


# ---------------------------------------------------------------------------
# Continuous-batching helpers (host-side; see launch/executor.py).
#
# A "slot view" is the cache restricted to one batch row: per-sequence
# leaves (page tables, ring buffers, recurrent state, …) are sliced to
# batch 1, while the *shared* page pools pass through whole — a prefill
# run on the view writes only the pages that row's table points to.
# ---------------------------------------------------------------------------
_POOL_LEAVES = ("k_pages", "v_pages", "ckv_pages", "krope_pages")


def _slot_axis(path) -> int:
    """Batch axis of a cache leaf: scanned group leaves carry a leading
    ``layers`` dim, so their batch dim is 1."""
    return 1 if any(getattr(p, "key", None) == "groups" for p in path) else 0


def _is_pool(path) -> bool:
    return getattr(path[-1], "key", None) in _POOL_LEAVES


def _is_shared_pos(path, leaf, batch_size: int, axis: int) -> bool:
    """Lockstep-only shared slot maps ((S,) pos of dense-global / MLA
    caches) have no batch dim and are left whole in a slot view."""
    return getattr(path[-1], "key", None) == "pos" and \
        (leaf.ndim <= axis or leaf.shape[axis] != batch_size)


def cache_slot_view(cache: Tree, batch_size: int, b: int) -> Tree:
    """Batch-1 view of ``cache`` for slot ``b`` (page pools shared)."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for path, leaf in leaves:
        ax = _slot_axis(path)
        if _is_pool(path) or _is_shared_pos(path, leaf, batch_size, ax):
            out.append(leaf)
        else:
            out.append(jax.lax.slice_in_dim(leaf, b, b + 1, axis=ax))
    return jax.tree.unflatten(treedef, out)


def cache_slot_merge(cache: Tree, view: Tree, batch_size: int, b: int) -> Tree:
    """Write a slot view (as returned by prefill) back into the full cache:
    pool leaves replace wholesale, per-sequence leaves update row ``b``."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(cache)
    vleaves = jax.tree.leaves(view)
    assert len(leaves) == len(vleaves), (len(leaves), len(vleaves))
    out = []
    for (path, leaf), vleaf in zip(leaves, vleaves):
        ax = _slot_axis(path)
        if _is_pool(path) or _is_shared_pos(path, leaf, batch_size, ax):
            out.append(vleaf)
        else:
            out.append(jax.lax.dynamic_update_slice_in_dim(
                leaf, vleaf.astype(leaf.dtype), b, axis=ax))
    return jax.tree.unflatten(treedef, out)


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    return P.count_params(cfg, active_only=active_only)
