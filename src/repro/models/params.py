"""Abstract parameter trees: one source of truth for shape/axes/init.

``abstract_params(cfg)`` returns a nested dict whose leaves are
:class:`ParamAb` — (shape, dtype, logical_axes, init spec).  Everything else
derives from it:

* ``init_params``        — concrete tree (PRNG init, per-leaf fold_in)
* ``tree_shardings``     — NamedSharding tree (via repro.dist)
* ``shape_dtype_tree``   — ShapeDtypeStruct tree for the dry-run
* ``count_params``       — analytic parameter count (6ND roofline term)

Layer stacks that repeat (the scan groups) carry a leading ``layers`` dim.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    GLOBAL_ATTN,
    LOCAL_ATTN,
    RECURRENT,
    RWKV,
    ModelConfig,
)

Tree = Dict[str, object]


@dataclass(frozen=True)
class ParamAb:
    """Abstract parameter: shape + logical axes + init recipe."""

    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    init: str = "fan_in"          # fan_in | zeros | ones | normal:<s> | rglru_lambda | uniform:<lo>:<hi>
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (self.shape, self.logical_axes)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))

    def shape_dtype(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, jnp.dtype(self.dtype))

    def materialize(self, key: jax.Array) -> jax.Array:
        dt = jnp.dtype(self.dtype)
        if self.init == "zeros":
            return jnp.zeros(self.shape, dt)
        if self.init == "ones":
            return jnp.ones(self.shape, dt)
        if self.init == "fan_in":
            fan_in = self.shape[0] if len(self.shape) == 1 else int(np.prod(self.shape[:-1]))
            s = 1.0 / math.sqrt(max(fan_in, 1))
            return (jax.random.normal(key, self.shape, jnp.float32) * s).astype(dt)
        if self.init.startswith("normal:"):
            s = float(self.init.split(":")[1])
            return (jax.random.normal(key, self.shape, jnp.float32) * s).astype(dt)
        if self.init == "rglru_lambda":
            # Λ such that a = sigmoid(Λ) ∈ [0.9, 0.999]  (Griffin §2.4)
            u = jax.random.uniform(key, self.shape, jnp.float32, 0.9, 0.999)
            return jnp.log(u / (1.0 - u)).astype(dt)
        if self.init.startswith("uniform:"):
            _, lo, hi = self.init.split(":")
            return jax.random.uniform(key, self.shape, jnp.float32, float(lo), float(hi)).astype(dt)
        raise ValueError(f"unknown init {self.init!r}")


def _norm(d: int) -> ParamAb:
    return ParamAb((d,), ("embed",), "ones")


# ---------------------------------------------------------------------------
# Per-block param builders.  Dict keys are stable — the forward pass and the
# tests index them by name.
# ---------------------------------------------------------------------------
def _attention_params(cfg: ModelConfig) -> Tree:
    """Projections kept 3-D (D, heads, head_dim) so the kv_heads dim
    replicates cleanly (auto-drop) when it doesn't divide the model axis,
    instead of silently splitting head_dim."""
    D, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p: Tree = {
        "q": ParamAb((D, H, hd), ("embed", "heads", "head_dim")),
        "k": ParamAb((D, K, hd), ("embed", "kv_heads", "head_dim")),
        "v": ParamAb((D, K, hd), ("embed", "kv_heads", "head_dim")),
        "o": ParamAb((H, hd, D), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["qb"] = ParamAb((H, hd), ("heads", "head_dim"), "zeros")
        p["kb"] = ParamAb((K, hd), ("kv_heads", "head_dim"), "zeros")
        p["vb"] = ParamAb((K, hd), ("kv_heads", "head_dim"), "zeros")
    if cfg.qk_norm:
        p["q_norm"] = ParamAb((hd,), ("head_dim",), "ones")
        p["k_norm"] = ParamAb((hd,), ("head_dim",), "ones")
    return p


def _mla_params(cfg: ModelConfig) -> Tree:
    """DeepSeek-V2 multi-head latent attention."""
    D, H = cfg.d_model, cfg.num_heads
    qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    p: Tree = {
        "kv_a": ParamAb((D, cfg.kv_lora_rank + cfg.qk_rope_head_dim), ("embed", "lora")),
        "kv_norm": ParamAb((cfg.kv_lora_rank,), ("lora",), "ones"),
        "kv_b": ParamAb(
            (cfg.kv_lora_rank, H, cfg.qk_nope_head_dim + cfg.v_head_dim),
            ("lora", "heads", "head_dim"),
        ),
        "o": ParamAb((H, cfg.v_head_dim, D), ("heads", "head_dim", "embed")),
    }
    if cfg.q_lora_rank:
        p["q_a"] = ParamAb((D, cfg.q_lora_rank), ("embed", "lora"))
        p["q_norm"] = ParamAb((cfg.q_lora_rank,), ("lora",), "ones")
        p["q_b"] = ParamAb((cfg.q_lora_rank, H, qk), ("lora", "heads", "head_dim"))
    else:
        p["q"] = ParamAb((D, H, qk), ("embed", "heads", "head_dim"))
    return p


def _dense_ffn_params(cfg: ModelConfig, d_ff: Optional[int] = None) -> Tree:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wg": ParamAb((D, F), ("embed", "ffn")),
        "wu": ParamAb((D, F), ("embed", "ffn")),
        "wd": ParamAb((F, D), ("ffn", "embed")),
    }


def _moe_ffn_params(cfg: ModelConfig) -> Tree:
    D, E, Fe = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    p: Tree = {
        "router": ParamAb((D, E), ("embed", "experts"), "normal:0.02"),
        "we_g": ParamAb((E, D, Fe), ("experts", "embed", "expert_ffn")),
        "we_u": ParamAb((E, D, Fe), ("experts", "embed", "expert_ffn")),
        "we_d": ParamAb((E, Fe, D), ("experts", "expert_ffn", "embed")),
    }
    if cfg.num_shared_experts:
        Fs = Fe * cfg.num_shared_experts
        p["ws_g"] = ParamAb((D, Fs), ("embed", "ffn"))
        p["ws_u"] = ParamAb((D, Fs), ("embed", "ffn"))
        p["ws_d"] = ParamAb((Fs, D), ("ffn", "embed"))
    return p


def _rglru_params(cfg: ModelConfig) -> Tree:
    """Griffin/RecurrentGemma recurrent block (linear y-gate ⊙ RG-LRU(x))."""
    D, R, CW = cfg.d_model, cfg.rnn_width, cfg.conv1d_width
    return {
        "wx": ParamAb((D, R), ("embed", "rnn")),
        "wy": ParamAb((D, R), ("embed", "rnn")),
        "conv_w": ParamAb((CW, R), ("conv", "rnn"), "normal:0.02"),
        "conv_b": ParamAb((R,), ("rnn",), "zeros"),
        "gate_i": ParamAb((R, R), (None, "rnn")),   # input gate  σ(x W)
        "gate_r": ParamAb((R, R), (None, "rnn")),   # recurrence gate
        "rglru_lambda": ParamAb((R,), ("rnn",), "rglru_lambda"),
        "wo": ParamAb((R, D), ("rnn", "embed")),
    }


def _rwkv_time_mix_params(cfg: ModelConfig) -> Tree:
    """RWKV6 ("Finch") time-mix with ddlerp token shift + data-dep decay."""
    D = cfg.d_model
    H = D // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    rk, rw = cfg.rwkv_ddlerp_rank, cfg.rwkv_decay_rank
    return {
        # token-shift: 5 lerp targets (r,k,v,w,g) + 1 for the ddlerp input x
        "tm_mu": ParamAb((6, D), (None, "embed"), "uniform:0:1"),
        "tm_A": ParamAb((D, 5 * rk), ("embed", "lora"), "normal:0.02"),
        "tm_B": ParamAb((5, rk, D), (None, "lora", "embed"), "normal:0.02"),
        "wr": ParamAb((D, D), ("embed", "heads")),
        "wk": ParamAb((D, D), ("embed", "heads")),
        "wv": ParamAb((D, D), ("embed", "heads")),
        "wg": ParamAb((D, D), ("embed", "heads")),
        "w_base": ParamAb((D,), ("heads",), "uniform:-7:-5"),  # decay bias (pre-softplus-ish)
        "ww_A": ParamAb((D, rw), ("embed", "lora"), "normal:0.02"),
        "ww_B": ParamAb((rw, D), ("lora", "heads"), "normal:0.02"),
        "u": ParamAb((H, hd), ("heads", "head_dim"), "normal:0.02"),  # bonus
        "ln_x": ParamAb((D,), ("heads",), "ones"),                    # per-head groupnorm scale
        "wo": ParamAb((D, D), ("heads", "embed")),
    }


def _rwkv_channel_mix_params(cfg: ModelConfig) -> Tree:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "cm_mu_k": ParamAb((D,), ("embed",), "uniform:0:1"),
        "cm_mu_r": ParamAb((D,), ("embed",), "uniform:0:1"),
        "wk_c": ParamAb((D, F), ("embed", "ffn")),
        "wv_c": ParamAb((F, D), ("ffn", "embed")),
        "wr_c": ParamAb((D, D), ("embed", None)),
    }


def _layer_params(cfg: ModelConfig, kind: str, *, dense_ffn: bool = False,
                  cross_attn: bool = False, causal_attn: bool = True) -> Tree:
    """One full block = temporal mixer + channel mixer (+norms)."""
    D = cfg.d_model
    p: Tree = {"pre_norm": _norm(D)}
    if kind in (GLOBAL_ATTN, LOCAL_ATTN):
        p["attn"] = _mla_params(cfg) if cfg.use_mla else _attention_params(cfg)
    elif kind == RECURRENT:
        p["rec"] = _rglru_params(cfg)
    elif kind == RWKV:
        p["tm"] = _rwkv_time_mix_params(cfg)
    else:
        raise ValueError(kind)
    if cfg.use_post_block_norm:
        p["post_norm"] = _norm(D)
    if cross_attn:
        p["cross_norm"] = _norm(D)
        p["cross"] = _attention_params(cfg)
        if cfg.use_post_block_norm:
            p["post_cross_norm"] = _norm(D)
    # channel mixer
    if kind == RWKV:
        p["cm_norm"] = _norm(D)
        p["cm"] = _rwkv_channel_mix_params(cfg)
    else:
        p["ffn_norm"] = _norm(D)
        if cfg.is_moe and not dense_ffn:
            p["moe"] = _moe_ffn_params(cfg)
        else:
            p["ffn"] = _dense_ffn_params(cfg)
    if cfg.use_post_block_norm:
        p["post_ffn_norm"] = _norm(D)
    return p


def _stack(tree: Tree, n: int) -> Tree:
    """Prepend a scan ``layers`` dim of length ``n`` to every leaf."""
    return jax.tree.map(
        lambda ab: ParamAb((n,) + ab.shape, ("layers",) + ab.logical_axes,
                           ab.init, ab.dtype),
        tree,
        is_leaf=lambda x: isinstance(x, ParamAb),
    )


def _stack_of_layers(cfg: ModelConfig, *, cross_attn: bool = False,
                     num_layers: Optional[int] = None) -> Tree:
    """groups (scanned, stacked) + prefix (first-k-dense) + tail (remainder)."""
    kinds = cfg.layer_kinds(num_layers)
    prefix_n = cfg.first_k_dense if num_layers is None else 0
    pat = cfg.block_pattern
    body = kinds[prefix_n:]
    n_groups, tail_n = divmod(len(body), len(pat))
    out: Tree = {}
    if prefix_n:
        out["prefix"] = {
            str(i): _layer_params(cfg, kinds[i], dense_ffn=True, cross_attn=cross_attn)
            for i in range(prefix_n)
        }
    if n_groups:
        group = {str(i): _layer_params(cfg, pat[i], cross_attn=cross_attn)
                 for i in range(len(pat))}
        out["groups"] = _stack(group, n_groups)
    if tail_n:
        out["tail"] = {str(i): _layer_params(cfg, pat[i], cross_attn=cross_attn)
                       for i in range(tail_n)}
    return out


# ---------------------------------------------------------------------------
# Whole-model abstract tree
# ---------------------------------------------------------------------------
def abstract_params(cfg: ModelConfig) -> Tree:
    D, V = cfg.d_model, cfg.padded_vocab
    p: Tree = {
        "embed": ParamAb((V, D), ("vocab", "embed"), "normal:0.02"),
        "decoder": _stack_of_layers(cfg, cross_attn=cfg.is_encoder_decoder),
        "final_norm": _norm(D),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = ParamAb((D, V), ("embed", "vocab"))
    if cfg.is_encoder_decoder:
        p["encoder"] = _stack_of_layers(cfg, num_layers=cfg.num_encoder_layers)
        p["encoder_norm"] = _norm(D)
    return p


def shape_dtype_tree(tree: Tree):
    return jax.tree.map(lambda ab: ab.shape_dtype(), tree,
                        is_leaf=lambda x: isinstance(x, ParamAb))


def tree_logical_axes(tree: Tree, drop_leading: int = 0) -> Tree:
    """Per-leaf logical-axis tuples (``drop_leading=1`` strips the scan
    ``layers`` dim — what the in-loop weight slices actually carry)."""
    return jax.tree.map(lambda ab: ab.logical_axes[drop_leading:], tree,
                        is_leaf=lambda x: isinstance(x, ParamAb))


def param_shardings(cfg: ModelConfig, mesh, rules=None) -> Tree:
    """NamedSharding tree for the whole model, inferred from the abstract
    tree by repro.dist (no arrays allocated)."""
    from repro.dist.sharding import DEFAULT_RULES, tree_shardings
    return tree_shardings(abstract_params(cfg), mesh,
                          DEFAULT_RULES if rules is None else rules)


def init_params(cfg: ModelConfig, key: jax.Array) -> Tree:
    """Concrete init.  Each leaf gets a key folded from its tree path, so
    adding/removing an unrelated leaf never reshuffles other leaves."""
    ab = abstract_params(cfg)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        ab, is_leaf=lambda x: isinstance(x, ParamAb))

    def leaf_key(path) -> jax.Array:
        k = key
        for p in path:
            name = getattr(p, "key", getattr(p, "idx", None))
            k = jax.random.fold_in(k, _stable_hash(str(name)))
        return k

    vals = [leaf.materialize(leaf_key(path)) for path, leaf in leaves]
    return jax.tree.unflatten(treedef, vals)


def _stable_hash(s: str) -> int:
    h = 2166136261
    for c in s.encode():
        h = ((h ^ c) * 16777619) & 0x7FFFFFFF
    return h


# ---------------------------------------------------------------------------
# Counting
# ---------------------------------------------------------------------------
_EXPERT_KEYS = ("we_g", "we_u", "we_d")


def count_params(cfg: ModelConfig, active_only: bool = False,
                 include_embed: bool = False) -> int:
    """Analytic parameter count from the abstract tree.

    ``active_only`` scales routed-expert weights by top_k/E (MoE 6·N_active·D).
    ``include_embed=False`` excludes embedding + lm_head (standard 6ND
    convention counts matmul-participating non-embedding params)."""
    ab = abstract_params(cfg)
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            ab, is_leaf=lambda x: isinstance(x, ParamAb))[0]:
        names = [str(getattr(p, "key", "")) for p in path]
        if not include_embed and (names[0] in ("embed", "lm_head")):
            continue
        n = leaf.size
        if active_only and names[-1] in _EXPERT_KEYS and cfg.num_experts:
            n = n * cfg.num_experts_per_tok // cfg.num_experts
        total += n
    return total
