from repro.models.params import (  # noqa: F401
    ParamAb,
    abstract_params,
    init_params,
    count_params,
)
from repro.models.model import (  # noqa: F401
    forward,
    init_cache,
    abstract_cache,
)
