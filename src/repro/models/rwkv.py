"""RWKV6 ("Finch") blocks: time-mix (WKV6) + channel-mix.

WKV6 recurrence, per head (hd_k = hd_v = N, decay on the key channel):

    o_t = r_t · S_{t-1}  +  (r_t · (u ⊙ k_t)) v_t
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ          w_t = exp(-exp(ww_t)) ∈ (0,1)

Full mode runs the **chunked** formulation (scan over chunks of length
``ctx.rwkv_chunk``): within a chunk all pairwise decays are products of
per-step decays ≤ 1, computed in log space — every exp() argument is ≤ 0 so
the math is numerically stable without rescaling tricks.  The Pallas kernel
(kernels/rwkv6_wkv) implements the same chunking with the state in VMEM.

Decay ``w``, state and within-chunk math are fp32 throughout.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Ctx


def _token_shift(x: jax.Array, state: Optional[jax.Array]) -> jax.Array:
    """shift(x)_t = x_{t-1}; position -1 comes from ``state`` (decode) or 0."""
    prev = jnp.zeros_like(x[:, :1]) if state is None else state[:, None].astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(p, x: jax.Array, xx: jax.Array):
    """RWKV6 data-dependent lerp: 5 mixed inputs (w, k, v, r, g)."""
    B, S, D = x.shape
    rk = p["tm_B"].shape[1]
    base = x + xx * p["tm_mu"][0].astype(x.dtype)
    lora = jnp.tanh(base @ p["tm_A"]).reshape(B, S, 5, rk)
    dyn = jnp.einsum("bsjr,jrd->bsjd", lora, p["tm_B"])      # (B,S,5,D)
    mus = p["tm_mu"][1:6].astype(x.dtype)                    # (5,D)
    mixed = x[:, :, None] + xx[:, :, None] * (mus + dyn.astype(x.dtype))
    return [mixed[:, :, j] for j in range(5)]                # w,k,v,r,g


def wkv6_chunked(
    r: jax.Array, k: jax.Array, v: jax.Array,   # (B,S,H,N)
    lw: jax.Array,                              # (B,S,H,N) log-decay, ≤ 0, fp32
    u: jax.Array,                               # (H,N) bonus
    s0: jax.Array,                              # (B,H,N,N) initial state, fp32
    chunk: int = 32,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (o (B,S,H,N), s_final (B,H,N,N))."""
    B, S, H, N = r.shape
    pad = (-S) % chunk
    if pad:
        # zero k/r and lw=0 (decay 1): padded steps neither read nor write
        zeros = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, lw = zeros(r), zeros(k), zeros(v), zeros(lw)
        S += pad
    L, nc = chunk, S // chunk
    rf = r.astype(jnp.float32).reshape(B, nc, L, H, N).transpose(1, 0, 2, 3, 4)
    kf = k.astype(jnp.float32).reshape(B, nc, L, H, N).transpose(1, 0, 2, 3, 4)
    vf = v.astype(jnp.float32).reshape(B, nc, L, H, N).transpose(1, 0, 2, 3, 4)
    lwf = lw.reshape(B, nc, L, H, N).transpose(1, 0, 2, 3, 4)
    uf = u.astype(jnp.float32)
    mask = jnp.tril(jnp.ones((L, L), jnp.float32), k=-1)     # strict i<t

    def body(s, xs):
        rc, kc, vc, lwc = xs                                 # (B,L,H,N)...
        clw = jnp.cumsum(lwc, axis=1)                        # inclusive Σ_{s≤t}
        clw_ex = clw - lwc                                   # exclusive Σ_{s<t}
        # inter-chunk: state contribution
        o_inter = jnp.einsum("blhc,bhcv->blhv", rc * jnp.exp(clw_ex), s)
        # intra-chunk: pairwise decayed scores  A[t,i] (i<t) + u-bonus diag
        decay = jnp.exp(clw_ex[:, :, None] - clw[:, None])   # (B,t,i,H,N), ≤1
        a = jnp.einsum("bthc,bihc,btihc->btih", rc, kc, decay)
        a = a * mask[None, :, :, None]
        bonus = jnp.einsum("blhc,blhc->blh", rc, uf * kc)
        o_intra = jnp.einsum("btih,bihv->bthv", a, vc) + bonus[..., None] * vc
        # state update: decay to end-of-chunk + decayed key outer-products
        k_dec = kc * jnp.exp(clw[:, -1:] - clw)              # ∏_{s>i} w_s
        s_new = jnp.exp(clw[:, -1])[..., None] * s + \
            jnp.einsum("bihc,bihv->bhcv", k_dec, vc)
        return s_new, o_inter + o_intra

    s_fin, o = jax.lax.scan(body, s0, (rf, kf, vf, lwf))
    o = o.transpose(1, 0, 2, 3, 4).reshape(B, S, H, N)
    if pad:
        o = o[:, :S - pad]
    return o, s_fin


def wkv6_step(r, k, v, lw, u, s):
    """One decode step.  r,k,v,lw: (B,H,N); s: (B,H,N,N) fp32."""
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    at = kf[..., :, None] * vf[..., None, :]                 # (B,H,N,N)
    o = jnp.einsum("bhc,bhcv->bhv", rf, s + u[..., None] * at)
    s_new = jnp.exp(lw)[..., None] * s + at
    return o, s_new


def _group_norm_heads(o: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """LayerNorm within each head (RWKV 'ln_x' GroupNorm), scale (H*N,)."""
    B, S, H, N = o.shape
    of = o.astype(jnp.float32)
    mu = of.mean(-1, keepdims=True)
    var = of.var(-1, keepdims=True)
    normed = (of - mu) * jax.lax.rsqrt(var + eps)
    return (normed.reshape(B, S, H * N) * scale.astype(jnp.float32)).astype(o.dtype)


def rwkv_time_mix(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,
    ctx: Ctx,
    *,
    mode: str,
    cache: Optional[Dict[str, jax.Array]],
    lengths: Optional[jax.Array] = None,   # ragged prefill: (B,) true lens
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, D = x.shape
    N = cfg.rwkv_head_dim
    H = D // N
    shift_state = cache["shift_tm"] if (cache is not None and mode == "decode") else None
    xx = _token_shift(x, shift_state) - x
    xw, xk, xv, xr, xg = _ddlerp(p, x, xx)

    r = (xr @ p["wr"]).reshape(B, S, H, N)
    k = (xk @ p["wk"]).reshape(B, S, H, N)
    v = (xv @ p["wv"]).reshape(B, S, H, N)
    g = jax.nn.silu(xg @ p["wg"])
    r = ctx.constrain(r, ("batch", "seq", "heads", None))
    # data-dependent decay (fp32, log space):  lw = -exp(ww) ≤ 0
    ww = p["w_base"].astype(jnp.float32) + \
        (jnp.tanh(xw @ p["ww_A"]) @ p["ww_B"]).astype(jnp.float32)
    lw = -jnp.exp(ww).reshape(B, S, H, N)

    if lengths is not None and mode != "decode":
        # ragged prefill: padding steps neither read nor write the state —
        # k = 0 kills their outer-product write and u-bonus, lw = 0
        # (decay 1) stops them decaying the carry, so s_fin is each row's
        # state at lengths-1 (the same convention wkv6_chunked uses for
        # its own chunk padding)
        lens = lengths.astype(jnp.int32)
        pad_t = (jnp.arange(S, dtype=jnp.int32)[None, :]
                 >= lens[:, None])[..., None, None]        # (B,S,1,1)
        k = jnp.where(pad_t, jnp.zeros_like(k), k)
        lw = jnp.where(pad_t, 0.0, lw)

    if mode == "decode":
        s0 = cache["s"].astype(jnp.float32)
        o, s_new = wkv6_step(r[:, 0], k[:, 0], v[:, 0], lw[:, 0], p["u"].astype(jnp.float32), s0)
        o = o[:, None]
        new_cache = {"s": s_new.astype(cache["s"].dtype),
                     "shift_tm": x[:, -1], "shift_cm": cache["shift_cm"]}
    else:
        s0 = jnp.zeros((B, H, N, N), jnp.float32)
        if ctx.use_pallas:
            from repro.kernels.ops import wkv6_bshn
            o, s_fin = wkv6_bshn(r, k, v, lw, p["u"].astype(jnp.float32),
                                 s0, chunk=ctx.rwkv_chunk)
        else:
            o, s_fin = wkv6_chunked(r, k, v, lw, p["u"], s0, ctx.rwkv_chunk)
        new_cache = None
        if cache is not None:
            shift_fin = x[:, -1]
            if lengths is not None:
                last = jnp.maximum(lens - 1, 0)
                shift_fin = jnp.take_along_axis(
                    x, last[:, None, None], axis=1)[:, 0]
                keep = (lens > 0)
                s_fin = jnp.where(keep[:, None, None, None], s_fin,
                                  cache["s"].astype(s_fin.dtype))
                shift_fin = jnp.where(keep[:, None], shift_fin,
                                      cache["shift_tm"].astype(shift_fin.dtype))
            new_cache = {"s": s_fin.astype(cache["s"].dtype),
                         "shift_tm": shift_fin.astype(cache["shift_tm"].dtype),
                         "shift_cm": cache["shift_cm"]}
    o = o.astype(x.dtype)
    o = _group_norm_heads(o, p["ln_x"], cfg.norm_eps)
    o = o * g
    o = ctx.constrain(o, ("batch", "seq", "heads"))
    return o @ p["wo"], new_cache


def rwkv_channel_mix(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,
    ctx: Ctx,
    *,
    mode: str,
    cache: Optional[Dict[str, jax.Array]],
    lengths: Optional[jax.Array] = None,   # ragged prefill: (B,) true lens
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    shift_state = cache["shift_cm"] if (cache is not None and mode == "decode") else None
    xx = _token_shift(x, shift_state) - x
    xk = x + xx * p["cm_mu_k"].astype(x.dtype)
    xr = x + xx * p["cm_mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["wk_c"]))
    k = ctx.constrain(k, ("batch", "seq", "ffn"))
    out = jax.nn.sigmoid(xr @ p["wr_c"]) * (k @ p["wv_c"])
    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        shift_fin = x[:, -1]
        if lengths is not None and mode != "decode":
            lens = lengths.astype(jnp.int32)
            last = jnp.maximum(lens - 1, 0)
            shift_fin = jnp.take_along_axis(x, last[:, None, None],
                                            axis=1)[:, 0]
            shift_fin = jnp.where((lens > 0)[:, None], shift_fin,
                                  cache["shift_cm"].astype(shift_fin.dtype))
        new_cache["shift_cm"] = shift_fin.astype(cache["shift_cm"].dtype)
    return out, new_cache
