# Convenience entry points.  `make verify` is the tier-1 gate (same commands
# CI runs); see ROADMAP.md.

PY ?= python

.PHONY: verify lint staticcheck serve-smoke bench-smoke \
	prefix-cache-smoke platform-serve-smoke chaos-smoke dryrun

verify: lint staticcheck platform-serve-smoke prefix-cache-smoke chaos-smoke
	PYTHONPATH=src $(PY) -m pytest -x -q

# ruff is available in CI; locally the lint step degrades gracefully
lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check .; \
	else \
		echo "ruff not installed; skipping lint"; \
	fi

# Dependability static analysis: AST rules (SC1xx) + semantic checkers
# (sharding / kernel layouts / snapshot drift, SC2xx).  --check-baseline
# makes the checked-in baseline shrink-only (fixed findings must be
# removed from it).  See README §Static dependability checks.
staticcheck:
	PYTHONPATH=src $(PY) -m repro.staticcheck src tests benchmarks \
		--check-baseline --report artifacts/staticcheck_report.json

serve-smoke:
	PYTHONPATH=src $(PY) -m repro.launch.serve --reduced --batch 2 \
		--prompt-len 16 --gen 8
	PYTHONPATH=src $(PY) -m repro.launch.serve --reduced --batch 2 \
		--prompt-len 16 --gen 8 --continuous --requests 4

# Decode-kernel regression gate: tiny-shape interpret-mode run of the
# serve-decode lane (kernel ≡ reference check + modeled-bytes assertions).
# Never rewrites the checked-in BENCH_serve_decode.json.
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.serve_decode --smoke

# Prefix-cache regression gate: real-engine shared-prefix runs must pay
# exactly one prefill over the shared span, keep ONE physical copy of the
# prefix pages, and match solo runs token-for-token (aliasing is
# answer-invisible).  Never rewrites BENCH_prefix_cache.json.
prefix-cache-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.prefix_cache --smoke

# Platform-serve regression gate: the real ServingEngine payload runs a
# tiny workload under the platform and must produce responses byte-equal
# to the direct engine run.  Never rewrites BENCH_platform_serve.json.
platform-serve-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.platform_serve --smoke

# Self-healing chaos gate: scripted FaultPlan injection, one scenario per
# failure class (OOM, checkpoint corruption, flaky pod, poisoned node,
# straggler, unknown).  Each must be classified correctly, repaired from
# the safe list only, and still COMPLETE.  Virtual time — runs in seconds.
# Never rewrites the checked-in BENCH_chaos.json.
chaos-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.dependability_fig3 --chaos --smoke

dryrun:
	PYTHONPATH=src $(PY) -m repro.launch.dryrun --all
