# Convenience entry points.  `make verify` is the tier-1 gate (same commands
# CI runs); see ROADMAP.md.

PY ?= python

.PHONY: verify lint serve-smoke dryrun

verify: lint
	PYTHONPATH=src $(PY) -m pytest -x -q

# ruff is available in CI; locally the lint step degrades gracefully
lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check .; \
	else \
		echo "ruff not installed; skipping lint"; \
	fi

serve-smoke:
	PYTHONPATH=src $(PY) -m repro.launch.serve --reduced --batch 2 \
		--prompt-len 16 --gen 8
	PYTHONPATH=src $(PY) -m repro.launch.serve --reduced --batch 2 \
		--prompt-len 16 --gen 8 --continuous --requests 4

dryrun:
	PYTHONPATH=src $(PY) -m repro.launch.dryrun --all
