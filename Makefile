# Convenience entry points.  `make verify` is the tier-1 gate (same command
# CI runs); see ROADMAP.md.

PY ?= python

.PHONY: verify serve-smoke dryrun

verify:
	PYTHONPATH=src $(PY) -m pytest -x -q

serve-smoke:
	PYTHONPATH=src $(PY) -m repro.launch.serve --reduced --batch 2 \
		--prompt-len 16 --gen 8
	PYTHONPATH=src $(PY) -m repro.launch.serve --reduced --batch 2 \
		--prompt-len 16 --gen 8 --continuous --requests 4

dryrun:
	PYTHONPATH=src $(PY) -m repro.launch.dryrun --all
