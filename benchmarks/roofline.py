"""Roofline table (required §Roofline): three terms per (arch × shape),
single-pod 16×16 mesh, from the dry-run + analysis artifacts.

    compute    = HLO_FLOPs(device)      / 197 TFLOP/s   (bf16, TPU v5e)
    memory     = HLO_bytes(device)      / 819 GB/s      (HBM)
    collective = wire_bytes(device)     / 50 GB/s       (ICI per link)

FLOPs/bytes come from artifacts/analysis (unrolled-variant extrapolation —
XLA's cost model gives while bodies constant weight, see launch/analysis.py);
collective wire bytes use ring-algorithm accounting per op.  MODEL_FLOPS =
6·N·D (dense) or 6·N_active·D (MoE) counts non-embedding params.
"""
from __future__ import annotations

import json
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts"

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256


def load_cell(arch, shape, mesh="16x16"):
    a = ART / "analysis" / f"{arch}__{shape}__{mesh}.json"
    d = ART / "dryrun" / f"{arch}__{shape}__{mesh}.json"
    rec = {}
    if a.exists():
        rec["analysis"] = json.loads(a.read_text())
    if d.exists():
        rec["dryrun"] = json.loads(d.read_text())
    return rec


def model_flops_per_device(dryrun: dict) -> float:
    """6·N(active)·tokens / chips; decode processes 1 token per sequence."""
    n = dryrun.get("n_params_active") or dryrun.get("n_params")
    kind = dryrun["kind"]
    B, S = dryrun["global_batch"], dryrun["seq_len"]
    tokens = B if kind == "decode" else B * S
    mult = 6 if kind == "train" else 2
    return mult * n * tokens / dryrun.get("n_devices", CHIPS)


def roofline_row(arch: str, shape: str) -> dict | None:
    rec = load_cell(arch, shape)
    dr = rec.get("dryrun", {})
    an = rec.get("analysis", {})
    if "dryrun" not in rec or "analysis" not in rec:
        # fresh checkout or half-run sweep: the cell's dry-run/analysis
        # pass hasn't produced both artifacts yet — not a failure
        return {"arch": arch, "shape": shape, "missing": True}
    if dr.get("skipped") or an.get("skipped"):
        return {"arch": arch, "shape": shape, "skipped": dr.get("skipped") or
                an.get("skipped")}
    if not dr.get("ok") or not an.get("ok"):
        return {"arch": arch, "shape": shape, "error": True}
    ex = an["extrapolated"]
    wire = sum(v["wire_bytes"] for v in ex["collectives"].values())
    t_comp = ex["flops"] / PEAK_FLOPS
    t_mem = ex["bytes"] / HBM_BW
    t_coll = wire / ICI_BW
    row = {}
    if dr.get("kind") == "decode" and _kernel_applies(arch):
        # Paged-decode pricing: the dry-run HLO walks the cache at the
        # dense/table-bounded rate, but the serving hot path is the paged
        # flash-decode kernel, which touches only *resident* pages.
        # Re-price the memory term by swapping the dense-view attention
        # bytes for the kernel's resident-page bytes (per device).  MLA
        # archs keep the HLO pricing — their latent cache has no paged
        # decode walk yet (ROADMAP).
        row.update(_paged_decode_pricing(arch, shape, ex["bytes"]))
        t_mem = row["t_memory_paged_s"]
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(dr)
    step_time = max(terms.values())            # no-overlap upper bound
    mfu = mf / PEAK_FLOPS / step_time if step_time else 0.0
    row.update({
        "arch": arch, "shape": shape,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_dev": mf,
        "hlo_flops_dev": ex["flops"],
        "useful_ratio": mf / ex["flops"] if ex["flops"] else 0.0,
        "roofline_mfu": mfu,
        "temp_bytes_dev": dr.get("memory", {}).get("temp_size_in_bytes"),
    })
    return row


def _kernel_applies(arch: str) -> bool:
    """Paged flash-decode prices GQA page pools; MLA (latent cache) and
    attention-free stacks keep the raw HLO memory term."""
    from repro.configs import get_config
    cfg = get_config(arch)
    return cfg.uses_attention and not cfg.use_mla


def _paged_decode_pricing(arch: str, shape: str, hlo_bytes_dev: float) -> dict:
    """Kernel-vs-dense decode bandwidth for one cell: per-device attention
    bytes under the dense-view walk and the paged kernel (resident pages,
    serving occupancy from the cell's RunConfig), plus the re-priced
    memory term and the kernel's arithmetic intensity."""
    import dataclasses as _dc

    from repro.configs import SHAPES, get_config, get_run_config
    from repro.launch.specs import (
        decode_arithmetic_intensity, decode_attn_bytes)

    cfg = _dc.replace(get_config(arch), cache_layout="paged")
    sh = SHAPES[shape]
    run = get_run_config(arch, shape)
    dense_dev = decode_attn_bytes(cfg, sh, run, "dense") / CHIPS
    # dedup-aware: prefix pages shared across the batch (the serving
    # engine's prefix cache) are physically read once per step.  Equal to
    # the plain kernel walk at RunConfig.prefix_share_frac = 0, so cells
    # without a share assumption price exactly as before.
    kern_dev = decode_attn_bytes(cfg, sh, run, "kernel_unique") / CHIPS
    adj = max(hlo_bytes_dev - dense_dev + kern_dev, kern_dev)
    return {
        "attn_bytes_dense_dev": dense_dev,
        "attn_bytes_kernel_dev": kern_dev,
        "t_memory_paged_s": adj / HBM_BW,
        "kernel_ai_flops_per_byte": decode_arithmetic_intensity(
            cfg, sh, run, "kernel_unique"),
    }


def all_rows():
    from repro.configs import SHAPES, list_configs
    rows = []
    for arch in list_configs():
        if arch == "paper-overhead-100m":
            continue
        for shape in SHAPES:
            r = roofline_row(arch, shape)
            if r is not None:
                rows.append(r)
    return rows


def main():
    rows = all_rows()
    missing = sum(1 for r in rows if r.get("missing"))
    if missing == len(rows):
        print(f"(no dry-run artifacts for any of the {missing} cells — run "
              "`python -m repro.launch.dryrun --all` and "
              "`python -m repro.launch.analysis` to populate artifacts/)")
        return
    print("arch,shape,t_compute_ms,t_memory_ms,t_collective_ms,dominant,"
          "useful_flops_ratio,roofline_mfu,temp_GB,kernel_ai")
    for r in rows:
        if r.get("missing"):
            print(f"{r['arch']},{r['shape']},MISSING,,,,")
            continue
        if r.get("skipped"):
            print(f"{r['arch']},{r['shape']},SKIP,,,,{r['skipped'][:40]}...")
            continue
        if r.get("error"):
            print(f"{r['arch']},{r['shape']},ERROR,,,,")
            continue
        ai = r.get("kernel_ai_flops_per_byte")
        print(f"{r['arch']},{r['shape']},"
              f"{r['t_compute_s']*1e3:.1f},{r['t_memory_s']*1e3:.1f},"
              f"{r['t_collective_s']*1e3:.1f},{r['dominant']},"
              f"{r['useful_ratio']:.3f},{r['roofline_mfu']:.3f},"
              f"{(r['temp_bytes_dev'] or 0)/1e9:.1f},"
              f"{'' if ai is None else f'{ai:.2f}'}")


if __name__ == "__main__":
    main()
