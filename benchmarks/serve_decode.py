"""Serving-decode benchmark lane: paged-reference walk vs flash-decode.

Four sections, emitted together to ``BENCH_serve_decode.json``:

* **modeled** — per-step attention bytes-touched for production decode
  cells under the walks priced by ``launch.specs.decode_attn_bytes``
  (dense buffer / paged gather reference / paged kernel — and, for MLA,
  the hypothetical head-expanded cache), swept over pool occupancy.  The
  reference gathers the table-bounded dense view, so its bytes are flat
  in occupancy; the kernel touches only resident pages, so its bytes
  scale down linearly — the ratio is the modeled bandwidth win (4x at
  25% occupancy, the ISSUE acceptance number).  For deepseek-v2 the
  latent walk must also price ≥4x below the dense-expanded equivalent.
* **measured** — real wall-clock per decode step at a small op-level
  shape on the current backend (CPU in CI): the jitted reference gather
  vs the jitted O(pages) ``lax.scan`` walk, over the same occupancy
  sweep, plus a one-step interpret-mode run of the Pallas kernel checked
  against the reference (kernels are *validated* here; kernel speed is a
  TPU property the modeled section stands in for).
* **mla_measured** — the same sweep for the MLA latent walk: scan
  ms/step, latent vs hypothetical dense-expanded bytes/step, and the
  latent Pallas kernel validated (interpret) against the scan.
* **grouped_measured** — the head-tiled grouped kernel at G=8 (beyond
  the old ``G <= 4`` auto-cap) validated against the ungrouped grid and
  the scan walk, with scan timing for scale.

    PYTHONPATH=src python -m benchmarks.serve_decode [--smoke] [--no-write]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

OUT = Path(__file__).resolve().parents[1] / "BENCH_serve_decode.json"

MODELED_ARCHS = ("qwen3-0.6b", "gemma2-9b", "mistral-large-123b",
                 "deepseek-v2-236b")
MODELED_SHAPE = "decode_32k"
OCCUPANCIES = (1.0, 0.5, 0.25, 0.125)


def modeled_rows():
    from repro.configs import SHAPES, RunConfig, get_config
    from repro.launch.specs import (
        decode_arithmetic_intensity, decode_attn_bytes)

    rows = []
    for arch in MODELED_ARCHS:
        cfg = dataclasses.replace(get_config(arch), cache_layout="paged")
        sh = SHAPES[MODELED_SHAPE]
        for occ in OCCUPANCIES:
            run = RunConfig(page_occupancy=occ)
            dense = decode_attn_bytes(cfg, sh, run, "dense")
            ref = decode_attn_bytes(cfg, sh, run, "reference")
            kern = decode_attn_bytes(cfg, sh, run, "kernel")
            row = {
                "arch": arch, "shape": MODELED_SHAPE, "occupancy": occ,
                "bytes_dense": dense, "bytes_reference": ref,
                "bytes_kernel": kern,
                "reduction_ref_over_kernel": round(ref / kern, 3),
                "kernel_ai_flops_per_byte": round(
                    decode_arithmetic_intensity(cfg, sh, run, "kernel"), 3),
                "reference_ai_flops_per_byte": round(
                    decode_arithmetic_intensity(cfg, sh, run, "reference"), 3),
            }
            if cfg.use_mla:
                # the MLA lane's headline: what a head-expanded cache
                # would read vs the latent pages the kernel walks
                expanded = decode_attn_bytes(cfg, sh, run, "dense_expanded")
                row["bytes_dense_expanded"] = expanded
                row["reduction_expanded_over_kernel"] = round(
                    expanded / kern, 3)
            rows.append(row)
    return rows


def _time_it(fn, *args, iters: int) -> float:
    import jax
    jax.block_until_ready(fn(*args))           # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def measured_rows(smoke: bool):
    import functools

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.ops import paged_decode_bhd
    from repro.kernels.paged_attention import paged_decode_jnp
    from repro.models.attention import decode_attention_paged

    if smoke:
        B, H, K, hd, ps, pps, iters = 2, 4, 2, 16, 8, 8, 3
    else:
        B, H, K, hd, ps, pps, iters = 8, 16, 4, 64, 16, 64, 20
    P = B * pps                                  # worst-case pool
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, K, ps, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, K, ps, hd)), jnp.float32)
    scale = hd ** -0.5

    ref = jax.jit(functools.partial(decode_attention_paged, scale=scale))
    scan = jax.jit(functools.partial(
        lambda q, k, v, t, p, scale: paged_decode_jnp(
            q.reshape(B, K, H // K, hd), k, v, t, p,
            scale=scale).reshape(B, 1, H, hd), scale=scale))

    shape_meta = {"B": B, "H": H, "K": K, "hd": hd, "page_size": ps,
                  "pages_per_seq": pps, "pool_pages": P, "iters": iters,
                  "backend": jax.default_backend()}
    steps = []
    kernel_err = 0.0
    for occ in OCCUPANCIES:
        live = max(int(pps * occ), 1)
        table = np.full((B, pps), -1, np.int32)
        for b in range(B):
            table[b, :live] = rng.permutation(P)[:live]
        table_j = jnp.asarray(table)
        pos = jnp.full((B,), live * ps - 1, jnp.int32)   # last live slot
        t_ref = _time_it(ref, q, kp, vp, table_j, pos, iters=iters)
        t_scan = _time_it(scan, q, kp, vp, table_j, pos, iters=iters)
        # one interpret-mode kernel step, checked against the reference
        out_k = paged_decode_bhd(q, kp, vp, table_j, pos, scale=scale)
        out_r = ref(q, kp, vp, table_j, pos)
        kernel_err = max(kernel_err, float(jnp.abs(out_k - out_r).max()))
        token_bytes = 2 * K * hd * 4                     # K+V, fp32
        steps.append({
            "occupancy": occ, "live_pages": live,
            "ref_ms_per_step": round(t_ref * 1e3, 3),
            "scan_ms_per_step": round(t_scan * 1e3, 3),
            "tokens_per_s_ref": round(B / t_ref, 1),
            "tokens_per_s_scan": round(B / t_scan, 1),
            "bytes_touched_ref": B * pps * ps * token_bytes,
            "bytes_touched_scan": B * live * ps * token_bytes,
        })
    return {"shape": shape_meta, "steps": steps,
            "kernel_interpret_max_abs_err": kernel_err}


def mla_measured_rows(smoke: bool):
    import functools

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.paged_attention import (
        mla_paged_decode_attention, mla_paged_decode_jnp)

    if smoke:
        B, H, lora, rd, ps, pps, iters = 2, 4, 16, 8, 8, 8, 3
    else:
        B, H, lora, rd, ps, pps, iters = 8, 16, 64, 32, 16, 64, 20
    # the hypothetical head-expanded cache the latent layout replaces:
    # per-head nope+rope keys and values of the same latent capacity
    expanded_tok_bytes = H * (lora + rd + lora) * 4
    latent_tok_bytes = (lora + rd) * 4
    P = B * pps
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(B, H, lora + rd)), jnp.float32)
    ckv = jnp.asarray(rng.normal(size=(P, ps, lora)), jnp.float32)
    krope = jnp.asarray(rng.normal(size=(P, ps, rd)), jnp.float32)
    scale = (lora + rd) ** -0.5

    scan = jax.jit(functools.partial(mla_paged_decode_jnp, scale=scale))
    shape_meta = {"B": B, "H": H, "kv_lora_rank": lora, "rope_dim": rd,
                  "page_size": ps, "pages_per_seq": pps, "pool_pages": P,
                  "iters": iters, "backend": jax.default_backend()}
    steps = []
    kernel_err = 0.0
    for occ in OCCUPANCIES:
        live = max(int(pps * occ), 1)
        table = np.full((B, pps), -1, np.int32)
        for b in range(B):
            table[b, :live] = rng.permutation(P)[:live]
        table_j = jnp.asarray(table)
        pos = jnp.full((B,), live * ps - 1, jnp.int32)
        t_scan = _time_it(scan, q, ckv, krope, table_j, pos, iters=iters)
        out_k = mla_paged_decode_attention(q, ckv, krope, table_j, pos,
                                           scale=scale, interpret=True)
        out_s = scan(q, ckv, krope, table_j, pos)
        kernel_err = max(kernel_err, float(jnp.abs(out_k - out_s).max()))
        steps.append({
            "occupancy": occ, "live_pages": live,
            "scan_ms_per_step": round(t_scan * 1e3, 3),
            "tokens_per_s_scan": round(B / t_scan, 1),
            "bytes_latent": B * live * ps * latent_tok_bytes,
            "bytes_dense_expanded": B * pps * ps * expanded_tok_bytes,
        })
    return {"shape": shape_meta, "steps": steps,
            "kernel_interpret_max_abs_err": kernel_err}


def grouped_measured_rows(smoke: bool):
    import functools

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.paged_attention import (
        group_tile, paged_decode_attention, paged_decode_jnp)

    G = 8                                        # beyond the old auto-cap
    if smoke:
        B, K, hd, ps, pps, iters = 2, 2, 16, 8, 8, 3
    else:
        B, K, hd, ps, pps, iters = 8, 4, 64, 16, 64, 20
    P = B * pps
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(B, K, G, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, K, ps, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, K, ps, hd)), jnp.float32)
    scale = hd ** -0.5

    live = max(pps // 2, 1)
    table = np.full((B, pps), -1, np.int32)
    for b in range(B):
        table[b, :live] = rng.permutation(P)[:live]
    table_j = jnp.asarray(table)
    pos = jnp.full((B,), live * ps - 1, jnp.int32)

    scan = jax.jit(functools.partial(paged_decode_jnp, scale=scale))
    t_scan = _time_it(scan, q, kp, vp, table_j, pos, iters=iters)
    grp = paged_decode_attention(q, kp, vp, table_j, pos, scale=scale,
                                 interpret=True, grouped=True)
    ung = paged_decode_attention(q, kp, vp, table_j, pos, scale=scale,
                                 interpret=True, grouped=False)
    out_s = scan(q, kp, vp, table_j, pos)
    return {
        "shape": {"B": B, "K": K, "G": G, "hd": hd, "page_size": ps,
                  "pages_per_seq": pps, "head_tile": group_tile(K, G),
                  "iters": iters, "backend": jax.default_backend()},
        "scan_ms_per_step": round(t_scan * 1e3, 3),
        "grouped_vs_ungrouped_max_abs_err": float(
            jnp.abs(grp - ung).max()),
        "grouped_vs_scan_max_abs_err": float(jnp.abs(grp - out_s).max()),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI kernel-regression gate)")
    ap.add_argument("--no-write", action="store_true",
                    help="print only; don't rewrite BENCH_serve_decode.json")
    args = ap.parse_args(argv)

    modeled = modeled_rows()
    print("arch,shape,occupancy,GB_reference,GB_kernel,reduction,kernel_AI")
    for r in modeled:
        print(f"{r['arch']},{r['shape']},{r['occupancy']},"
              f"{r['bytes_reference']/1e9:.2f},{r['bytes_kernel']/1e9:.2f},"
              f"{r['reduction_ref_over_kernel']:.1f}x,"
              f"{r['kernel_ai_flops_per_byte']:.2f}")

    measured = measured_rows(args.smoke)
    err = measured["kernel_interpret_max_abs_err"]
    print(f"\nmeasured (backend={measured['shape']['backend']}, "
          f"pool={measured['shape']['pool_pages']} pages):")
    for s in measured["steps"]:
        print(f"  occ={s['occupancy']:<6} ref {s['ref_ms_per_step']:7.2f} ms"
              f"  scan {s['scan_ms_per_step']:7.2f} ms"
              f"  ({s['tokens_per_s_scan']:.0f} tok/s scan, "
              f"bytes {s['bytes_touched_ref']/1e6:.1f} -> "
              f"{s['bytes_touched_scan']/1e6:.1f} MB)")
    print(f"kernel (interpret) vs reference max abs err: {err:.2e}")
    if not (err < 1e-4):
        print("FAIL: kernel drifted from the reference walk")
        return 1

    quarter = [r for r in modeled if r["occupancy"] == 0.25]
    if any(r["reduction_ref_over_kernel"] < 4.0 for r in quarter):
        print("FAIL: <4x modeled reduction at 25% occupancy")
        return 1
    mla_modeled = [r for r in modeled if "reduction_expanded_over_kernel"
                   in r]
    if any(r["reduction_expanded_over_kernel"] < 4.0 for r in mla_modeled):
        print("FAIL: MLA latent walk <4x below the dense-expanded cache")
        return 1

    mla = mla_measured_rows(args.smoke)
    mla_err = mla["kernel_interpret_max_abs_err"]
    print(f"\nmla_measured (backend={mla['shape']['backend']}, "
          f"pool={mla['shape']['pool_pages']} pages):")
    for s in mla["steps"]:
        print(f"  occ={s['occupancy']:<6} scan {s['scan_ms_per_step']:7.2f}"
              f" ms  (latent {s['bytes_latent']/1e6:.2f} MB vs expanded "
              f"{s['bytes_dense_expanded']/1e6:.2f} MB)")
    print(f"mla kernel (interpret) vs scan max abs err: {mla_err:.2e}")
    if not (mla_err < 1e-4):
        print("FAIL: MLA kernel drifted from the latent scan walk")
        return 1

    grouped = grouped_measured_rows(args.smoke)
    gsh = grouped["shape"]
    print(f"\ngrouped_measured G={gsh['G']} K={gsh['K']} "
          f"(head_tile={gsh['head_tile']}): "
          f"scan {grouped['scan_ms_per_step']:.2f} ms, "
          f"grouped-vs-ungrouped err "
          f"{grouped['grouped_vs_ungrouped_max_abs_err']:.2e}, "
          f"grouped-vs-scan err "
          f"{grouped['grouped_vs_scan_max_abs_err']:.2e}")
    if not (grouped["grouped_vs_ungrouped_max_abs_err"] < 1e-4
            and grouped["grouped_vs_scan_max_abs_err"] < 1e-4):
        print("FAIL: grouped G=8 kernel drifted past the old auto-cap")
        return 1

    if not args.no_write and not args.smoke:   # smoke never rewrites the
        OUT.write_text(json.dumps(             # checked-in trajectory file
            {"modeled": modeled, "measured": measured,
             "mla_measured": mla, "grouped_measured": grouped},
            indent=1) + "\n")
        print(f"wrote {OUT}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
