"""Prefix-cache benchmark lane: hash-addressed shared prefix pages + CoW.

Two sections, emitted together to ``BENCH_prefix_cache.json``:

* **modeled** — per-step decode attention bytes and admitted capacity for
  production decode cells under batch-wide prefix sharing, swept over the
  share ratio (``RunConfig.prefix_share_frac``).  Shared prefix pages are
  physically resident ONCE, so the ``kernel_unique`` pricing path of
  ``launch.specs.decode_attn_bytes`` scales bytes/step down toward
  ``1/B`` of the kernel walk as the share ratio grows, and the same page
  budget admits proportionally more concurrent sequences.
* **measured** — the real ``ServingEngine`` on the current backend (CPU
  in CI) at a reduced shape, swept over share ratio × the same batch:
  prompt tokens actually prefilled vs served from cache, unique resident
  prefix pages (N sequences, ONE physical copy), peak concurrency vs the
  ``prefix_cache=False`` baseline, and byte-identical responses between
  the shared batch and solo runs of each request through a fresh engine
  (sharing is an alias, never an answer change — greedy decode must not
  notice).  The no-cache baseline's responses are reported but not gated:
  it prefills via the flash path, a different float-association family
  than the cache engine's chunked paged walk (~2e-3 logit noise, which
  can flip an argmax without either result being wrong).

    PYTHONPATH=src python -m benchmarks.prefix_cache [--smoke] [--no-write]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path

OUT = Path(__file__).resolve().parents[1] / "BENCH_prefix_cache.json"

MODELED_ARCHS = ("qwen3-0.6b", "gemma2-9b", "mistral-large-123b")
MODELED_SHAPE = "decode_32k"
SHARES = (0.0, 0.5, 0.9)


def modeled_rows():
    from repro.configs import SHAPES, RunConfig, get_config
    from repro.launch.specs import (
        decode_attn_bytes, decode_page_budget, unique_decode_pages)
    from repro.models.model import num_pages

    rows = []
    for arch in MODELED_ARCHS:
        cfg = dataclasses.replace(get_config(arch), cache_layout="paged")
        sh = SHAPES[MODELED_SHAPE]
        B = sh.global_batch
        r = num_pages(sh.seq_len, cfg.page_size)   # resident pages/seq
        for share in SHARES:
            run = RunConfig(prefix_share_frac=share)
            kern = decode_attn_bytes(cfg, sh, run, "kernel")
            uniq = decode_attn_bytes(cfg, sh, run, "kernel_unique")
            budget = decode_page_budget(cfg, sh, run)
            shared_pages = min(int(r * share), r)
            # the page budget holds `cap` concurrent sequences: the shared
            # span is resident once, each private remainder per sequence
            cap = (budget - shared_pages) // max(r - shared_pages, 1) \
                if shared_pages else budget // r
            rows.append({
                "arch": arch, "shape": MODELED_SHAPE, "share": share,
                "batch": B, "pages_per_seq": r,
                "unique_pages": unique_decode_pages(B, r, run),
                "bytes_kernel": kern, "bytes_kernel_unique": uniq,
                "reduction_bytes": round(kern / uniq, 3),
                "admitted_capacity": int(cap),
                "capacity_gain": round(cap / max(budget // r, 1), 3),
            })
    return rows


def _drive(engine):
    """engine.run() while tracking peak concurrency."""
    peak = 0
    while not engine.idle:
        engine.admit()
        peak = max(peak, sum(s is not None for s in engine.slots))
        if all(s is None for s in engine.slots):
            if not engine.queue:
                break
            continue
        engine.step()
    return peak


def measured_rows(smoke: bool):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.jobspec import ServeSpec
    from repro.launch.engine import ServingEngine, synthesize_requests
    from repro.models.layers import Ctx
    from repro.models.params import init_params

    # prompt 80 @ page 8: 90% share = 72 tokens = exactly 9 full pages.
    # budget 40 serializes the no-sharing baseline (8 x 11 worst-case
    # pages) but admits the whole dedup batch (11 + 7 x 2 reserved).
    N, P, G, budget = 8, 80, 8, 40
    cfg = dataclasses.replace(get_config("qwen3-0.6b").reduced(),
                              cache_layout="paged")
    ctx = Ctx(dtype=jnp.float32)
    params = init_params(cfg, jax.random.key(0))
    shares = (0.0, 0.9) if smoke else SHARES

    steps = []
    for share in shares:
        sv = ServeSpec(batch=N, prompt_len=P, gen=G, requests=N,
                       page_budget=budget, reduced=True,
                       shared_prefix_frac=share)
        eng = ServingEngine(cfg, ctx, params, sv)
        reqs = synthesize_requests(cfg, sv, seed=0, ragged=eng.ragged)
        for r in reqs:
            eng.submit(r)
        # capture residency right after the batch is fully admitted
        eng.admit()
        eng.admit()
        prefix_pages = eng.resident_prefix_pages()
        unique_pages = eng.unique_resident_pages()
        peak = max(_drive(eng), sum(s is not None for s in eng.slots))

        base = ServingEngine(cfg, ctx, params,
                             dataclasses.replace(sv, prefix_cache=False))
        for r in synthesize_requests(cfg, sv, seed=0, ragged=base.ragged):
            base.submit(r)
        base_peak = _drive(base)

        # golden gate: each request solo through a fresh cache engine must
        # reproduce its batch response token-for-token — page aliasing and
        # CoW are invisible to the answers (smoke spot-checks 3 requests)
        probe = range(N) if not smoke else (0, 1, N - 1)
        solo_ok = True
        for i in probe:
            se = ServingEngine(cfg, ctx, params, sv)
            se.submit(reqs[i])
            se.run()
            solo_ok = solo_ok and se.responses[i] == eng.responses[i]

        steps.append({
            "share": share, "requests": N, "prompt_len": P,
            "page_size": eng.ps, "page_budget": budget,
            "prefill_tokens": eng.prefill_tokens,
            "cached_tokens": eng.cached_tokens,
            "prefill_tokens_baseline": base.prefill_tokens,
            "resident_prefix_pages": prefix_pages,
            "unique_resident_pages": unique_pages,
            "prefix_hits": eng.prefix_hits,
            "cow_copies": eng.cow_copies,
            "peak_concurrency": peak,
            "peak_concurrency_baseline": base_peak,
            "responses_match_solo": solo_ok,
            "responses_match_baseline": eng.responses == base.responses,
        })
    return {"arch": cfg.name, "backend": jax.default_backend(),
            "steps": steps}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="share endpoints only (CI regression gate)")
    ap.add_argument("--no-write", action="store_true",
                    help="print only; don't rewrite BENCH_prefix_cache.json")
    args = ap.parse_args(argv)

    modeled = modeled_rows()
    print("arch,shape,share,GB_kernel,GB_unique,reduction,capacity,gain")
    for r in modeled:
        print(f"{r['arch']},{r['shape']},{r['share']},"
              f"{r['bytes_kernel']/1e9:.2f},"
              f"{r['bytes_kernel_unique']/1e9:.2f},"
              f"{r['reduction_bytes']:.1f}x,{r['admitted_capacity']},"
              f"{r['capacity_gain']:.1f}x")

    measured = measured_rows(args.smoke)
    print(f"\nmeasured (arch={measured['arch']}, "
          f"backend={measured['backend']}):")
    for s in measured["steps"]:
        print(f"  share={s['share']:<4} prefill {s['prefill_tokens']:4d} "
              f"(baseline {s['prefill_tokens_baseline']}) "
              f"cached {s['cached_tokens']:4d}  prefix pages "
              f"{s['resident_prefix_pages']}  concurrency "
              f"{s['peak_concurrency']} vs {s['peak_concurrency_baseline']}"
              f"  solo_match={s['responses_match_solo']}")

    failures = []
    for s in measured["steps"]:
        if not s["responses_match_solo"]:
            failures.append(f"share {s['share']}: batch responses diverged "
                            "from solo runs (aliasing changed an answer)")
    hi = [s for s in measured["steps"] if s["share"] == 0.9]
    for s in hi:
        N, P, ps = s["requests"], s["prompt_len"], s["page_size"]
        C = int(P * 0.9)
        # exactly ONE prefill over the shared span: leader pays P, each
        # follower only its private tail
        want = P + (N - 1) * (P - C)
        if s["prefill_tokens"] != want:
            failures.append(f"90% share: {s['prefill_tokens']} prefill "
                            f"tokens, want {want} (one shared-span prefill)")
        if s["resident_prefix_pages"] != -(-C // ps):
            failures.append(f"90% share: {s['resident_prefix_pages']} "
                            f"resident prefix pages, want {-(-C // ps)} "
                            "(one physical copy, not N)")
        if s["peak_concurrency"] < 2 * s["peak_concurrency_baseline"]:
            failures.append("90% share: <2x measured admitted capacity")
    hi_m = [r for r in modeled if r["share"] == 0.9]
    if any(r["reduction_bytes"] < 2.0 and r["capacity_gain"] < 2.0
           for r in hi_m):
        failures.append("<2x modeled bytes/step AND capacity at 90% share")

    for f in failures:
        print(f"FAIL: {f}")
    if failures:
        return 1

    if not args.no_write and not args.smoke:   # smoke never rewrites the
        OUT.write_text(json.dumps(             # checked-in trajectory file
            {"modeled": modeled, "measured": measured}, indent=1) + "\n")
        print(f"wrote {OUT}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
