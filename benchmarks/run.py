"""Benchmark driver: one section per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slow real-compute benchmarks")
    args = ap.parse_args()

    print("=" * 72)
    print("# Fig 4 — per-component crash recovery (virtual seconds)")
    print("=" * 72)
    from benchmarks import recovery_fig4
    recovery_fig4.main()

    if not args.quick:
        print()
        print("=" * 72)
        print("# Fig 2 — platform overhead vs bare loop (real JAX steps)")
        print("=" * 72)
        from benchmarks import overhead_fig2
        overhead_fig2.main()

        print()
        print("=" * 72)
        print("# Fig 3 — dependability fully-armed vs minimal")
        print("=" * 72)
        from benchmarks import dependability_fig3
        dependability_fig3.main([])

    print()
    print("=" * 72)
    print("# Chaos lane — self-healing Guardian: classify + safe repair "
          "per failure class")
    print("=" * 72)
    from benchmarks import dependability_fig3 as fig3
    failures_chaos = fig3.main(
        ["--chaos", "--smoke"] if args.quick else ["--chaos"])

    print()
    print("=" * 72)
    print("# Serve decode — paged flash-decode vs reference walk "
          "(bytes/step + tok/s)")
    print("=" * 72)
    from benchmarks import serve_decode
    failures = serve_decode.main(["--smoke"] if args.quick else [])

    print()
    print("=" * 72)
    print("# Platform serve — real-engine payload under the platform vs "
          "direct (wall s)")
    print("=" * 72)
    from benchmarks import platform_serve
    failures = platform_serve.main(
        ["--smoke"] if args.quick else []) or failures

    print()
    print("=" * 72)
    print("# Prefix cache — shared-prefix dedup + CoW "
          "(prefill tokens, resident pages, capacity)")
    print("=" * 72)
    from benchmarks import prefix_cache
    failures = prefix_cache.main(
        ["--smoke"] if args.quick else ["--no-write"]) or failures

    print()
    print("=" * 72)
    print("# Roofline — per (arch × shape), single-pod 16x16 "
          "(from dry-run artifacts)")
    print("=" * 72)
    from benchmarks import roofline
    roofline.main()

    if failures or failures_chaos:
        sys.exit(1)                  # propagate lane FAILs to CI


if __name__ == "__main__":
    main()
