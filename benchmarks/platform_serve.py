"""Platform-vs-direct serving overhead for the REAL payload (Fig-2 analog
for the serve kind).

The same ``JobSpec(kind="serve", serve.real_compute=True)`` workload runs
twice:

* **direct** — ``RealServePayload.build()`` + ``ServingEngine`` drained
  in-process: model build, prefill/decode compiles, continuous batching.
* **platform** — submitted to ``DLaaSPlatform``: the identical engine runs
  inside a server pod under the full dependability machinery (gang
  admission, claim journal + periodic engine snapshots on the job volume,
  COS response shipping, Guardian monitoring, metering).

Overhead = extra wall-clock the platform machinery adds around identical
JAX work (each side pays exactly one model build + compile).  The run also
asserts the two response sets are byte-identical — the platform must never
change what gets served, only make it dependable.

    PYTHONPATH=src python -m benchmarks.platform_serve [--smoke] [--no-write]

``--smoke`` (CI) uses tiny shapes and never rewrites the checked-in
``BENCH_platform_serve.json``.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

OUT = Path(__file__).resolve().parents[1] / "BENCH_platform_serve.json"


def _spec(smoke: bool):
    from repro.core.jobspec import JobSpec, ServeSpec
    sv = ServeSpec(batch=2, prompt_len=16, gen=6, requests=4,
                   reduced=True, real_compute=True, snapshot_every=2) \
        if smoke else \
        ServeSpec(batch=4, prompt_len=32, gen=16, requests=12,
                  reduced=True, real_compute=True, snapshot_every=4)
    return JobSpec(name="bench-platform-serve", kind="serve",
                   framework="qwen3-0.6b", serve=sv)


def run_direct(spec):
    from repro.launch.engine import RealServePayload
    t0 = time.perf_counter()
    engine, requests = RealServePayload(spec).build()
    for r in requests:
        engine.submit(r)
    engine.run()
    dt = time.perf_counter() - t0
    return dt, engine.responses, {
        "decode_steps": engine.decode_steps,
        "generated": engine.generated,
        "high_water_pages": engine.pool.high_water,
    }


def run_platform(spec):
    from repro.core.platform import DLaaSPlatform
    t0 = time.perf_counter()
    p = DLaaSPlatform(seed=11)
    p.run(10)
    h = p.submit(spec)
    p.run(5)
    assert h.acked, h.rejected
    state = p.run_until_terminal(h.job_id, timeout=3600)
    dt = time.perf_counter() - t0
    assert state == "COMPLETED", state
    responses = {}
    for r in range(spec.serve.requests):
        raw = p.objectstore.get(f"cos/{h.job_id}/responses/{r}")
        responses[r] = json.loads(raw.decode())["tokens"]
    return dt, responses, {"virtual_s": round(p.sim.now, 1),
                           "restarts": p.client.get(h.job_id)["restarts"]}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI platform-serve gate)")
    ap.add_argument("--no-write", action="store_true",
                    help="print only; don't rewrite BENCH_platform_serve.json")
    args = ap.parse_args(argv)

    spec = _spec(args.smoke)
    sv = spec.serve
    print(f"workload: {spec.framework} reduced, slots={sv.batch} "
          f"prompt<={sv.prompt_len} gen<={sv.gen} requests={sv.requests} "
          f"snapshot_every={sv.snapshot_every}")

    # warm-up both paths once so first-touch costs (compile caches, import
    # side effects) bias neither measured run; smoke only gates on the
    # byte-equality check, so it skips the warm-up entirely
    if not args.smoke:
        run_direct(spec)
        run_platform(spec)
    direct_s, direct_resp, engine_stats = run_direct(spec)
    platform_s, platform_resp, plat_stats = run_platform(spec)

    if platform_resp != direct_resp:
        print("FAIL: platform responses diverge from the direct engine run")
        return 1
    overhead_pct = 100.0 * (platform_s - direct_s) / direct_s
    tokens = sum(len(t) for t in direct_resp.values())
    print(f"direct:   {direct_s:6.1f} s wall "
          f"({tokens/direct_s:.0f} tok/s, "
          f"{engine_stats['decode_steps']} decode steps)")
    print(f"platform: {platform_s:6.1f} s wall "
          f"(virtual {plat_stats['virtual_s']} s, "
          f"restarts {plat_stats['restarts']})")
    print(f"overhead: {overhead_pct:+.1f}% (incl. per-pod model build, "
          f"snapshots every {sv.snapshot_every} steps, COS shipping)")
    print("responses: byte-identical across platform and direct runs")

    if not args.no_write and not args.smoke:   # smoke never rewrites the
        OUT.write_text(json.dumps(             # checked-in trajectory file
            {"workload": {"framework": spec.framework,
                          "batch": sv.batch, "prompt_len": sv.prompt_len,
                          "gen": sv.gen, "requests": sv.requests,
                          "snapshot_every": sv.snapshot_every},
             "direct_s": round(direct_s, 2),
             "platform_s": round(platform_s, 2),
             "overhead_pct": round(overhead_pct, 1),
             "tokens": tokens,
             "engine": engine_stats,
             "platform": plat_stats,
             "responses_match": True}, indent=1) + "\n")
        print(f"wrote {OUT}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
