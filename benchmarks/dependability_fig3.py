"""Fig. 3 analog: the dependability/efficiency trade-off — plus the
self-healing chaos lane.

**Overhead section** (default).  The paper's Fig. 3 compares DLaaS on
commodity hardware against a bare DGX-1 (≈3–14% slower) and argues the gap
buys dependability.  Our analog measures the cost of ARMING the
dependability features on the same hardware: a minimally-instrumented loop
vs a fully-armed one (synchronous quorum status every step + frequent real
checkpoints to the object store with sha256 integrity).  The fully-armed
config bounds lost work at one checkpoint interval; the measured % slowdown
is the price.

Output rows: config,steps_s,overhead_pct_vs_minimal,ckpt_bytes

**Chaos lane** (``--chaos``).  Scripted ``FaultPlan`` injection against the
virtual-time platform, one scenario per failure class the self-healing
Guardian knows how to classify and repair:

    scenario        injected fault            expected classification/repair
    oom             learner OOM gate          OOM → reduce_memory
    ckpt_corrupt    corrupt newest gen +      CKPT_CORRUPT → checkpoint_fallback
                    chief kill
    flaky_pod       one-shot pod kill         FLAKY_POD → restart_in_place
    poisoned_node   poison the learners'      POISONED_NODE →
                    node (gray failure)       reschedule_exclude_node
    straggler       4× slow incarnation       STRAGGLER → restart_in_place
    unknown         wedge with an exit        UNKNOWN → plain restart,
                    detail nobody knows       NO repair applied

Each scenario must end COMPLETED with the expected category journaled in
the job's event stream and the applied repair drawn from the registered
safe list (``core.failures.SAFE_REPAIRS``); the ``unknown`` scenario must
provably fall back to a plain restart (no REPAIR event, no exclusions, no
knob writes).  Everything runs in virtual time — seconds of wall-clock,
no JAX.  ``--smoke`` skips rewriting the checked-in ``BENCH_chaos.json``.

    PYTHONPATH=src python -m benchmarks.dependability_fig3 [--chaos] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import List, Optional

STEPS = 60
WARMUP = 10

BENCH_OUT = Path(__file__).resolve().parents[1] / "BENCH_chaos.json"
REPORT_OUT = Path(__file__).resolve().parents[1] / "artifacts" / \
    "chaos_report.json"


# ---------------------------------------------------------------------------
# Overhead section (real JAX steps; unchanged semantics)
# ---------------------------------------------------------------------------
def run(arch: str = "paper-overhead-100m", ckpt_every: int = 10):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import RunConfig, get_config
    from repro.core.checkpoint import CheckpointManager
    from repro.core.objectstore import ObjectStore
    from repro.core.platform import DLaaSPlatform
    from repro.data.pipeline import SyntheticLMData
    from repro.models.layers import Ctx
    from repro.train.steps import init_train_state, make_train_step

    cfg = get_config(arch).reduced()
    run_cfg = RunConfig(learning_rate=1e-3, warmup_steps=5, total_steps=1000)
    data = SyntheticLMData(cfg.vocab_size, 64, 8, seed=0)
    step = jax.jit(make_train_step(cfg, Ctx(dtype=jnp.float32), run_cfg))

    def warm():
        s = init_train_state(cfg, jax.random.key(0), run_cfg)
        for i in range(WARMUP):
            s, m = step(s, data.batch_at(i))
        jax.block_until_ready(m["loss"])
        return s

    platform = DLaaSPlatform(seed=2)
    platform.run(5)
    store = ObjectStore()
    ck = CheckpointManager(store, "armed", keep_last=2)

    def run_minimal(s):
        t0 = time.perf_counter()
        for i in range(STEPS):
            s, m = step(s, data.batch_at(i))
        jax.block_until_ready(m["loss"])
        return STEPS / (time.perf_counter() - t0)

    def run_armed(s):
        t0 = time.perf_counter()
        for i in range(STEPS):
            s, m = step(s, data.batch_at(i))
            def put(i=i):
                yield from platform.statestore.put(
                    "status/armed/learner/0",
                    {"state": "RUNNING", "step": i, "loss": float(m["loss"])})
            platform.sim.spawn(put())
            platform.sim.run_for(0.3)
            if (i + 1) % ckpt_every == 0:
                ck.save(i, jax.tree.map(np.asarray, s))
        jax.block_until_ready(m["loss"])
        return STEPS / (time.perf_counter() - t0)

    # interleave repetitions and take medians (1-CPU timing is noisy)
    import statistics
    s = warm()
    mins, arms = [], []
    for _ in range(3):
        mins.append(run_minimal(s))
        arms.append(run_armed(s))
    minimal = statistics.median(mins)
    armed = statistics.median(arms)

    pct = 100.0 * (minimal - armed) / minimal
    return [
        ("dependability_fig3/minimal", minimal, 0.0, 0),
        (f"dependability_fig3/armed_ckpt{ckpt_every}", armed, pct,
         store.bytes_written),
    ]


# ---------------------------------------------------------------------------
# Chaos lane (virtual time; no JAX)
# ---------------------------------------------------------------------------
def _chaos_submit(p, name, *, learners, gpus=1, total_steps=60,
                  ckpt_s=10.0, recovery="checkpoint"):
    from repro.core.jobspec import JobSpec, Resources, TrainSpec
    h = p.submit(JobSpec(
        name=name,
        resources=Resources(replicas=learners, gpus_per_replica=gpus),
        max_restarts=10,
        train=TrainSpec(total_steps=total_steps, step_time_s=0.5,
                        checkpoint_interval_s=ckpt_s,
                        recovery_mode=recovery)))
    p.run(5)
    assert h.acked and h.job_id, f"{name}: submission not acked"
    return h


def _chaos_case(scenario: str, seed: int, *, n_nodes=8, gpus_per_node=4,
                learners=2, total_steps=60, ckpt_s=10.0,
                recovery="checkpoint", make_faults=None,
                expect_category="", expect_repair: Optional[str] = None,
                recovery_pod: Optional[str] = None):
    """Boot a fresh platform, submit, arm the scripted faults, run to a
    terminal state, then check journal + repair against expectations."""
    from repro.core.failures import FaultPlan
    from repro.core.platform import DLaaSPlatform

    p = DLaaSPlatform(seed=seed, n_nodes=n_nodes, gpus_per_node=gpus_per_node)
    p.run(10)
    h = _chaos_submit(p, f"chaos-{scenario}", learners=learners,
                      total_steps=total_steps, ckpt_s=ckpt_s,
                      recovery=recovery)
    t_inject = p.sim.now
    p.inject(FaultPlan(tuple(make_faults(p, h.job_id))))
    state = p.run_until_terminal(h.job_id, timeout=3000)

    ev = p.client.events(h.job_id)
    cats = [e["failure"]["category"] for e in ev if "failure" in e]
    repairs = [e["event"] for e in ev if e["event"].startswith("REPAIR ")]
    plains = [e["event"] for e in ev
              if e["event"].startswith("RESTART plain")]

    why: List[str] = []
    if state != "COMPLETED":
        why.append(f"terminal state {state} != COMPLETED")
    if expect_category not in cats:
        why.append(f"category {expect_category} not journaled (got {cats})")
    if expect_repair is not None:
        if not any(f"REPAIR {expect_repair} " in r for r in repairs):
            why.append(f"repair {expect_repair} not applied (got {repairs})")
    else:
        if repairs:
            why.append(f"unexpected repair applied: {repairs}")
        if not plains:
            why.append("no plain-restart fallback event")
    # the safe-list contract: every applied repair is a registered action
    from repro.core.failures import SAFE_REPAIRS
    for r in repairs:
        action = r.split()[1]
        if action not in SAFE_REPAIRS.values():
            why.append(f"unregistered repair action {action!r}")
    # exclusions never leak past the job
    if p.scheduler.excluded_for(h.job_id):
        why.append("node exclusions leaked past job teardown")

    rec = None
    if recovery_pod is not None:
        rec = p.recovery_time(recovery_pod.format(job=h.job_id), t_inject)
    return {
        "scenario": scenario, "state": state, "categories": cats,
        "repairs": repairs, "plain_restarts": len(plains),
        "recovery_s": round(rec, 2) if rec is not None else None,
        "ok": not why, "why": why,
    }


def run_chaos():
    """All chaos scenarios; returns (rows, n_failures)."""
    from repro.core.failures import Fault

    rows = []
    rows.append(_chaos_case(
        "oom", seed=41, learners=2, total_steps=60,
        make_faults=lambda p, j: [Fault(
            kind="oom", at=p.sim.now, job=j, learner=0, at_step=10)],
        expect_category="OOM", expect_repair="reduce_memory",
        recovery_pod="learner-{job}-0"))

    rows.append(_chaos_case(
        "ckpt_corrupt", seed=42, learners=2, total_steps=100, ckpt_s=8.0,
        make_faults=lambda p, j: [Fault(
            kind="ckpt_corrupt", at=p.sim.now + 55.0, job=j, learner=0)],
        expect_category="CKPT_CORRUPT", expect_repair="checkpoint_fallback",
        recovery_pod="learner-{job}-0"))

    rows.append(_chaos_case(
        "flaky_pod", seed=43, learners=2, total_steps=60,
        make_faults=lambda p, j: [Fault(
            kind="flaky_pod", at=p.sim.now + 35.0, job=j, learner=1)],
        expect_category="FLAKY_POD", expect_repair="restart_in_place",
        recovery_pod="learner-{job}-1"))

    # 4 × 1-GPU learners bin-pack onto one node; poisoning it takes the
    # whole gang down at once — classified from node co-occurrence, cured
    # by excluding the node and rescheduling the gang elsewhere
    rows.append(_chaos_case(
        "poisoned_node", seed=44, n_nodes=4, learners=4, total_steps=60,
        make_faults=lambda p, j: [Fault(
            kind="poison_node", at=p.sim.now + 35.0, job=j, learner=0)],
        expect_category="POISONED_NODE",
        expect_repair="reschedule_exclude_node",
        recovery_pod="learner-{job}-0"))

    rows.append(_chaos_case(
        "straggler", seed=45, learners=4, total_steps=120,
        recovery="rejoin",
        make_faults=lambda p, j: [Fault(
            kind="straggler", at=p.sim.now, job=j, learner=2,
            slow_factor=4.0, incarnations=1)],
        expect_category="STRAGGLER", expect_repair="restart_in_place"))

    # an exit detail nobody recognizes: journaled UNKNOWN at low
    # confidence, plain restart, provably NO repair action
    rows.append(_chaos_case(
        "unknown", seed=46, learners=2, total_steps=60,
        make_faults=lambda p, j: [Fault(
            kind="wedge", at=p.sim.now, job=j, learner=1, at_step=8,
            detail="container exited with status 139 (segfault?)")],
        expect_category="UNKNOWN", expect_repair=None,
        recovery_pod="learner-{job}-1"))

    return rows, sum(1 for r in rows if not r["ok"])


def chaos_main(smoke: bool) -> int:
    t0 = time.perf_counter()
    rows, failures = run_chaos()
    wall = time.perf_counter() - t0
    print("scenario,state,category,repair,recovery_s,ok")
    for r in rows:
        cat = r["categories"][0] if r["categories"] else ""
        rep = r["repairs"][0] if r["repairs"] else "plain-restart"
        print(f"{r['scenario']},{r['state']},{cat},{rep},"
              f"{r['recovery_s']},{'OK' if r['ok'] else 'FAIL'}")
        for w in r["why"]:
            print(f"  FAIL: {w}")
    report = {"lane": "chaos", "wall_s": round(wall, 2),
              "failures": failures, "scenarios": rows}
    REPORT_OUT.parent.mkdir(parents=True, exist_ok=True)
    REPORT_OUT.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {REPORT_OUT} ({failures} failures, {wall:.1f}s)")
    if not smoke:
        BENCH_OUT.write_text(json.dumps(report, indent=2) + "\n")
        print(f"wrote {BENCH_OUT}")
    return failures


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--chaos", action="store_true",
                    help="run the self-healing chaos lane (virtual time)")
    ap.add_argument("--smoke", action="store_true",
                    help="chaos: don't rewrite the checked-in BENCH file")
    args = ap.parse_args(argv)

    if args.chaos:
        return chaos_main(smoke=args.smoke)

    print("config,steps_s,overhead_pct,ckpt_bytes")
    for r in run():
        print(f"{r[0]},{r[1]:.2f},{r[2]:.2f},{r[3]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
