"""Fig. 3 analog: the dependability/efficiency trade-off.

The paper's Fig. 3 compares DLaaS on commodity hardware against a bare
DGX-1 (≈3–14% slower) and argues the gap buys dependability.  Our analog
measures the cost of ARMING the dependability features on the same
hardware: a minimally-instrumented loop vs a fully-armed one (synchronous
quorum status every step + frequent real checkpoints to the object store
with sha256 integrity).  The fully-armed config bounds lost work at one
checkpoint interval; the measured % slowdown is the price.

Output rows: config,steps_s,overhead_pct_vs_minimal,ckpt_bytes
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig, get_config
from repro.core.checkpoint import CheckpointManager
from repro.core.objectstore import ObjectStore
from repro.core.platform import DLaaSPlatform
from repro.data.pipeline import SyntheticLMData
from repro.models.layers import Ctx
from repro.train.steps import init_train_state, make_train_step

STEPS = 60
WARMUP = 10


def run(arch: str = "paper-overhead-100m", ckpt_every: int = 10):
    cfg = get_config(arch).reduced()
    run_cfg = RunConfig(learning_rate=1e-3, warmup_steps=5, total_steps=1000)
    data = SyntheticLMData(cfg.vocab_size, 64, 8, seed=0)
    step = jax.jit(make_train_step(cfg, Ctx(dtype=jnp.float32), run_cfg))

    def warm():
        s = init_train_state(cfg, jax.random.key(0), run_cfg)
        for i in range(WARMUP):
            s, m = step(s, data.batch_at(i))
        jax.block_until_ready(m["loss"])
        return s

    platform = DLaaSPlatform(seed=2)
    platform.run(5)
    store = ObjectStore()
    ck = CheckpointManager(store, "armed", keep_last=2)

    def run_minimal(s):
        t0 = time.perf_counter()
        for i in range(STEPS):
            s, m = step(s, data.batch_at(i))
        jax.block_until_ready(m["loss"])
        return STEPS / (time.perf_counter() - t0)

    def run_armed(s):
        t0 = time.perf_counter()
        for i in range(STEPS):
            s, m = step(s, data.batch_at(i))
            def put(i=i):
                yield from platform.statestore.put(
                    "status/armed/learner/0",
                    {"state": "RUNNING", "step": i, "loss": float(m["loss"])})
            platform.sim.spawn(put())
            platform.sim.run_for(0.3)
            if (i + 1) % ckpt_every == 0:
                ck.save(i, jax.tree.map(np.asarray, s))
        jax.block_until_ready(m["loss"])
        return STEPS / (time.perf_counter() - t0)

    # interleave repetitions and take medians (1-CPU timing is noisy)
    import statistics
    s = warm()
    mins, arms = [], []
    for _ in range(3):
        mins.append(run_minimal(s))
        arms.append(run_armed(s))
    minimal = statistics.median(mins)
    armed = statistics.median(arms)

    pct = 100.0 * (minimal - armed) / minimal
    return [
        ("dependability_fig3/minimal", minimal, 0.0, 0),
        (f"dependability_fig3/armed_ckpt{ckpt_every}", armed, pct,
         store.bytes_written),
    ]


def main():
    print("config,steps_s,overhead_pct,ckpt_bytes")
    for r in run():
        print(f"{r[0]},{r[1]:.2f},{r[2]:.2f},{r[3]}")


if __name__ == "__main__":
    main()
