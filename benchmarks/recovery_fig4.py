"""Fig. 4 analog: per-component crash-recovery time.

The paper kills each component with kubectl and reports seconds to recover
(API 3-5, LCM 4-6, Guardian 1-2, Helper 3-4, Learner 10-20).  We do the
same against the virtual-time platform: kill the pod, measure virtual
seconds until the replacement is RUNNING.  Additionally we report the REAL
wall-clock cost of the learner's state restore (checkpoint download/load +
re-jit), which the paper attributes the learner's longer recovery to.

Output rows: component,recover_s_min,recover_s_max,paper_range
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig, get_config
from repro.core import DLaaSPlatform
from repro.core.checkpoint import CheckpointManager
from repro.core.jobspec import JobSpec, Resources, TrainSpec
from repro.core.objectstore import ObjectStore
from repro.data.pipeline import SyntheticLMData
from repro.models.layers import Ctx
from repro.train.steps import init_train_state, make_train_step

PAPER = {"api": "3-5s", "lcm": "4-6s", "guardian": "1-2s",
         "helper": "3-4s", "learner": "10-20s"}


def measure_component(component: str, trials: int = 5):
    times = []
    for t in range(trials):
        p = DLaaSPlatform(seed=100 + t)
        p.run(10)
        h = p.submit(JobSpec(
            name="r",
            resources=Resources(replicas=2, gpus_per_replica=1),
            max_restarts=50,
            train=TrainSpec(total_steps=10_000, step_time_s=0.5,
                            checkpoint_interval_s=20)))
        p.run(40)           # fully deployed and training
        pod = {"api": "api-0", "lcm": "lcm-0",
               "guardian": f"guardian-{h.job_id}",
               "helper": f"helper-{h.job_id}",
               "learner": f"learner-{h.job_id}-0"}[component]
        t0 = p.sim.now
        assert p.kill_pod(pod), pod
        p.run(60)
        rt = p.recovery_time(pod, t0)
        if rt is not None:
            times.append(rt)
    return times


def learner_restore_wallclock():
    """Real work on restart: checkpoint load + re-jit + first step."""
    cfg = get_config("paper-overhead-100m").reduced()
    run_cfg = RunConfig(learning_rate=1e-3, warmup_steps=5, total_steps=100)
    data = SyntheticLMData(cfg.vocab_size, 64, 8, seed=0)
    state = init_train_state(cfg, jax.random.key(0), run_cfg)
    step = jax.jit(make_train_step(cfg, Ctx(dtype=jnp.float32), run_cfg))
    for i in range(5):
        state, m = step(state, data.batch_at(i))
    store = ObjectStore()
    ck = CheckpointManager(store, "restore-bench")
    ck.save(5, jax.tree.map(np.asarray, state))

    t0 = time.perf_counter()
    _, restored = ck.load()
    state2 = jax.tree.map(lambda c, n: jnp.asarray(n).astype(c.dtype),
                          state, restored)
    step2 = jax.jit(make_train_step(cfg, Ctx(dtype=jnp.float32), run_cfg))
    state2, m = step2(state2, data.batch_at(5))
    jax.block_until_ready(m["loss"])
    return time.perf_counter() - t0


def run():
    rows = []
    for comp in ("api", "lcm", "guardian", "helper", "learner"):
        ts = measure_component(comp)
        rows.append((comp, min(ts), max(ts), PAPER[comp]))
    return rows


def main():
    print("component,recover_s_min,recover_s_max,paper_range")
    for comp, lo, hi, paper in run():
        print(f"{comp},{lo:.1f},{hi:.1f},{paper}")
    print(f"learner_restore_wallclock_s,"
          f"{learner_restore_wallclock():.2f},,real CPU (load+rejit+step)")


if __name__ == "__main__":
    main()
