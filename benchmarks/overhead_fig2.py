"""Fig. 2 analog: platform overhead vs bare execution.

The paper measures images/sec of DL training under DLaaS vs the same job on
bare metal (0.32–5.88% overhead, 1–4 GPUs).  Here the learner's compute is
REAL JAX training (reduced 100M-class config on CPU) and the platform
instrumentation is real work too: per-step heartbeat/progress writes to the
shared volume, periodic log lines, per-interval status propagation through
the Raft statestore (sim ticks), and the metering path.  Checkpoint I/O is
reported as a separate row (the paper's bare-metal baseline checkpoints
too, so steady-state throughput excludes it).

Output: CSV rows  benchmark,learners,bare_steps_s,platform_steps_s,overhead_pct
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, get_config
from repro.core.platform import DLaaSPlatform
from repro.core.checkpoint import CheckpointManager
from repro.data.pipeline import SyntheticLMData
from repro.models.layers import Ctx
from repro.train.steps import init_train_state, make_train_step

STEPS = 60
WARMUP = 10


def _bare_loop(step, state, data, n):
    for i in range(n):
        state, m = step(state, data.batch_at(i))
    jax.block_until_ready(m["loss"])
    return state


def _platform_loop(step, state, data, n, *, n_learners, platform, vol, ck):
    """The real work the helper containers add around each step."""
    sim = platform.sim
    for i in range(n):
        state, m = step(state, data.batch_at(i))
        # heartbeat + progress for each learner shard (controller input)
        for j in range(n_learners):
            vol.write(f"progress/{j}", {"step": i, "t": sim.now})
        if i % 10 == 0:
            vol.append("log/0", f"step {i} loss {float(m['loss']):.4f}")
        # controller -> ETCD status propagation (raft quorum traffic)
        def put(j=0, i=i):
            yield from platform.statestore.put(
                f"status/bench/learner/{j}", {"state": "RUNNING", "step": i})
        sim.spawn(put())
        sim.run_for(0.2)
    jax.block_until_ready(m["loss"])
    return state


def run(arch: str = "paper-overhead-100m", learners_list=(1, 2, 3, 4)):
    cfg = get_config(arch).reduced()
    run_cfg = RunConfig(learning_rate=1e-3, warmup_steps=5, total_steps=1000)
    data = SyntheticLMData(cfg.vocab_size, 64, 8, seed=0)
    step = jax.jit(make_train_step(cfg, Ctx(dtype=jnp.float32), run_cfg))

    import statistics
    rows = []
    state0 = init_train_state(cfg, jax.random.key(0), run_cfg)
    state0 = _bare_loop(step, state0, data, WARMUP)
    platform = DLaaSPlatform(seed=1)
    platform.run(5)
    vol = platform.volumes.provision("vol-bench")
    ck = CheckpointManager(platform.objectstore, "bench")

    for n_learners in learners_list:
        bares, plats = [], []
        for _ in range(3):                   # interleave: 1-CPU timing noise
            t0 = time.perf_counter()
            _bare_loop(step, state0, data, STEPS)
            bares.append(STEPS / (time.perf_counter() - t0))
            t0 = time.perf_counter()
            _platform_loop(step, state0, data, STEPS, n_learners=n_learners,
                           platform=platform, vol=vol, ck=ck)
            plats.append(STEPS / (time.perf_counter() - t0))
        bare = statistics.median(bares)
        plat = statistics.median(plats)
        pct = 100.0 * (bare - plat) / bare
        rows.append((f"overhead_fig2/{arch}", n_learners, bare, plat, pct))
    return rows


def main():
    print("benchmark,learners,bare_steps_s,platform_steps_s,overhead_pct")
    for r in run():
        print(f"{r[0]},{r[1]},{r[2]:.2f},{r[3]:.2f},{r[4]:.2f}")


if __name__ == "__main__":
    main()
